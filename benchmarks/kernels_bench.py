"""Kernel micro-benchmarks (the paper's Im2Col+GEMM operators, §6).

On this CPU box the Pallas kernels execute in interpret mode, so absolute
times are not TPU numbers; what IS meaningful here is (a) correctness-at-
scale vs the XLA reference and (b) the arithmetic-intensity table used to
pick BlockSpecs — both reported.  TPU wall-time belongs to real hardware.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import csv_row, save


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out


def run(verbose: bool = True) -> list[str]:
    key = jax.random.PRNGKey(0)
    rows = []

    # GEMM (paper's conv operator #2): MXU tile 128x128xK
    m, k, n = 512, 512, 512
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = jax.random.normal(key, (k, n), jnp.float32)
    t_ref, y_ref = _time(lambda x, y: ref.gemm_ref(x, y), a, b)
    t_k, y_k = _time(lambda x, y: ops.gemm(x, y), a, b)
    err = float(jnp.max(jnp.abs(y_ref - y_k)))
    ai = 2 * m * k * n / ((m * k + k * n + m * n) * 4)
    rows.append(csv_row("gemm_512_interp", t_k, f"xla_ref_us={t_ref:.0f};max_err={err:.1e};arith_intensity={ai:.0f}"))

    # Im2Col conv (paper's operator #1): AlexNet conv3 shape
    x = jax.random.normal(key, (1, 13, 13, 256), jnp.float32)
    w = jax.random.normal(key, (3, 3, 256, 384), jnp.float32)
    t_ref, y_ref = _time(lambda x, w: ref.conv2d_ref(x, w), x, w)
    t_k, y_k = _time(lambda x, w: ops.conv2d_im2col(x, w), x, w)
    err = float(jnp.max(jnp.abs(y_ref - y_k)))
    rows.append(csv_row("im2col_conv_alexnet3_interp", t_k, f"xla_ref_us={t_ref:.0f};max_err={err:.1e}"))

    # Flash attention
    q = jax.random.normal(key, (1, 4, 256, 64), jnp.float32)
    kk = jax.random.normal(key, (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2, 256, 64), jnp.float32)
    t_ref, y_ref = _time(lambda q, k, v: ref.attention_ref(q, k, v), q, kk, v)
    t_k, y_k = _time(lambda q, k, v: ops.flash_attention(q, k, v, bq=128, bk=128), q, kk, v)
    err = float(jnp.max(jnp.abs(y_ref - y_k)))
    rows.append(csv_row("flash_attn_s256_interp", t_k, f"xla_ref_us={t_ref:.0f};max_err={err:.1e}"))

    # SSD scan
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (1, 256, 4, 32), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 256, 4)))
    A = -jnp.exp(jax.random.normal(ks[2], (4,)) * 0.5)
    B = jax.random.normal(ks[3], (1, 256, 16))
    C = jax.random.normal(ks[4], (1, 256, 16))
    t_ref, y_ref = _time(lambda *a: ref.ssd_ref(*a), x, dt, A, B, C)
    t_k, y_k = _time(lambda *a: ops.ssd_scan(*a, chunk=64), x, dt, A, B, C)
    err = float(jnp.max(jnp.abs(y_ref - y_k)))
    rows.append(csv_row("ssd_scan_s256_interp", t_k, f"xla_ref_us={t_ref:.0f};max_err={err:.1e}"))

    if verbose:
        for r in rows:
            print("  kern", r)
    save("kernels_bench", {"rows": rows})
    return rows


if __name__ == "__main__":
    run()
