"""Fig. 7/8 — heuristic sweep H1..H6 across EP configurations C1..C5.

Fig. 7: solution throughput per (heuristic, platform), normalized to the
best heuristic on that platform.  Fig. 8: convergence time of H1 vs H3
(paper: H1/H3 win ~80% of cases; H3 converges faster in ~90%).
"""

from __future__ import annotations

from repro.core import HEURISTICS, run_shisha, table3_platform

from .common import fresh_trace, save
from repro.models.cnn import network_layers
from repro.core import weights, DatabaseEvaluator, Trace


def run(verbose: bool = True, nets=("resnet50", "yolov3")) -> dict:
    payload = {}
    h1h3_faster = 0
    h1h3_total = 0
    best_is_h1_or_h3 = 0
    cases = 0
    for net in nets:
        layers = network_layers(net)
        ws = weights(layers)
        payload[net] = {}
        for conf_name in ("C1", "C2", "C3", "C4", "C5"):
            plat = table3_platform(conf_name)
            row = {}
            for h in HEURISTICS:
                tr = Trace(DatabaseEvaluator(plat, layers))
                res = run_shisha(ws, tr, h)
                row[h] = {"tp": res.result.best_throughput, "wall": tr.wall, "trials": tr.n_trials}
            best = max(r["tp"] for r in row.values())
            for h in row:
                row[h]["norm"] = row[h]["tp"] / best
            payload[net][conf_name] = row
            cases += 1
            winner = max(row, key=lambda h: row[h]["tp"])
            if winner in ("H1", "H3"):
                best_is_h1_or_h3 += 1
            h1h3_total += 1
            if row["H3"]["wall"] <= row["H1"]["wall"]:
                h1h3_faster += 1
            if verbose:
                cells = " ".join(f"{h}={row[h]['norm']:.3f}" for h in HEURISTICS)
                print(f"  fig7 {net:9s} {conf_name} {cells}  winner={winner}")
    payload["summary"] = {
        "h1_or_h3_wins_frac": best_is_h1_or_h3 / cases,
        "h3_faster_than_h1_frac": h1h3_faster / h1h3_total,
    }
    if verbose:
        s = payload["summary"]
        print(
            f"  fig7/8 H1-or-H3 wins {s['h1_or_h3_wins_frac']*100:.0f}% of cases (paper ~80%); "
            f"H3 faster than H1 in {s['h3_faster_than_h1_frac']*100:.0f}% (paper ~90%)"
        )
    save("fig7_heuristics", payload)
    return payload


if __name__ == "__main__":
    run()
