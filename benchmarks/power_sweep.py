"""Power sweep — cap-blind vs cap-aware DVFS tuning under a package cap.

    PYTHONPATH=src python -m benchmarks.power_sweep [--quick]

The acceptance scenario of the power/thermal subsystem, run on the
congested mesh cell of :mod:`benchmarks.fig9_interconnect` (2x4 mesh, a
co-tenant hammering the FEP-row links) with a :class:`~repro.power.PowerModel`
attached.  For each swept cap fraction two arms tune and then serve the
*same* seeded arrival stream:

  * **cap-blind** — the paper's loop (``run_shisha``), oblivious to the
    package budget: all EPs stay at nominal clocks, so under a binding cap
    its served peak package draw *violates* the budget.
  * **cap-aware** — a warm re-tune with ``tune(dvfs=True)``: per-EP
    frequency levels become tuned state, cap-infeasible candidates are
    rejected before being paid, and the adopted level vector satisfies the
    cap by construction.

Both arms serve with the thermal RC model live, so each reports
joules/request, peak/average package watts, throttle events and the
hottest chiplet temperature — the energy price of staying under the
budget, next to the throughput price.

The full payload lands in ``experiments/benchmarks/power_sweep.json`` and
the acceptance cell's headline (tightest swept cap) additionally in
``BENCH_power_sweep.json`` at the repo root, mirroring
``BENCH_selfbench.json``; both are strict JSON (an uncapped model reports
``cap_w`` as ``null``, never ``inf``).  Everything here is deterministic:
database oracle, seeded traffic, seeded thermal parameter jitter.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.core import DatabaseEvaluator, Trace, paper_platform, weights
from repro.core.heuristics import run_shisha
from repro.core.tuner import tune
from repro.interconnect import Flow, mesh2d, uniform_fabric
from repro.models.cnn import network_layers
from repro.power import uniform_power, uniform_thermal
from repro.serve import PoissonTraffic, ServingSimulator

from .common import save

ROOT = Path(__file__).resolve().parent.parent

#: the fig9_interconnect congested cell: low-bandwidth 2x4 mesh with a
#: steady co-tenant on the links joining the FEP row
LINK_BW = 1e8
CONGESTOR_PAIRS = ((0, 1), (1, 2), (2, 3), (0, 3))
CONGESTOR_BYTES = 2e6

#: package cap as a fraction of the blind schedule's nominal all-busy
#: draw — every fraction below 1.0 is binding at nominal clocks
CAP_FRACTIONS = (0.9, 0.8, 0.7)
CAP_FRACTIONS_QUICK = (0.8,)

THERMAL_SEED = 11


def _cell():
    layers = network_layers("synthnet")
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=LINK_BW, latency=1e-6))
    )
    bg = tuple(
        Flow(src=s, dst=d, nbytes=CONGESTOR_BYTES, nodes=True)
        for s, d in CONGESTOR_PAIRS
    )
    return layers, plat, bg


def _powered_evaluator(plat, layers, bg, cap_w):
    """Fresh evaluator over a fresh powered platform (arms must not share
    the mutable level vector)."""
    pm = uniform_power(
        plat, cap_w=cap_w, thermal=uniform_thermal(plat.n_eps, seed=THERMAL_SEED)
    )
    ev = DatabaseEvaluator(plat.with_power(pm), layers)
    ev.background_flows = bg
    return ev, pm


def _serve(ev, conf, arrivals, horizon, slo) -> dict:
    sim = ServingSimulator(ev, conf, slo=slo)
    res = sim.run(arrivals, horizon)
    p = res.power
    return {
        "n_completed": res.n_completed,
        "p99_latency_s": res.p99,
        "energy_j": p["energy_j"],
        "joules_per_request": p["joules_per_request"],
        "peak_package_w": p["peak_package_w"],
        "avg_package_w": p["avg_package_w"],
        "cap_w": p["cap_w"],
        "throttle_events": p["throttle_events"],
        "max_temp_c": p["max_temp_c"],
        "dvfs_levels": p["dvfs_levels"],
    }


def sweep_cell(
    cap_fraction, blind, blind_serve, layers, plat, bg, arrivals, horizon, slo, verbose
) -> dict:
    """One binding cap: the blind arm's measured serve vs a cap-aware
    warm re-tune (DVFS knobs live, infeasible candidates rejected)."""
    cap_w = cap_fraction * blind_serve["peak_package_w"]

    aware_ev, aware_pm = _powered_evaluator(plat, layers, bg, cap_w=cap_w)
    aware_trace = Trace(aware_ev)
    aware = tune(blind.best_conf, aware_trace, dvfs=True)
    assert aware.dvfs_levels is not None
    assert aware_pm.cap_feasible(aware.best_conf.eps)

    cell = {
        "cap_fraction": cap_fraction,
        "cap_w": cap_w,
        "blind_throughput": blind.best_throughput,
        "aware_throughput": aware.best_throughput,
        "aware_retune_trials": aware_trace.n_trials,
        "aware_dvfs_levels": list(aware.dvfs_levels),
        # the blind serve is cap-independent physics (nominal clocks, no
        # enforcement); only its *reported* cap changes across the sweep
        "blind": dict(blind_serve, cap_w=cap_w),
        "aware": _serve(aware_ev, aware.best_conf, arrivals, horizon, slo),
    }
    cell["blind_violates_cap"] = cell["blind"]["peak_package_w"] > cap_w
    cell["aware_meets_cap"] = cell["aware"]["peak_package_w"] <= cap_w
    if verbose:
        print(
            f"  power_sweep cap={cap_fraction:.2f} ({cap_w:6.1f} W): "
            f"blind peak={cell['blind']['peak_package_w']:6.1f} W "
            f"({cell['blind']['joules_per_request']:.2f} J/req), "
            f"aware peak={cell['aware']['peak_package_w']:6.1f} W "
            f"({cell['aware']['joules_per_request']:.2f} J/req) -> "
            f"blind violates: {cell['blind_violates_cap']}, "
            f"aware meets: {cell['aware_meets_cap']}"
        )
    return cell


def run(verbose: bool = True, quick: bool = False) -> dict:
    horizon = 40.0 if quick else 120.0
    fractions = CAP_FRACTIONS_QUICK if quick else CAP_FRACTIONS

    layers, plat, bg = _cell()
    ws = weights(layers)
    # cap-blind arm, once: the paper's loop at nominal clocks, then served
    # uncapped — its measured peak draw is the self-calibrated reference
    # every swept cap binds against (a budget *below* observed draw)
    blind_ev, _ = _powered_evaluator(plat, layers, bg, cap_w=float("inf"))
    blind = run_shisha(ws, Trace(blind_ev), "H3").result
    rate = 0.45 * blind.best_throughput
    arrivals = PoissonTraffic(rate=rate, seed=29).arrivals(horizon)
    slo = 3.0 * sum(blind_ev.stage_times(blind.best_conf))
    blind_serve = _serve(blind_ev, blind.best_conf, arrivals, horizon, slo)

    cells = [
        sweep_cell(
            f, blind, blind_serve, layers, plat, bg, arrivals, horizon, slo, verbose
        )
        for f in fractions
    ]

    # acceptance at every binding cap: blind violates, aware satisfies, and
    # both arms priced their energy
    for cell in cells:
        assert cell["blind_violates_cap"], (
            f"cap {cell['cap_fraction']}: blind peak "
            f"{cell['blind']['peak_package_w']:.1f} W never exceeded the "
            f"{cell['cap_w']:.1f} W cap — the cap is not binding"
        )
        assert cell["aware_meets_cap"], (
            f"cap {cell['cap_fraction']}: aware peak "
            f"{cell['aware']['peak_package_w']:.1f} W breaks the cap"
        )
        assert cell["blind"]["joules_per_request"] is not None
        assert cell["aware"]["joules_per_request"] is not None

    tightest = min(cells, key=lambda c: c["cap_fraction"])
    payload = {
        "bench": "power_sweep",
        "cell": {"net": "synthnet", "topology": "mesh2x4", "congestor_flows": len(CONGESTOR_PAIRS)},
        "horizon_s": horizon,
        "offered_rate": rate,
        "sweep": cells,
        # headline scalars (tightest swept cap) for the BENCH_ artifacts
        "cap_fraction": tightest["cap_fraction"],
        "cap_w": tightest["cap_w"],
        "blind_peak_package_w": tightest["blind"]["peak_package_w"],
        "aware_peak_package_w": tightest["aware"]["peak_package_w"],
        "blind_joules_per_request": tightest["blind"]["joules_per_request"],
        "aware_joules_per_request": tightest["aware"]["joules_per_request"],
        "blind_violates_cap": tightest["blind_violates_cap"],
        "aware_meets_cap": tightest["aware_meets_cap"],
    }
    save("power_sweep", payload)
    out = ROOT / "BENCH_power_sweep.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    if verbose:
        print(f"  power_sweep payload -> {out.name}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="single cap fraction, shorter serve")
    args = ap.parse_args()
    run(verbose=True, quick=args.quick)


if __name__ == "__main__":
    main()
