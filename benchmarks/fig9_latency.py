"""Fig. 9 — impact of inter-chiplet latency on pipeline throughput.

Latency sweep 1 ns .. 1 s injected into every chip-to-chip transfer of the
best SynthNet schedule (paper: throughput flat until ~1 ms, Shisha still
finds near-optimal schedules beyond)."""

from __future__ import annotations

from repro.core import AnalyticEvaluator, DatabaseEvaluator, Trace, run_shisha, weights

from .common import save, setup

LATENCIES = [1e-9, 1e-7, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0]


def run(verbose: bool = True) -> dict:
    layers, ws, plat = setup("synthnet", 8)
    base = run_shisha(ws, Trace(DatabaseEvaluator(plat, layers)), "H3")
    conf = base.result.best_conf
    base_tp = base.result.best_throughput

    payload = {"latencies": [], "fixed_conf_tp": [], "retuned_tp": []}
    for lat in LATENCIES:
        plat_l = plat.with_latency(lat)
        ev = DatabaseEvaluator(plat_l, layers)
        tp_fixed = ev.throughput(conf)
        retuned = run_shisha(ws, Trace(DatabaseEvaluator(plat_l, layers)), "H3")
        payload["latencies"].append(lat)
        payload["fixed_conf_tp"].append(tp_fixed / base_tp)
        payload["retuned_tp"].append(retuned.result.best_throughput / base_tp)
        if verbose:
            print(
                f"  fig9 latency={lat:8.0e}s fixed={tp_fixed / base_tp:6.3f} "
                f"retuned={retuned.result.best_throughput / base_tp:.3f}"
            )
    save("fig9_latency", payload)
    return payload


if __name__ == "__main__":
    run()
