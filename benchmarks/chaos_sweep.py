"""Chaos sweep — blind vs resilient serving under a seeded fault trace.

    PYTHONPATH=src python -m benchmarks.chaos_sweep [--quick]

The acceptance scenario of the chaos layer.  A tuned synthnet pipeline on
the 2x4-mesh cell serves the *same* seeded Poisson stream while the
*identical* seeded fault trace — FEP dropouts/revivals from per-class
MTBF/MTTR, fabric link failures and degradations, transient batch
errors — plays out, once per arm:

  * **blind** — the plain :class:`ServingSimulator`: dead EPs stall their
    stage until the scripted revival, batch errors re-serve head-of-line
    forever, queues are unbounded, and every completion counts no matter
    how late.  At 0.8x capacity the fault downtime pushes effective
    utilisation past 1: the backlog grows with every outage and almost
    nothing finishes inside its deadline (congestion collapse).
  * **resilient** — the same simulator with a :class:`ResiliencePolicy`:
    per-request deadlines, capped exponential-backoff retries, a bounded
    admission queue, and deadline-aware shedding that drops expired work
    at whatever stage the outage stranded it.  Goodput stays near the
    faulted pipeline's effective capacity because service time is never
    spent on requests that already missed their deadline.
  * **retuning** — resilient plus a :class:`ContinuousShisha` autotuner
    (dropout/link-loss rescues).  Reported, not asserted: at these MTBFs
    the exploration windows — charged in simulated service seconds — cost
    more than the rescued placement earns back, an honest negative result
    the payload keeps visible.  (The rescue path itself is pinned by
    ``tests/test_chaos.py``.)

Goodput is scored at the same deadline for every arm (the blind arm's is
derived post-hoc from its latency sample), so the comparison is honest:
the resilient arm must win on in-deadline completions per second AND
keep its peak in-system population below the blind arm's backlog.  Both
claims are asserted per swept chaos seed.

The full payload lands in ``experiments/benchmarks/chaos_sweep.json`` and
the first seed's headline additionally in ``BENCH_chaos.json`` at the
repo root, mirroring ``BENCH_power_sweep.json``; both are strict JSON.
Everything here is deterministic: database oracle, seeded traffic,
seeded fault trace (a pure function of model, platform shape, horizon).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.core import DatabaseEvaluator, Trace, generate_seed, paper_platform, tune, weights
from repro.faults import FaultInjector, FaultModel, ResiliencePolicy
from repro.interconnect import mesh2d, uniform_fabric
from repro.models.cnn import network_layers
from repro.serve import ContinuousShisha, PoissonTraffic, ServingSimulator

from .common import save

ROOT = Path(__file__).resolve().parent.parent

#: the same healthy 2x4 mesh cell the serve benchmarks use
LINK_BW = 1e9
CHAOS_SEEDS = (7, 19, 42)
CHAOS_SEEDS_QUICK = (7,)

#: offered load as a fraction of tuned capacity — high enough that fault
#: downtime pushes the *effective* utilisation past 1, so the blind arm's
#: backlog cannot drain between outages
LOAD_FRACTION = 0.8

#: admission bound for the resilient arms; the blind arm queues unboundedly
QUEUE_CAP = 64


def _platform():
    """A fresh platform per arm: chaos link faults mutate the shared
    fabric link state, so arms must not share a fabric object."""
    return paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=LINK_BW, latency=1e-6))
    )


def _chaos(seed: int) -> FaultModel:
    """Class-1 (FEP) outages only — the class-2 EPs stay up, so the fault
    downtime derates rather than zeroes the cell's capacity."""
    return FaultModel(
        seed=seed,
        ep_mtbf={1: 12.0},
        ep_mttr={1: 3.0},
        link_mtbf=20.0,
        link_mttr=3.0,
        batch_error_p=0.05,
    )


def _arm(res, deadline: float, horizon: float) -> dict:
    """Honest cross-arm scoring: goodput at the shared deadline (the blind
    arm has no policy, so its goodput is derived from its latencies)."""
    n_good = sum(1 for l in res.latencies if l <= deadline)
    return {
        "n_arrived": res.n_arrived,
        "n_completed": res.n_completed,
        "goodput_rps": n_good / horizon,
        "throughput_rps": res.throughput_rps,
        "availability": res.availability,
        "n_shed": res.n_shed,
        "n_failed": res.n_failed,
        "n_retries": res.n_retries,
        "p99_latency_s": res.p99,
        "peak_in_system": max((n for _, n in res.load_samples), default=0),
        "n_reconfigs": len(res.reconfigs),
        "reconfig_kinds": sorted({r["kind"] for r in res.reconfigs}),
    }


def sweep_cell(seed, layers, conf, arrivals, horizon, slo, deadline, verbose) -> dict:
    fm = _chaos(seed)
    trace = FaultInjector(fm).trace(_platform(), horizon)
    kinds = [ev.kind for ev in trace]
    assert "dropout" in kinds, f"seed {seed}: fault trace has no EP dropout"
    assert "link" in kinds, f"seed {seed}: fault trace has no link fault"

    pol = ResiliencePolicy(
        deadline_s=deadline, max_retries=3, backoff_s=0.05, queue_cap=QUEUE_CAP
    )

    def serve(resilience=None, autotuner=None):
        plat = _platform().with_faults(fm)
        sim = ServingSimulator(
            DatabaseEvaluator(plat, layers),
            conf,
            slo=slo,
            resilience=resilience,
            autotuner=autotuner(plat) if autotuner is not None else None,
        )
        return _arm(sim.run(arrivals, horizon), deadline, horizon)

    blind = serve()
    resilient = serve(resilience=pol)
    retuning = serve(
        resilience=pol,
        autotuner=lambda p: ContinuousShisha(
            p,
            layers,
            make_evaluator=lambda q: DatabaseEvaluator(q, layers),
            measure_batches=1,
            alpha=2,
            cooldown=15.0,
        ),
    )

    cell = {
        "chaos_seed": seed,
        "n_fault_events": len(trace),
        "n_dropouts": kinds.count("dropout"),
        "n_link_events": kinds.count("link"),
        "blind": blind,
        "resilient": resilient,
        "retuning": retuning,
    }
    cell["resilient_wins_goodput"] = resilient["goodput_rps"] > blind["goodput_rps"]
    cell["resilient_queue_bounded"] = (
        resilient["peak_in_system"] < blind["peak_in_system"]
    )
    if verbose:
        print(
            f"  chaos_sweep seed={seed} ({len(trace)} fault events): "
            f"blind goodput={blind['goodput_rps']:.2f} rps "
            f"(peak in-system {blind['peak_in_system']}), "
            f"resilient goodput={resilient['goodput_rps']:.2f} rps "
            f"(peak {resilient['peak_in_system']}, shed {resilient['n_shed']}), "
            f"retuning goodput={retuning['goodput_rps']:.2f} rps "
            f"({retuning['n_reconfigs']} retunes) -> "
            f"wins: {cell['resilient_wins_goodput']}, "
            f"bounded: {cell['resilient_queue_bounded']}"
        )
    return cell


def run(verbose: bool = True, quick: bool = False) -> dict:
    horizon = 30.0 if quick else 60.0
    seeds = CHAOS_SEEDS_QUICK if quick else CHAOS_SEEDS

    layers = network_layers("synthnet")
    healthy = _platform()
    ev = DatabaseEvaluator(healthy, layers)
    tuned = tune(generate_seed(weights(layers), healthy), Trace(ev))
    conf = tuned.best_conf
    rate = LOAD_FRACTION * tuned.best_throughput
    arrivals = PoissonTraffic(rate=rate, seed=29).arrivals(horizon)
    slo = 3.0 * sum(ev.stage_times(conf))
    deadline = 2.0 * slo

    cells = [
        sweep_cell(s, layers, conf, arrivals, horizon, slo, deadline, verbose)
        for s in seeds
    ]

    # acceptance at every swept seed: under the identical fault trace the
    # resilient arm delivers strictly more in-deadline completions per
    # second AND keeps its in-system population below the blind backlog
    for cell in cells:
        assert cell["resilient_wins_goodput"], (
            f"seed {cell['chaos_seed']}: resilient goodput "
            f"{cell['resilient']['goodput_rps']:.2f} rps did not beat blind "
            f"{cell['blind']['goodput_rps']:.2f} rps"
        )
        assert cell["resilient_queue_bounded"], (
            f"seed {cell['chaos_seed']}: resilient peak in-system "
            f"{cell['resilient']['peak_in_system']} not below blind "
            f"{cell['blind']['peak_in_system']}"
        )

    head = cells[0]
    payload = {
        "bench": "chaos_sweep",
        "cell": {"net": "synthnet", "topology": "mesh2x4", "queue_cap": QUEUE_CAP},
        "horizon_s": horizon,
        "offered_rate": rate,
        "deadline_s": deadline,
        "sweep": cells,
        # headline scalars (first swept seed) for the BENCH_ artifact
        "chaos_seed": head["chaos_seed"],
        "n_fault_events": head["n_fault_events"],
        "blind_goodput_rps": head["blind"]["goodput_rps"],
        "resilient_goodput_rps": head["resilient"]["goodput_rps"],
        "retuning_goodput_rps": head["retuning"]["goodput_rps"],
        "blind_peak_in_system": head["blind"]["peak_in_system"],
        "resilient_peak_in_system": head["resilient"]["peak_in_system"],
        "resilient_availability": head["resilient"]["availability"],
        "resilient_wins_goodput": head["resilient_wins_goodput"],
        "resilient_queue_bounded": head["resilient_queue_bounded"],
    }
    save("chaos_sweep", payload)
    out = ROOT / "BENCH_chaos.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    if verbose:
        print(f"  chaos_sweep payload -> {out.name}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="single chaos seed, shorter serve")
    args = ap.parse_args()
    run(verbose=True, quick=args.quick)


if __name__ == "__main__":
    main()
