"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core import (
    DatabaseEvaluator,
    Trace,
    database_generation_cost,
    paper_platform,
    weights,
)
from repro.models.cnn import network_layers

OUT = Path(__file__).resolve().parent.parent / "experiments" / "benchmarks"


def setup(net: str, n_eps: int = 8):
    layers = network_layers(net)
    plat = paper_platform(n_eps)
    return layers, weights(layers), plat


def fresh_trace(plat, layers, setup_cost: float = 0.0) -> Trace:
    return Trace(DatabaseEvaluator(plat, layers), setup_cost=setup_cost)


def db_cost(n_layers: int, n_eps: int, max_depth=None) -> float:
    return database_generation_cost(n_layers, n_eps, max_depth)


def save(name: str, payload: dict) -> Path:
    OUT.mkdir(parents=True, exist_ok=True)
    p = OUT / f"{name}.json"
    p.write_text(json.dumps(payload, indent=2))
    return p


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
