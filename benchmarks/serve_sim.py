"""Serving-simulator harness: static vs continuous Shisha, multi-tenancy.

    PYTHONPATH=src python -m benchmarks.serve_sim [--quick]

Two experiments, both fully deterministic (seeded traffic, database oracle):

  (a) **drift** — SynthNet on the paper's 8-EP big/LITTLE platform under
      Poisson traffic at 50% of tuned capacity.  At ``fault_t`` the EP
      hosting the bottleneck stage becomes 3x slower (thermal straggler).
      *static* keeps the launch-time schedule; *continuous* detects the
      drift, re-runs Algorithm 2 against the derated platform model —
      paying the full exploration wall-clock on the simulated timeline —
      and installs the recovered schedule.

  (b) **multitenant** — SynthNet + ResNet50 co-scheduled on one 8-EP
      platform via disjoint EP partitions (interleaved / blocked /
      proportional), compared against SynthNet serving alone on the full
      platform under the same traffic.

A third experiment, **multitenant_drift** (own harness entry so CI can
smoke it alone), co-serves both tenants on one shared clock and drops a
FEP one third into the horizon: the *static* arm leaves the launch
partition in place (the victim re-tunes within what remains), the
*elastic* arm lets the ElasticPartitioner steal the cheapest
at-risk-priced EP from the headroomed donor.  Both arms replay the
identical recorded traffic and fault script.

Reported per arm: p50/p95/p99 latency, SLO-violation rate, throughput;
JSON payloads land in experiments/benchmarks/serve_sim.json and
experiments/benchmarks/multitenant_drift.json.
"""

from __future__ import annotations

import argparse

from repro.core import DatabaseEvaluator, Trace, paper_platform, weights
from repro.core.heuristics import run_shisha
from repro.models.cnn import network_layers
from repro.serve import (
    ContinuousShisha,
    MMPPTraffic,
    PoissonTraffic,
    ReplayTraffic,
    ServingSimulator,
    SimResult,
    Tenant,
    co_schedule,
    co_serve,
    partition_eps,
    subplatform,
)

from .common import save


def _metrics(res: SimResult) -> dict:
    return {
        "n_arrived": res.n_arrived,
        "n_completed": res.n_completed,
        "throughput_rps": res.throughput_rps,
        "p50_s": res.p50,
        "p95_s": res.p95,
        "p99_s": res.p99,
        "p95_wait_s": res.p95_wait,
        "slo_s": res.slo,
        "slo_violation_rate": res.slo_rate,
        "occupancy": res.occupancy,
        "reconfigs": res.reconfigs,
    }


def _print_arm(name: str, res: SimResult, verbose: bool) -> None:
    if verbose:
        print(
            f"  serve_sim {name:22s} tp={res.throughput_rps:6.2f}/s "
            f"p50={res.p50 * 1e3:8.0f}ms p95={res.p95 * 1e3:8.0f}ms "
            f"p99={res.p99 * 1e3:8.0f}ms slo_viol={res.slo_rate * 100:5.1f}%"
        )


def drift_scenario(quick: bool, verbose: bool) -> dict:
    """(a) EP slowdown: static Shisha vs continuous Shisha."""
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    ev = DatabaseEvaluator(plat, layers)
    sh = run_shisha(weights(layers), Trace(ev), "H3")
    conf, cap = sh.result.best_conf, sh.result.best_throughput
    fill = sum(ev.stage_times(conf))
    slo = 3.0 * fill
    horizon = 200.0 if quick else 400.0
    fault_t = 40.0 if quick else 60.0
    traffic = PoissonTraffic(rate=0.5 * cap, seed=1)
    times = ev.stage_times(conf)
    bad_ep = conf.eps[max(range(conf.depth), key=times.__getitem__)]

    results = {}
    for arm in ("static", "continuous"):
        tuner = (
            ContinuousShisha(
                plat, layers, make_evaluator=lambda p: DatabaseEvaluator(p, layers)
            )
            if arm == "continuous"
            else None
        )
        sim = ServingSimulator(ev, conf, slo=slo, autotuner=tuner)
        sim.schedule_slowdown(fault_t, bad_ep, 3.0)
        res = sim.run(traffic.arrivals(horizon), horizon)
        results[arm] = res
        _print_arm(f"drift/{arm}", res, verbose)

    st, co = results["static"], results["continuous"]
    beats = co.throughput_rps > st.throughput_rps and co.slo_rate < st.slo_rate
    if verbose:
        print(f"  serve_sim drift: continuous beats static: {beats}")
    return {
        "net": "synthnet",
        "n_eps": 8,
        "capacity_rps": cap,
        "slo_s": slo,
        "horizon_s": horizon,
        "fault": {"t": fault_t, "ep": bad_ep, "slowdown": 3.0},
        "static": _metrics(st),
        "continuous": _metrics(co),
        "continuous_beats_static": beats,
    }


def tenancy_scenario(quick: bool, verbose: bool) -> dict:
    """(b) single-tenant vs two-tenant co-scheduling."""
    plat = paper_platform(8)
    horizon = 120.0 if quick else 240.0

    nets = {}
    for net in ("synthnet", "resnet50"):
        layers = network_layers(net)
        ev = DatabaseEvaluator(plat, layers)
        sh = run_shisha(weights(layers), Trace(ev), "H3")
        nets[net] = {
            "layers": layers,
            "ev": ev,
            "conf": sh.result.best_conf,
            "cap": sh.result.best_throughput,
            "slo": 3.0 * sum(ev.stage_times(sh.result.best_conf)),
        }

    # each tenant asks for ~60% of *half* the platform's capacity, so the
    # partitioned arms are loaded but feasible
    tenants = [
        Tenant(
            name="synthnet",
            layers=tuple(nets["synthnet"]["layers"]),
            traffic=PoissonTraffic(rate=0.3 * nets["synthnet"]["cap"], seed=11),
            slo=nets["synthnet"]["slo"],
        ),
        Tenant(
            name="resnet50",
            layers=tuple(nets["resnet50"]["layers"]),
            traffic=MMPPTraffic(
                rate_low=0.15 * nets["resnet50"]["cap"],
                rate_high=0.45 * nets["resnet50"]["cap"],
                seed=12,
            ),
            slo=nets["resnet50"]["slo"],
        ),
    ]

    # single-tenant baseline: synthnet alone on the full platform
    single = ServingSimulator(
        nets["synthnet"]["ev"], nets["synthnet"]["conf"], slo=nets["synthnet"]["slo"]
    ).run(tenants[0].traffic.arrivals(horizon), horizon)
    _print_arm("tenancy/single", single, verbose)

    strategies = ("interleaved",) if quick else ("interleaved", "blocked", "proportional")
    per_strategy = {}
    for strategy in strategies:
        rows = co_schedule(plat, tenants, strategy=strategy, horizon=horizon)
        per_strategy[strategy] = {
            r.tenant.name: {
                "eps": list(r.ep_idxs),
                "conf": r.conf_pretty,
                "model_throughput": r.model_throughput,
                "n_trials": r.n_trials,
                **_metrics(r.sim),
            }
            for r in rows
        }
        for r in rows:
            _print_arm(f"tenancy/{strategy[:5]}/{r.tenant.name}", r.sim, verbose)

    return {
        "horizon_s": horizon,
        "single_tenant": {"synthnet": _metrics(single)},
        "two_tenant": per_strategy,
    }


def multitenant_drift_scenario(quick: bool, verbose: bool) -> dict:
    """(c) shared-clock co-serving: static vs elastic partitions under one
    scripted FEP dropout at t = horizon/3, identical replayed traffic."""
    plat = paper_platform(8)
    horizon = 150.0 if quick else 300.0
    fault_t = horizon / 3.0

    # tune each tenant on its launch (interleaved) partition to express
    # load as a fraction of the capacity it actually owns
    parts = partition_eps(plat, 2, "interleaved")
    caps, layer_sets = {}, {}
    for name, part in zip(("synthnet", "resnet50"), parts):
        layers = network_layers(name)
        ev = DatabaseEvaluator(subplatform(plat, part, name), layers)
        caps[name] = run_shisha(weights(layers), Trace(ev), "H3").result.best_throughput
        layer_sets[name] = layers

    # victim: steady load at 65% of its partition capacity with a 3x-fill
    # SLO; donor: bursty but deeply headroomed (8-30% of capacity), so the
    # at-risk pricing can afford to hand over a fast EP
    tenants = [
        Tenant(
            name="synthnet",
            layers=tuple(layer_sets["synthnet"]),
            traffic=ReplayTraffic.record(
                PoissonTraffic(rate=0.65 * caps["synthnet"], seed=11), horizon
            ),
            slo=2.7,
        ),
        Tenant(
            name="resnet50",
            layers=tuple(layer_sets["resnet50"]),
            traffic=ReplayTraffic.record(
                MMPPTraffic(
                    rate_low=0.08 * caps["resnet50"],
                    rate_high=0.30 * caps["resnet50"],
                    seed=12,
                ),
                horizon,
            ),
            slo=0.8,
        ),
    ]
    # drop the first FEP of the victim's partition (global index)
    fep = next(e for e in parts[0] if plat.eps[e].is_fep)
    faults = [("dropout", fault_t, fep)]

    arms = {}
    for arm, elastic in (("static", False), ("elastic", True)):
        res = co_serve(
            plat,
            tenants,
            horizon=horizon,
            elastic=elastic,
            batch_policy_search=True,
            measure_batches=2,
            alpha=4,
            faults=faults,
        )
        arms[arm] = res
        for r in res.results:
            _print_arm(f"mt_drift/{arm}/{r.tenant.name}", r.sim, verbose)

    beats = arms["elastic"].aggregate_slo_rate < arms["static"].aggregate_slo_rate
    if verbose:
        print(
            f"  serve_sim mt_drift: elastic {arms['elastic'].aggregate_slo_rate:.3f} vs "
            f"static {arms['static'].aggregate_slo_rate:.3f} agg SLO viol -> "
            f"elastic beats static: {beats}"
        )
    return {
        "n_eps": 8,
        "horizon_s": horizon,
        "fault": {"t": fault_t, "ep": fep, "kind": "dropout"},
        "capacity_rps": caps,
        **{
            arm: {
                "aggregate_slo_rate": res.aggregate_slo_rate,
                "aggregate_throughput_rps": res.aggregate_throughput_rps,
                "final_partitions": {k: list(v) for k, v in res.partitions.items()},
                "tenants": {
                    r.tenant.name: {
                        "eps": list(r.ep_idxs),
                        "batch_policy": list(r.batch_policy or ()),
                        **_metrics(r.sim),
                    }
                    for r in res.results
                },
                "repartitions": [
                    {
                        "t": e.t,
                        "dead_ep": e.dead_ep,
                        "victim": e.victim,
                        "donor": e.donor,
                        "stolen_ep": e.stolen_ep,
                        "price_rps": e.price,
                        "bundle": [dict(d) for d in e.bundle],
                        "partitions": {k: list(v) for k, v in e.partitions.items()},
                        "retune_wall_costs_s": e.retune_costs,
                    }
                    for e in res.repartitions
                ],
            }
            for arm, res in arms.items()
        },
        "elastic_beats_static": beats,
    }


def run_multitenant_drift(verbose: bool = True, quick: bool = False) -> dict:
    payload = multitenant_drift_scenario(quick, verbose)
    save("multitenant_drift", payload)
    if not payload["elastic_beats_static"]:
        raise AssertionError(
            "elastic re-partitioning failed to beat the static partition"
        )
    return payload


def run(verbose: bool = True, quick: bool = False) -> dict:
    payload = {
        "drift": drift_scenario(quick, verbose),
        "multitenant": tenancy_scenario(quick, verbose),
    }
    save("serve_sim", payload)
    if not payload["drift"]["continuous_beats_static"]:
        raise AssertionError("continuous Shisha failed to beat static under drift")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="shorter horizons, fewer strategies")
    ap.add_argument(
        "--scenario",
        default="all",
        choices=("all", "serve_sim", "multitenant_drift"),
        help="which experiment set to run",
    )
    args = ap.parse_args()
    if args.scenario in ("all", "serve_sim"):
        run(verbose=True, quick=args.quick)
    if args.scenario in ("all", "multitenant_drift"):
        run_multitenant_drift(verbose=True, quick=args.quick)


if __name__ == "__main__":
    main()
