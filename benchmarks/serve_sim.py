"""Serving-simulator harness: static vs continuous Shisha, multi-tenancy.

    PYTHONPATH=src python -m benchmarks.serve_sim [--quick]

Two experiments, both fully deterministic (seeded traffic, database oracle):

  (a) **drift** — SynthNet on the paper's 8-EP big/LITTLE platform under
      Poisson traffic at 50% of tuned capacity.  At ``fault_t`` the EP
      hosting the bottleneck stage becomes 3x slower (thermal straggler).
      *static* keeps the launch-time schedule; *continuous* detects the
      drift, re-runs Algorithm 2 against the derated platform model —
      paying the full exploration wall-clock on the simulated timeline —
      and installs the recovered schedule.

  (b) **multitenant** — SynthNet + ResNet50 co-scheduled on one 8-EP
      platform via disjoint EP partitions (interleaved / blocked /
      proportional), compared against SynthNet serving alone on the full
      platform under the same traffic.

Reported per arm: p50/p95/p99 latency, SLO-violation rate, throughput;
JSON payload lands in experiments/benchmarks/serve_sim.json.
"""

from __future__ import annotations

import argparse

from repro.core import DatabaseEvaluator, Trace, paper_platform, weights
from repro.core.heuristics import run_shisha
from repro.models.cnn import network_layers
from repro.serve import (
    ContinuousShisha,
    MMPPTraffic,
    PoissonTraffic,
    ServingSimulator,
    SimResult,
    Tenant,
    co_schedule,
)

from .common import save


def _metrics(res: SimResult) -> dict:
    return {
        "n_arrived": res.n_arrived,
        "n_completed": res.n_completed,
        "throughput_rps": res.throughput_rps,
        "p50_s": res.p50,
        "p95_s": res.p95,
        "p99_s": res.p99,
        "p95_wait_s": res.p95_wait,
        "slo_s": res.slo,
        "slo_violation_rate": res.slo_rate,
        "occupancy": res.occupancy,
        "reconfigs": res.reconfigs,
    }


def _print_arm(name: str, res: SimResult, verbose: bool) -> None:
    if verbose:
        print(
            f"  serve_sim {name:22s} tp={res.throughput_rps:6.2f}/s "
            f"p50={res.p50 * 1e3:8.0f}ms p95={res.p95 * 1e3:8.0f}ms "
            f"p99={res.p99 * 1e3:8.0f}ms slo_viol={res.slo_rate * 100:5.1f}%"
        )


def drift_scenario(quick: bool, verbose: bool) -> dict:
    """(a) EP slowdown: static Shisha vs continuous Shisha."""
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    ev = DatabaseEvaluator(plat, layers)
    sh = run_shisha(weights(layers), Trace(ev), "H3")
    conf, cap = sh.result.best_conf, sh.result.best_throughput
    fill = sum(ev.stage_times(conf))
    slo = 3.0 * fill
    horizon = 200.0 if quick else 400.0
    fault_t = 40.0 if quick else 60.0
    traffic = PoissonTraffic(rate=0.5 * cap, seed=1)
    times = ev.stage_times(conf)
    bad_ep = conf.eps[max(range(conf.depth), key=times.__getitem__)]

    results = {}
    for arm in ("static", "continuous"):
        tuner = (
            ContinuousShisha(
                plat, layers, make_evaluator=lambda p: DatabaseEvaluator(p, layers)
            )
            if arm == "continuous"
            else None
        )
        sim = ServingSimulator(ev, conf, slo=slo, autotuner=tuner)
        sim.schedule_slowdown(fault_t, bad_ep, 3.0)
        res = sim.run(traffic.arrivals(horizon), horizon)
        results[arm] = res
        _print_arm(f"drift/{arm}", res, verbose)

    st, co = results["static"], results["continuous"]
    beats = co.throughput_rps > st.throughput_rps and co.slo_rate < st.slo_rate
    if verbose:
        print(f"  serve_sim drift: continuous beats static: {beats}")
    return {
        "net": "synthnet",
        "n_eps": 8,
        "capacity_rps": cap,
        "slo_s": slo,
        "horizon_s": horizon,
        "fault": {"t": fault_t, "ep": bad_ep, "slowdown": 3.0},
        "static": _metrics(st),
        "continuous": _metrics(co),
        "continuous_beats_static": beats,
    }


def tenancy_scenario(quick: bool, verbose: bool) -> dict:
    """(b) single-tenant vs two-tenant co-scheduling."""
    plat = paper_platform(8)
    horizon = 120.0 if quick else 240.0

    nets = {}
    for net in ("synthnet", "resnet50"):
        layers = network_layers(net)
        ev = DatabaseEvaluator(plat, layers)
        sh = run_shisha(weights(layers), Trace(ev), "H3")
        nets[net] = {
            "layers": layers,
            "ev": ev,
            "conf": sh.result.best_conf,
            "cap": sh.result.best_throughput,
            "slo": 3.0 * sum(ev.stage_times(sh.result.best_conf)),
        }

    # each tenant asks for ~60% of *half* the platform's capacity, so the
    # partitioned arms are loaded but feasible
    tenants = [
        Tenant(
            name="synthnet",
            layers=tuple(nets["synthnet"]["layers"]),
            traffic=PoissonTraffic(rate=0.3 * nets["synthnet"]["cap"], seed=11),
            slo=nets["synthnet"]["slo"],
        ),
        Tenant(
            name="resnet50",
            layers=tuple(nets["resnet50"]["layers"]),
            traffic=MMPPTraffic(
                rate_low=0.15 * nets["resnet50"]["cap"],
                rate_high=0.45 * nets["resnet50"]["cap"],
                seed=12,
            ),
            slo=nets["resnet50"]["slo"],
        ),
    ]

    # single-tenant baseline: synthnet alone on the full platform
    single = ServingSimulator(
        nets["synthnet"]["ev"], nets["synthnet"]["conf"], slo=nets["synthnet"]["slo"]
    ).run(tenants[0].traffic.arrivals(horizon), horizon)
    _print_arm("tenancy/single", single, verbose)

    strategies = ("interleaved",) if quick else ("interleaved", "blocked", "proportional")
    per_strategy = {}
    for strategy in strategies:
        rows = co_schedule(plat, tenants, strategy=strategy, horizon=horizon)
        per_strategy[strategy] = {
            r.tenant.name: {
                "eps": list(r.ep_idxs),
                "conf": r.conf_pretty,
                "model_throughput": r.model_throughput,
                "n_trials": r.n_trials,
                **_metrics(r.sim),
            }
            for r in rows
        }
        for r in rows:
            _print_arm(f"tenancy/{strategy[:5]}/{r.tenant.name}", r.sim, verbose)

    return {
        "horizon_s": horizon,
        "single_tenant": {"synthnet": _metrics(single)},
        "two_tenant": per_strategy,
    }


def run(verbose: bool = True, quick: bool = False) -> dict:
    payload = {
        "drift": drift_scenario(quick, verbose),
        "multitenant": tenancy_scenario(quick, verbose),
    }
    save("serve_sim", payload)
    if not payload["drift"]["continuous_beats_static"]:
        raise AssertionError("continuous Shisha failed to beat static under drift")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="shorter horizons, fewer strategies")
    args = ap.parse_args()
    run(verbose=True, quick=args.quick)


if __name__ == "__main__":
    main()
