"""Fig. 9 extended — interconnect topology, latency and contention sweep.

The paper's Fig. 9 sweeps one scalar inter-chiplet latency; with the
``repro.interconnect`` fabric the same sensitivity question becomes
three-dimensional: **topology** (how many hops a transfer really takes) ×
**link latency** (the original knob, now per hop) × **co-tenant load**
(concurrent flows fair-sharing links).  Three experiments, all
deterministic (database oracle, seeded traffic):

  (a) **sweep** — for each (topology, latency, co-tenant load) cell, tune a
      SynthNet pipeline contention-blind (in isolation, the incumbent) and
      contention-aware (a warm-start re-tune from the incumbent with the
      live flow set in the model — the paper's online mode, plus the
      placement moves of ``tune(placement=True)``), then score both under
      the ground truth that includes the co-tenant flows.

  (b) **congested mesh** (acceptance) — the 2D-mesh cell with a co-tenant
      hammering the row-0 links between the FEPs: the contention-aware
      schedule must achieve *strictly* higher ground-truth throughput than
      the contention-blind one.

  (c) **co-serve** — two tenants on one mesh-fabric platform on the shared
      clock: every monitor window each lane's live activation flows congest
      the other lane's links (``set_background_flows`` on the event loop);
      reported for contention-aware vs contention-blind lane tuners.

JSON payload lands in experiments/benchmarks/fig9_interconnect.json.
"""

from __future__ import annotations

import argparse

from repro.core import DatabaseEvaluator, Trace, paper_platform, weights
from repro.core.heuristics import run_shisha
from repro.core.tuner import tune
from repro.interconnect import (
    Flow,
    crossbar,
    hierarchical,
    mesh2d,
    ring,
    scalar_fabric,
    uniform_fabric,
)
from repro.models.cnn import network_layers
from repro.serve import MMPPTraffic, PoissonTraffic, ReplayTraffic, Tenant, co_serve

from .common import save

#: low-bandwidth fabric links so communication is a first-order cost (the
#: regime where topology/contention can change which schedule wins)
LINK_BW = 1e8
#: per-hop link latencies swept (the Fig. 9 knob, now multiplied by hops)
LATENCIES = [1e-6, 1e-4, 1e-3]
LATENCIES_QUICK = [1e-6, 1e-3]

#: co-tenant congestor: steady flows on the row-0 links joining the FEP
#: nodes of the 2x4 layouts — exactly the links a blind FEP-first schedule
#: crosses most
CONGESTOR_PAIRS = ((0, 1), (1, 2), (2, 3), (0, 3))
CONGESTOR_BYTES = 2e6


def _topologies(n: int, quick: bool) -> dict:
    base = paper_platform(n)
    topos = {
        "scalar": scalar_fabric(base),
        "mesh2x4": uniform_fabric(mesh2d(2, n // 2, bw=LINK_BW, latency=1e-6)),
    }
    if not quick:
        topos["ring"] = uniform_fabric(ring(n, bw=LINK_BW, latency=1e-6))
        topos["crossbar"] = uniform_fabric(crossbar(n, bw=LINK_BW, latency=1e-6), n_eps=n)
        topos["hier2x4"] = uniform_fabric(
            hierarchical(2, n // 2, intra_bw=LINK_BW, inter_bw=LINK_BW / 4)
        )
    return topos


def _congestor() -> tuple[Flow, ...]:
    return tuple(
        Flow(src=s, dst=d, nbytes=CONGESTOR_BYTES, nodes=True) for s, d in CONGESTOR_PAIRS
    )


def _blind_vs_aware(plat, layers, ws, bg: tuple[Flow, ...]) -> dict:
    """Tune blind (isolation incumbent) and aware (warm re-tune under the
    live flow set), score both under the congested ground truth."""
    blind_trace = Trace(DatabaseEvaluator(plat, layers))
    blind = run_shisha(ws, blind_trace, "H3", placement=True).result.best_conf
    if bg:
        aware_ev = DatabaseEvaluator(plat, layers)
        aware_ev.background_flows = bg
        aware_trace = Trace(aware_ev)
        aware = tune(blind, aware_trace, placement=True).best_conf
        aware_wall = aware_trace.wall
    else:
        aware, aware_wall = blind, 0.0
    gt = DatabaseEvaluator(plat, layers)
    gt.background_flows = bg
    return {
        "blind_tp": gt.throughput(blind),
        "aware_tp": gt.throughput(aware),
        "blind_conf": blind.pretty(),
        "aware_conf": aware.pretty(),
        "aware_retune_wall_s": aware_wall,
    }


def sweep(quick: bool, verbose: bool) -> list[dict]:
    layers = network_layers("synthnet")
    ws = weights(layers)
    lats = LATENCIES_QUICK if quick else LATENCIES
    rows = []
    for topo_name, fabric in _topologies(8, quick).items():
        plat0 = paper_platform(8).with_fabric(fabric)
        for lat in lats:
            # with_latency rescales the EP scalars *and* the fabric links,
            # so the knob is the same in both pricing paths
            plat = plat0.with_latency(lat)
            for load_name, bg in (("solo", ()), ("cotenant", _congestor())):
                cell = _blind_vs_aware(plat, layers, ws, bg)
                cell.update(topology=topo_name, latency_s=lat, load=load_name)
                rows.append(cell)
                if verbose:
                    print(
                        f"  fig9i {topo_name:8s} lat={lat:7.0e} {load_name:8s} "
                        f"blind={cell['blind_tp']:6.3f} aware={cell['aware_tp']:6.3f}"
                    )
    return rows


def congested_mesh_scenario(verbose: bool) -> dict:
    """Acceptance cell: 2D mesh, FEP-row congestor, aware must beat blind."""
    layers = network_layers("synthnet")
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=LINK_BW, latency=1e-6))
    )
    cell = _blind_vs_aware(plat, layers, weights(layers), _congestor())
    cell["aware_beats_blind"] = cell["aware_tp"] > cell["blind_tp"]
    if verbose:
        print(
            f"  fig9i congested-mesh: blind={cell['blind_tp']:.3f} "
            f"aware={cell['aware_tp']:.3f} -> aware beats blind: "
            f"{cell['aware_beats_blind']}"
        )
    return cell


def co_serve_scenario(quick: bool, verbose: bool) -> dict:
    """Two tenants co-served on a mesh fabric: live per-window flow sets on
    the event loop, with contention-aware vs -blind lane tuners."""
    horizon = 60.0 if quick else 150.0
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=LINK_BW, latency=1e-6))
    )
    caps = {}
    layer_sets = {}
    for name in ("synthnet", "resnet50"):
        layers = network_layers(name)
        ev = DatabaseEvaluator(plat, layers)
        caps[name] = run_shisha(weights(layers), Trace(ev), "H3").result.best_throughput
        layer_sets[name] = layers
    # loads high enough that both lanes are busy when the slowdown re-tune
    # fires, so the aware arm's tuner provably sees a non-empty flow set
    tenants = [
        Tenant(
            name="synthnet",
            layers=tuple(layer_sets["synthnet"]),
            traffic=ReplayTraffic.record(
                PoissonTraffic(rate=0.55 * caps["synthnet"], seed=21), horizon
            ),
            slo=2.7,
        ),
        Tenant(
            name="resnet50",
            layers=tuple(layer_sets["resnet50"]),
            traffic=ReplayTraffic.record(
                MMPPTraffic(
                    rate_low=0.3 * caps["resnet50"],
                    rate_high=0.6 * caps["resnet50"],
                    seed=22,
                ),
                horizon,
            ),
            slo=0.8,
        ),
    ]
    raw = {}
    arms = {}
    for arm, aware in (("blind", False), ("aware", True)):
        res = co_serve(
            plat,
            tenants,
            horizon=horizon,
            elastic=True,
            contention_aware=aware,
            placement=True,
            measure_batches=2,
            alpha=4,
            faults=[("slowdown", horizon / 3.0, 0, 2.0)],
        )
        raw[arm] = res
        arms[arm] = {
            "aggregate_throughput_rps": res.aggregate_throughput_rps,
            "aggregate_slo_rate": res.aggregate_slo_rate,
            "tenants": {
                r.tenant.name: {
                    "throughput_rps": r.sim.throughput_rps,
                    "p95_s": r.sim.p95,
                    "slo_violation_rate": r.sim.slo_rate,
                    "reconfigs": len(r.sim.reconfigs),
                }
                for r in res.results
            },
        }
        if verbose:
            print(
                f"  fig9i co-serve/{arm}: agg tp="
                f"{arms[arm]['aggregate_throughput_rps']:.2f}/s slo_viol="
                f"{arms[arm]['aggregate_slo_rate'] * 100:.1f}%"
            )
    # witness that the contention_aware knob changed behaviour: the runs are
    # fully deterministic, so identical latency sequences would mean the
    # tuner-side flow injection silently stopped working
    arms_differ = any(
        a.sim.latencies != b.sim.latencies
        for a, b in zip(raw["blind"].results, raw["aware"].results)
    )
    if verbose:
        print(f"  fig9i co-serve: aware arm diverges from blind: {arms_differ}")
    return {"horizon_s": horizon, "arms_differ": arms_differ, **arms}


def adaptive_scenario(verbose: bool, quick: bool = False) -> dict:
    """``fig9_adaptive`` acceptance cell: congestion-aware routing.

    A 2D mesh with the FEP-row congestor, one schedule tuned under static
    routing, the *same* schedule and the *same* flow set priced under (a)
    static XY routing and (b) the adaptive router.  Adaptive must achieve a
    **strictly lower beat**: the routing layer alone — no schedule change —
    must find the detour around the hammered row-0 links.  A second cell
    adds row express channels (heterogeneous links XY routing cannot use) to
    show the headroom adaptive routing unlocks on a richer fabric, and a
    third re-tunes *under* the adaptive fabric (placement on, hop-priced
    relocation trials) to show scheduling and routing compose.
    """
    layers = network_layers("synthnet")
    ws = weights(layers)
    bg = _congestor()
    cells = {}
    topos = {
        "mesh2x4": mesh2d(2, 4, bw=LINK_BW, latency=1e-6),
        "mesh2x4+express": mesh2d(
            2, 4, bw=LINK_BW, latency=1e-6, express_bw=2 * LINK_BW
        ),
    }
    for topo_name, topo in topos.items():
        fab = uniform_fabric(topo)
        plat_static = paper_platform(8).with_fabric(fab)
        plat_adaptive = paper_platform(8).with_fabric(fab.with_routing("adaptive"))
        # one schedule, tuned under static routing: both arms price IT
        conf = run_shisha(
            ws, Trace(DatabaseEvaluator(plat_static, layers)), "H3"
        ).result.best_conf
        beats = {}
        for arm, plat in (("static", plat_static), ("adaptive", plat_adaptive)):
            ev = DatabaseEvaluator(plat, layers)
            ev.background_flows = bg
            beats[arm] = max(ev.stage_times(conf))
        cell = {
            "conf": conf.pretty(),
            "static_beat_s": beats["static"],
            "adaptive_beat_s": beats["adaptive"],
            "adaptive_beats_static": beats["adaptive"] < beats["static"],
        }
        if not quick:
            # routing + scheduling composed: warm re-tune under the adaptive
            # fabric with hop-priced placement moves, scored on that fabric
            aware_ev = DatabaseEvaluator(plat_adaptive, layers)
            aware_ev.background_flows = bg
            aware_trace = Trace(aware_ev)
            retuned = tune(conf, aware_trace, placement=True).best_conf
            gt = DatabaseEvaluator(plat_adaptive, layers)
            gt.background_flows = bg
            cell["retuned_adaptive_beat_s"] = max(gt.stage_times(retuned))
            cell["retune_wall_s"] = aware_trace.wall
        cells[topo_name] = cell
        if verbose:
            msg = (
                f"  fig9a {topo_name:16s} static_beat={cell['static_beat_s']:.4f} "
                f"adaptive_beat={cell['adaptive_beat_s']:.4f}"
            )
            if "retuned_adaptive_beat_s" in cell:
                msg += f" retuned={cell['retuned_adaptive_beat_s']:.4f}"
            print(msg)
    return {
        "link_bw": LINK_BW,
        "congestor": {
            "pairs": [list(p) for p in CONGESTOR_PAIRS],
            "nbytes": CONGESTOR_BYTES,
        },
        "cells": cells,
    }


def run_adaptive(verbose: bool = True, quick: bool = False) -> dict:
    """The ``fig9_adaptive`` benchmark arm (own payload, own CI smoke)."""
    payload = adaptive_scenario(verbose, quick)
    save("fig9_adaptive", payload)
    for topo_name, cell in payload["cells"].items():
        if not cell["adaptive_beats_static"]:
            raise AssertionError(
                f"adaptive routing failed to strictly beat static on the "
                f"congested {topo_name} cell under an identical schedule"
            )
    return payload


def run(verbose: bool = True, quick: bool = False) -> dict:
    payload = {
        "link_bw": LINK_BW,
        "congestor": {
            "pairs": [list(p) for p in CONGESTOR_PAIRS],
            "nbytes": CONGESTOR_BYTES,
        },
        "sweep": sweep(quick, verbose),
        "congested_mesh": congested_mesh_scenario(verbose),
        "co_serve": co_serve_scenario(quick, verbose),
    }
    save("fig9_interconnect", payload)
    if not payload["congested_mesh"]["aware_beats_blind"]:
        raise AssertionError(
            "contention-aware tuning failed to beat contention-blind on the "
            "congested mesh"
        )
    if not payload["co_serve"]["arms_differ"]:
        raise AssertionError(
            "contention_aware had no effect on the co-serve scenario: the "
            "tuner-side flow injection is not reaching the lanes"
        )
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="fewer topologies/latencies")
    args = ap.parse_args()
    run(verbose=True, quick=args.quick)


if __name__ == "__main__":
    main()
