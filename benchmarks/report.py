"""Assemble EXPERIMENTS.md from experiments/{benchmarks,dryrun}/*.json.

    PYTHONPATH=src python -m benchmarks.report
"""

from __future__ import annotations

import json
from pathlib import Path

from . import roofline

ROOT = Path(__file__).resolve().parent.parent
BENCH = ROOT / "experiments" / "benchmarks"
PERF = ROOT / "experiments" / "perf"


def _load(name: str) -> dict | None:
    p = BENCH / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None


def repro_section() -> str:
    out = ["## §Repro — paper-claim validation", ""]
    f4 = _load("fig4_convergence")
    if f4:
        out += [
            "### Fig. 4 — convergence race (SynthNet, 8 EPs)",
            "",
            "| algorithm | best throughput | trials | time-to-converge (sim s) | Shisha speedup |",
            "|---|---|---|---|---|",
        ]
        for name, r in f4["algorithms"].items():
            out.append(
                f"| {name} | {r['best_throughput']:.4f} | {r['n_trials']} | "
                f"{r['time_to_converge_s']:.1f} | {r['speedup_of_shisha']:.1f}x |"
            )
        out += [
            "",
            f"**Mean convergence speedup of Shisha: {f4['mean_speedup']:.1f}×** "
            "(paper: ~35×).  The magnitude depends on the online trial-cost "
            "model (we charge reconfiguration + pipeline fill + 8 measured "
            "beats per trial, identically for every explorer; ES/PS "
            "additionally pay their configuration-database generation, as in "
            "the paper's Fig. 4).  The paper's qualitative claims — orders-of-"
            "magnitude faster convergence, seeded HC/SA matching Shisha's "
            "solution but not beating it, DB-bound ES/PS starting late — all "
            "reproduce; the exact multiplier is cost-model-dependent.",
            "",
        ]
    f5 = _load("fig5_quality")
    if f5:
        out += [
            "### Fig. 5 — solution quality normalized to Exhaustive Search (4 EPs)",
            "",
            "| network | Shisha | HC | SA | RW | PS | Shisha explored |",
            "|---|---|---|---|---|---|---|",
        ]
        for net, row in f5.items():
            out.append(
                f"| {net} | {row['Shisha']['norm']:.3f} | {row['HC']['norm']:.3f} | "
                f"{row['SA']['norm']:.3f} | {row['RW']['norm']:.3f} | {row['PS']['norm']:.3f} | "
                f"{row['Shisha']['explored_frac'] * 100:.4f}% |"
            )
        out += ["", "(paper: Shisha ≈ ES at ~0.1% of the space; ~2.5% on SynthNet)", ""]
    f6 = _load("fig6_seed")
    if f6:
        out += ["### Fig. 6 — Algorithm-1 seed vs 100 random seeds", ""]
        for net, r in f6.items():
            out.append(
                f"* **{net}**: throughput ×{r['tp_gain_vs_random_mean']:.3f} vs random-seed mean, "
                f"convergence ×{r['convergence_speedup_vs_random_mean']:.2f} faster "
                f"(paper: similar/better quality, ≥1.35× faster; +16% tp on YOLOv3)."
            )
        out.append("")
    f7 = _load("fig7_heuristics")
    if f7 and "summary" in f7:
        s = f7["summary"]
        out += [
            "### Fig. 7/8 — heuristics H1–H6 × platforms C1–C5",
            "",
            f"* H1-or-H3 best heuristic in **{s['h1_or_h3_wins_frac'] * 100:.0f}%** of cases (paper ~80%).",
            f"* H3 converges faster than H1 in **{s['h3_faster_than_h1_frac'] * 100:.0f}%** of cases (paper ~90%).",
            "",
        ]
    f9 = _load("fig9_latency")
    if f9:
        out += [
            "### Fig. 9 — inter-chiplet latency sweep (SynthNet best schedule)",
            "",
            "| latency (s) | throughput (fixed conf, rel.) | retuned |",
            "|---|---|---|",
        ]
        for lat, fx, rt in zip(f9["latencies"], f9["fixed_conf_tp"], f9["retuned_tp"]):
            out.append(f"| {lat:.0e} | {fx:.3f} | {rt:.3f} |")
        out += ["", "(paper: flat until ~1 ms; Shisha still near-optimal beyond)", ""]
    kb = _load("kernels_bench")
    if kb:
        out += ["### Kernel micro-bench (interpret mode — correctness + reference timing)", "", "```"]
        out += kb["rows"]
        out += ["```", ""]
    return "\n".join(out)


def dryrun_section() -> str:
    from repro.configs import ARCHS, SHAPES, applicable

    recs_s = roofline.load("single")
    recs_m = roofline.load("multi")
    ok_s = [r for r in recs_s if r.get("runs")]
    ok_m = [r for r in recs_m if r.get("runs")]
    skips = [(a, s, reason) for a in ARCHS for s in SHAPES for runs, reason in [applicable(a, s)] if not runs]
    out = [
        "## §Dry-run",
        "",
        f"* 40 (arch × shape) cells; {len(skips)} skipped by the assignment's "
        "sub-quadratic rule (below), the other 32 compiled on BOTH meshes:",
        f"* single-pod mesh (16×16 = 256 chips): **{len(ok_s)}/32 cells compiled**.",
        f"* multi-pod mesh (2×16×16 = 512 chips): **{len(ok_m)}/32 cells compiled** "
        "(pass/fail gate: proves the `pod` axis shards; roofline below is single-pod).",
        "",
        "Per-cell records (memory_analysis, cost_analysis, collective schedule):",
        "`experiments/dryrun/<arch>__<shape>__<mesh>.json`.",
        "",
        "Skipped cells:",
    ]
    for a, s, reason in skips:
        out.append(f"* {a} × {s} — {reason}")
    out.append("")
    mems = sorted(ok_s, key=lambda r: -r["memory"]["peak_estimate_gib"])[:5]
    out.append("Largest per-device footprints (args+temp−aliased):")
    for r in mems:
        out.append(
            f"* {r['arch']} × {r['shape']}: {r['memory']['peak_estimate_gib']} GiB/dev "
            f"(args {r['memory']['argument_bytes_per_dev'] / 2**30:.1f} GiB)"
        )
    out.append("")
    return "\n".join(out)


def roofline_section() -> str:
    s = roofline.summary("single")
    out = [
        "## §Roofline (single-pod, per device per step; v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link)",
        "",
        roofline.table("single"),
        "",
        f"Dominant-term census over {s['n_cells']} compiled cells: "
        + ", ".join(f"**{k}**: {v}" for k, v in s["dominant_counts"].items()),
        "",
        "Methodology: HLO FLOPs/bytes from `compiled.cost_analysis()`, "
        "loop-trip-count corrected by a linear fit over two reduced-depth "
        "fully-unrolled compiles (DESIGN.md §6b.6); collective wire bytes "
        "parsed from the partitioned HLO with ring-algorithm factors. "
        "CPU-backend fusion is weaker than TPU's, so the memory term is an "
        "upper bound — the Pallas kernels (validated separately) eliminate "
        "the dominant score/state round-trips on real hardware.",
        "",
    ]
    return "\n".join(out)


def perf_section() -> str:
    out = ["## §Perf — hillclimb log", ""]
    if PERF.exists():
        for p in sorted(PERF.glob("*.md")):
            out.append(p.read_text())
    else:
        out.append("(no perf iterations recorded yet)")
    out.append("")
    return "\n".join(out)


def main() -> None:
    doc = "\n".join(
        [
            "# EXPERIMENTS",
            "",
            "All numbers produced on this container (1-core CPU; TPU v5e is the",
            "*target* of the dry-run analysis, not the runtime).  Regenerate with",
            "`python -m benchmarks.run`, `python -m repro.launch.sweep`, then",
            "`python -m benchmarks.report`.",
            "",
            repro_section(),
            dryrun_section(),
            roofline_section(),
            perf_section(),
        ]
    )
    (ROOT / "EXPERIMENTS.md").write_text(doc)
    print(f"wrote EXPERIMENTS.md ({len(doc.splitlines())} lines)")


if __name__ == "__main__":
    main()
