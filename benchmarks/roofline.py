"""Roofline table: aggregate experiments/dryrun/*.json into EXPERIMENTS-ready
markdown + a machine-readable summary.

Terms (per device, per step; TPU v5e constants):
    compute    = HLO_FLOPs / 197e12
    memory     = HLO_bytes / 819e9
    collective = wire_bytes / 50e9
"""

from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load(mesh: str = "single") -> list[dict]:
    recs = []
    for p in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def _fmt(x: float) -> str:
    return f"{x:.3e}"


def table(mesh: str = "single") -> str:
    rows = [
        "| arch | shape | phase | compute s | memory s | collective s | dominant | mem GiB/dev | useful-FLOPs ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        if not r.get("runs"):
            rows.append(
                f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | — | — | {r['reason'][:60]} |"
            )
            continue
        if "roofline" not in r:
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['phase']} | compiled | | | | "
                f"{r['memory']['peak_estimate_gib']} | |"
            )
            continue
        ro = r["roofline"]
        ufr = ro.get("useful_flops_ratio")
        rows.append(
            "| {arch} | {shape} | {phase} | {c} | {m} | {k} | **{dom}** | {gib} | {ufr} |".format(
                arch=r["arch"],
                shape=r["shape"],
                phase=r["phase"],
                c=_fmt(ro["compute_s"]),
                m=_fmt(ro["memory_s"]),
                k=_fmt(ro["collective_s"]),
                dom=ro["dominant"],
                gib=r["memory"]["peak_estimate_gib"],
                ufr=f"{ufr:.2f}" if ufr else "—",
            )
        )
    return "\n".join(rows)


def summary(mesh: str = "single") -> dict:
    recs = [r for r in load(mesh) if r.get("runs") and "roofline" in r]
    doms = {}
    for r in recs:
        doms.setdefault(r["roofline"]["dominant"], []).append(f"{r['arch']}/{r['shape']}")
    return {
        "n_cells": len(recs),
        "dominant_counts": {k: len(v) for k, v in doms.items()},
        "dominant_cells": doms,
    }


def run(verbose: bool = True):
    for mesh in ("single", "multi"):
        recs = load(mesh)
        if not recs:
            continue
        ok = [r for r in recs if r.get("runs")]
        if verbose:
            print(f"  roofline[{mesh}]: {len(ok)} compiled cells, {len(recs) - len(ok)} skipped")
        if mesh == "single" and verbose:
            s = summary(mesh)
            print(f"  roofline dominant terms: {s['dominant_counts']}")
    return summary("single")


if __name__ == "__main__":
    print(table("single"))
    print(json.dumps(summary("single"), indent=2))
