"""Fig. 6 — value of the Algorithm-1 seed vs 100 random seeds.

For ResNet50 and YOLOv3: tune from the Shisha seed and from 100 random
configurations; compare solution throughput and simulated convergence time
(paper: similar-or-better quality, ≥35% faster convergence, 16% better
throughput on YOLOv3).
"""

from __future__ import annotations

import random

import numpy as np

from repro.core import generate_seed, random_config, tune

from .common import fresh_trace, save, setup


def run(verbose: bool = True, nets=("resnet50", "yolov3"), n_random: int = 100) -> dict:
    payload = {}
    for net in nets:
        layers, ws, plat = setup(net, 8)
        n = len(ws)

        tr = fresh_trace(plat, layers)
        seed = generate_seed(ws, plat, choice="rank_w")
        res = tune(seed, tr)
        shisha = {"tp": res.best_throughput, "wall": tr.wall, "trials": tr.n_trials}

        rng = random.Random(0)
        rand_tp, rand_wall = [], []
        for i in range(n_random):
            tr_r = fresh_trace(plat, layers)
            conf = random_config(rng, n, plat.n_eps, depth=plat.n_eps)
            r = tune(conf, tr_r)
            rand_tp.append(r.best_throughput)
            rand_wall.append(tr_r.wall)

        payload[net] = {
            "shisha": shisha,
            "random": {
                "tp_mean": float(np.mean(rand_tp)),
                "tp_best": float(np.max(rand_tp)),
                "wall_mean": float(np.mean(rand_wall)),
            },
            "tp_gain_vs_random_mean": shisha["tp"] / float(np.mean(rand_tp)),
            "convergence_speedup_vs_random_mean": float(np.mean(rand_wall)) / shisha["wall"],
        }
        if verbose:
            p = payload[net]
            print(
                f"  fig6 {net:9s} shisha tp={shisha['tp']:.4f} wall={shisha['wall']:.1f}s | "
                f"random mean tp={p['random']['tp_mean']:.4f} wall={p['random']['wall_mean']:.1f}s | "
                f"tp x{p['tp_gain_vs_random_mean']:.3f} conv x{p['convergence_speedup_vs_random_mean']:.2f}"
            )
    save("fig6_seed", payload)
    return payload


if __name__ == "__main__":
    run()
