"""Benchmark orchestrator — one harness per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV rows per harness plus per-figure
summaries; raw payloads land in experiments/benchmarks/*.json.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="skip the slowest figures")
    ap.add_argument("--only", default=None, help="comma-separated figure list")
    args = ap.parse_args()

    from . import fig4_convergence, fig5_quality, fig6_seed, fig7_heuristics, fig9_latency
    from . import fig9_interconnect, kernels_bench, roofline, serve_sim

    figures = {
        "fig4": fig4_convergence.run,
        "fig5": fig5_quality.run,
        "fig6": fig6_seed.run,
        "fig7": fig7_heuristics.run,
        "fig9": fig9_latency.run,
        "fig9_interconnect": lambda: fig9_interconnect.run(quick=args.quick),
        "fig9_adaptive": lambda: fig9_interconnect.run_adaptive(quick=args.quick),
        "kernels": kernels_bench.run,
        "roofline": roofline.run,
        "serve_sim": lambda: serve_sim.run(quick=args.quick),
        "multitenant_drift": lambda: serve_sim.run_multitenant_drift(quick=args.quick),
    }
    if args.only:
        keep = set(args.only.split(","))
        figures = {k: v for k, v in figures.items() if k in keep}
    if args.quick:
        figures.pop("fig6", None)

    rows = []
    for name, fn in figures.items():
        t0 = time.perf_counter()
        print(f"[bench] {name} ...", flush=True)
        try:
            fn()
            dt = (time.perf_counter() - t0) * 1e6
            rows.append(f"{name},{dt:.0f},ok")
        except Exception as e:  # keep the harness going; report at the end
            dt = (time.perf_counter() - t0) * 1e6
            rows.append(f"{name},{dt:.0f},FAILED:{type(e).__name__}:{e}")
            import traceback

            traceback.print_exc()
    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)
    if any("FAILED" in r for r in rows):
        sys.exit(1)


if __name__ == "__main__":
    main()
