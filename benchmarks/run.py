"""Benchmark orchestrator — one harness per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--bench-json DIR]

Prints ``name,us_per_call,derived`` CSV rows per harness plus per-figure
summaries; raw payloads land in experiments/benchmarks/*.json.  With
``--bench-json DIR`` each executed harness additionally drops a
``BENCH_<name>.json`` artifact into DIR: its headline scalars (the
top-level numbers a trajectory plot wants) plus the harness wall time —
the machine-readable form CI uploads.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def headline(payload) -> dict:
    """The top-level scalars of a harness payload (trajectory material)."""
    if not isinstance(payload, dict):
        return {}
    return {
        k: v
        for k, v in payload.items()
        if isinstance(v, (bool, int, float)) or (isinstance(v, str) and len(v) <= 64)
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="skip the slowest figures")
    ap.add_argument("--only", default=None, help="comma-separated figure list")
    ap.add_argument(
        "--bench-json",
        default=None,
        metavar="DIR",
        help="also write BENCH_<figure>.json headline artifacts into DIR",
    )
    args = ap.parse_args()

    from . import fig4_convergence, fig5_quality, fig6_seed, fig7_heuristics, fig9_latency
    from . import chaos_sweep, fig9_interconnect, kernels_bench, power_sweep, roofline
    from . import selfbench, serve_sim

    figures = {
        "fig4": fig4_convergence.run,
        "fig5": fig5_quality.run,
        "fig6": fig6_seed.run,
        "fig7": fig7_heuristics.run,
        "fig9": fig9_latency.run,
        "fig9_interconnect": lambda: fig9_interconnect.run(quick=args.quick),
        "fig9_adaptive": lambda: fig9_interconnect.run_adaptive(quick=args.quick),
        "kernels": kernels_bench.run,
        "roofline": roofline.run,
        "serve_sim": lambda: serve_sim.run(quick=args.quick),
        "multitenant_drift": lambda: serve_sim.run_multitenant_drift(quick=args.quick),
        "selfbench": lambda: selfbench.run(quick=args.quick),
        "power_sweep": lambda: power_sweep.run(quick=args.quick),
        "chaos_sweep": lambda: chaos_sweep.run(quick=args.quick),
    }
    if args.only:
        keep = set(args.only.split(","))
        figures = {k: v for k, v in figures.items() if k in keep}
    if args.quick:
        figures.pop("fig6", None)

    bench_dir = Path(args.bench_json) if args.bench_json else None
    if bench_dir is not None:
        bench_dir.mkdir(parents=True, exist_ok=True)

    rows = []
    for name, fn in figures.items():
        t0 = time.perf_counter()
        print(f"[bench] {name} ...", flush=True)
        try:
            payload = fn()
            dt = (time.perf_counter() - t0) * 1e6
            rows.append(f"{name},{dt:.0f},ok")
            if bench_dir is not None:
                artifact = {
                    "figure": name,
                    "wall_us": dt,
                    "headline": headline(payload),
                }
                (bench_dir / f"BENCH_{name}.json").write_text(
                    json.dumps(artifact, indent=2) + "\n"
                )
        except Exception as e:  # keep the harness going; report at the end
            dt = (time.perf_counter() - t0) * 1e6
            rows.append(f"{name},{dt:.0f},FAILED:{type(e).__name__}:{e}")
            import traceback

            traceback.print_exc()
    print("\nname,us_per_call,derived")
    for r in rows:
        print(r)
    if any("FAILED" in r for r in rows):
        sys.exit(1)


if __name__ == "__main__":
    main()
