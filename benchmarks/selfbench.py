"""Self-benchmark: how fast is the simulator itself?

    PYTHONPATH=src python -m benchmarks.selfbench [--quick]

Every other harness measures the *reproduced system* (throughput, SLO rates,
convergence); this one measures the *simulator* — the only perf trajectory
worth tracking for the repo's own hot paths:

  * **event_loop** — raw :class:`~repro.serve.simulator.EventLoop` dispatch
    rate (a no-op owner): the ceiling every scenario runs under, measured
    bare, with a live :class:`~repro.telemetry.Telemetry` session (to pin
    the instrumentation overhead ratio), and against the legacy
    :class:`~repro.serve.simulator.HeapEventLoop` reference engine (to pin
    ``speedup_vs_legacy``, the drain-engine dividend).
  * **serve** — a real single-tenant :class:`ServingSimulator` scenario
    (SynthNet, Poisson traffic), simulated-events/sec bare vs telemetry-on
    vs legacy-heap; the simulated :class:`SimResult` is asserted identical
    across all three arms every run, so the speedup numbers can never come
    from a divergent simulation.  The telemetry arm's wall time also comes
    from the session's own ``timed("event_loop.run")`` profiling hook,
    closing the loop on the profiler itself.
  * **cotenant** — one tenant per EP on the paper's 8-EP platform, all on
    one shared clock: the peak-tenant-count stress shape, reported as
    simulated-events/sec at that width.

The headline payload lands in ``BENCH_selfbench.json`` at the repo root
(committed, so the trajectory is visible in history) and the telemetry arm's
Chrome trace in ``experiments/telemetry/selfbench_trace.json``.  Wall-clock
numbers vary run to run, machine to machine; the *simulated* side of every
arm is deterministic.
"""

from __future__ import annotations

import argparse
import gc
import json
import math
import time
from pathlib import Path

from repro.core import DatabaseEvaluator, Trace, paper_platform, weights
from repro.core.heuristics import run_shisha
from repro.models.cnn import network_layers
from repro.serve import PoissonTraffic, ServingSimulator, Tenant, co_serve
from repro.serve.simulator import EventLoop, HeapEventLoop
from repro.telemetry import Telemetry

ROOT = Path(__file__).resolve().parent.parent


class _NullOwner:
    """Dispatch target that does nothing: isolates the loop's own cost."""

    def _dispatch(self, t, kind, payload):
        pass


#: timed repetitions per arm; the *fastest* of each is reported.  Arms are
#: warmed (one untimed run each) and *interleaved* bare/instrumented, so
#: machine-load drift between arms cancels instead of biasing the ratio —
#: single-shot sequential arms made it swing 0.8x-1.6x run to run.  Each
#: timed region is also preceded by a ``gc.collect()``: a generational
#: collection over the previous arm's dead event tuples landing mid-run
#: is the other way the overhead ratio inverted below 1.0.
BEST_OF = 3

#: the raw dispatch arms are ~100x cheaper than a serve run, so they take
#: more repetitions — the min is what survives a noisy shared runner
LOOP_BEST_OF = 7


def bench_event_loop(n_events: int) -> tuple[dict, dict, dict]:
    """Bare, instrumented, and legacy-heap dispatch arms, interleaved best-of."""
    owner = _NullOwner()
    times = [i * 1e-6 for i in range(n_events)]
    payloads = [None] * n_events

    def arm(cls, telemetry: Telemetry | None = None) -> tuple[float, int]:
        loop = cls(telemetry)
        loop.push_batch(times, 0, owner, payloads)
        gc.collect()  # pay prior arms' garbage before the timer starts
        t0 = time.perf_counter()
        loop.run(math.inf)
        return time.perf_counter() - t0, loop.n_dispatched

    # warmup (untimed), then interleaved best-of so load drift cancels
    arm(EventLoop), arm(EventLoop, Telemetry()), arm(HeapEventLoop)
    bare = tel = legacy = (math.inf, 0)
    for _ in range(LOOP_BEST_OF):
        bare = min(bare, arm(EventLoop))
        tel = min(tel, arm(EventLoop, Telemetry()))
        legacy = min(legacy, arm(HeapEventLoop))

    def payload(wall: float, dispatched: int) -> dict:
        return {
            "n_events": dispatched,
            "wall_s": wall,
            "events_per_s": dispatched / wall if wall > 0 else float("inf"),
        }

    return payload(*bare), payload(*tel), payload(*legacy)


def bench_serve(horizon: float) -> tuple[dict, dict, dict, Telemetry]:
    """Bare, instrumented, and legacy-heap serve arms, interleaved best-of.

    A fresh simulator (and, on the instrumented arm, a fresh telemetry
    session) per repetition, so every timed run replays the same seeded
    scenario from scratch.  The simulated :class:`SimResult` is asserted
    identical across every arm and repetition — the legacy
    :class:`HeapEventLoop` arm doubles as a live equivalence check on the
    drain engine.  Returns the instrumented arm's last session for the
    trace export.
    """
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    ev = DatabaseEvaluator(plat, layers)
    sh = run_shisha(weights(layers), Trace(ev), "H3")
    conf, cap = sh.result.best_conf, sh.result.best_throughput
    arrivals = PoissonTraffic(rate=0.6 * cap, seed=7).arrivals(horizon)

    def arm(instrumented: bool = False, legacy: bool = False):
        tl = Telemetry() if instrumented else None
        loop = HeapEventLoop() if legacy else None
        sim = ServingSimulator(ev, conf, slo=3.0, loop=loop, telemetry=tl)
        gc.collect()  # pay prior arms' garbage before the timer starts
        t0 = time.perf_counter()
        res = sim.run(arrivals, horizon)
        return time.perf_counter() - t0, sim, res, tl

    arm(), arm(instrumented=True), arm(legacy=True)  # warmup, untimed
    bare_wall = tel_wall = legacy_wall = math.inf
    sim = res = tl = None
    legacy_events = 0
    for _ in range(BEST_OF):
        w, s, r, _ = arm()
        if res is not None and r != res:
            raise AssertionError("serve arms diverged: bare vs bare repeat")
        if w < bare_wall:
            bare_wall, sim, res = w, s, r
        w2, _, r2, t2 = arm(instrumented=True)
        tl = t2
        tel_wall = min(tel_wall, w2)
        w3, s3, r3, _ = arm(legacy=True)
        legacy_wall = min(legacy_wall, w3)
        legacy_events = s3.loop.n_dispatched
        if r2 != res or r3 != res:
            raise AssertionError("serve arms diverged: drain vs legacy-heap")

    def payload(wall: float, sim_events: int) -> dict:
        return {
            "horizon_s": horizon,
            "n_completed": res.n_completed,
            "sim_events": sim_events,
            "wall_s": wall,
            "events_per_s": sim_events / wall if wall > 0 else float("inf"),
        }

    n_ev = sim.loop.n_dispatched
    return (
        payload(bare_wall, n_ev),
        payload(tel_wall, n_ev),
        payload(legacy_wall, legacy_events),
        tl,
    )


def bench_cotenant(horizon: float, n_tenants: int) -> dict:
    """One tenant per EP — the widest shape the partitioner admits."""
    plat = paper_platform(8)
    layers = tuple(network_layers("alexnet"))
    cap_ev = DatabaseEvaluator(plat, layers)
    cap = run_shisha(weights(layers), Trace(cap_ev), "H3").result.best_throughput
    tenants = [
        Tenant(
            name=f"t{i}",
            layers=layers,
            traffic=PoissonTraffic(rate=0.3 * cap / n_tenants, seed=100 + i),
            slo=5.0,
        )
        for i in range(n_tenants)
    ]
    tl = Telemetry()
    t0 = time.perf_counter()
    res = co_serve(plat, tenants, horizon=horizon, elastic=False, telemetry=tl)
    wall = time.perf_counter() - t0
    loop_profile = tl.profile_snapshot().get("event_loop.run", {})
    return {
        "horizon_s": horizon,
        "peak_tenants": n_tenants,
        "n_completed": sum(r.sim.n_completed for r in res.results),
        "wall_s": wall,
        "loop_wall_s": loop_profile.get("wall_s"),
        "completed_per_s": (
            sum(r.sim.n_completed for r in res.results) / wall if wall > 0 else 0.0
        ),
    }


def run(verbose: bool = True, quick: bool = False) -> dict:
    n_events = 50_000 if quick else 200_000
    horizon = 60.0 if quick else 200.0
    co_horizon = 20.0 if quick else 60.0
    n_tenants = 4 if quick else 8

    base_loop, tel_loop, legacy_loop = bench_event_loop(n_events)
    base_serve, tel_serve, legacy_serve, tl = bench_serve(horizon)
    cotenant = bench_cotenant(co_horizon, n_tenants)

    trace_path = ROOT / "experiments" / "telemetry" / "selfbench_trace.json"
    tl.export_chrome_trace(trace_path)

    def ratio(num: dict, den: dict) -> float:
        return (
            num["events_per_s"] / den["events_per_s"]
            if den["events_per_s"] > 0
            else float("inf")
        )

    payload = {
        "bench": "selfbench",
        "event_loop": {
            "baseline": base_loop,
            "telemetry": tel_loop,
            "legacy_heap": legacy_loop,
            "overhead_ratio": ratio(base_loop, tel_loop),
            "speedup_vs_legacy": ratio(base_loop, legacy_loop),
        },
        "serve": {
            "baseline": base_serve,
            "telemetry": tel_serve,
            "legacy_heap": legacy_serve,
            "overhead_ratio": ratio(base_serve, tel_serve),
            "speedup_vs_legacy": ratio(base_serve, legacy_serve),
            "profile": tl.profile_snapshot(),
        },
        "cotenant": cotenant,
        "events_per_s": base_serve["events_per_s"],
        "chrome_trace": str(trace_path.relative_to(ROOT)),
    }
    out = ROOT / "BENCH_selfbench.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    if verbose:
        print(
            f"  selfbench event_loop: {base_loop['events_per_s']:,.0f} ev/s bare, "
            f"{tel_loop['events_per_s']:,.0f} ev/s instrumented "
            f"({payload['event_loop']['overhead_ratio']:.2f}x overhead), "
            f"{legacy_loop['events_per_s']:,.0f} ev/s legacy heap "
            f"({payload['event_loop']['speedup_vs_legacy']:.2f}x speedup)"
        )
        print(
            f"  selfbench serve: {base_serve['events_per_s']:,.0f} sim-events/s "
            f"({base_serve['sim_events']} events over {horizon:.0f}s simulated), "
            f"{payload['serve']['speedup_vs_legacy']:.2f}x vs legacy heap"
        )
        print(
            f"  selfbench cotenant: {cotenant['peak_tenants']} tenants, "
            f"{cotenant['n_completed']} completions in {cotenant['wall_s']:.2f}s wall"
        )
        print(f"  selfbench payload -> {out.name}, trace -> {trace_path}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="smaller event counts/horizons")
    args = ap.parse_args()
    run(verbose=True, quick=args.quick)


if __name__ == "__main__":
    main()
