"""Self-benchmark: how fast is the simulator itself?

    PYTHONPATH=src python -m benchmarks.selfbench [--quick]

Every other harness measures the *reproduced system* (throughput, SLO rates,
convergence); this one measures the *simulator* — the only perf trajectory
worth tracking for the repo's own hot paths:

  * **event_loop** — raw :class:`~repro.serve.simulator.EventLoop` dispatch
    rate (a no-op owner, heap-only): the ceiling every scenario runs under,
    measured bare and with a live :class:`~repro.telemetry.Telemetry`
    session to pin the instrumentation overhead ratio.
  * **serve** — a real single-tenant :class:`ServingSimulator` scenario
    (SynthNet, Poisson traffic), simulated-events/sec bare vs telemetry-on;
    the telemetry arm's wall time also comes from the session's own
    ``timed("event_loop.run")`` profiling hook, closing the loop on the
    profiler itself.
  * **cotenant** — one tenant per EP on the paper's 8-EP platform, all on
    one shared clock: the peak-tenant-count stress shape, reported as
    simulated-events/sec at that width.

The headline payload lands in ``BENCH_selfbench.json`` at the repo root
(committed, so the trajectory is visible in history) and the telemetry arm's
Chrome trace in ``experiments/telemetry/selfbench_trace.json``.  Wall-clock
numbers vary run to run, machine to machine; the *simulated* side of every
arm is deterministic.
"""

from __future__ import annotations

import argparse
import json
import math
import time
from pathlib import Path

from repro.core import DatabaseEvaluator, Trace, paper_platform, weights
from repro.core.heuristics import run_shisha
from repro.models.cnn import network_layers
from repro.serve import PoissonTraffic, ServingSimulator, Tenant, co_serve
from repro.serve.simulator import EventLoop
from repro.telemetry import Telemetry

ROOT = Path(__file__).resolve().parent.parent


class _NullOwner:
    """Dispatch target that does nothing: isolates the loop's own cost."""

    def _dispatch(self, t, kind, payload):
        pass


#: timed repetitions per arm; the *fastest* of each is reported.  Arms are
#: warmed (one untimed run each) and *interleaved* bare/instrumented, so
#: machine-load drift between arms cancels instead of biasing the ratio —
#: single-shot sequential arms made it swing 0.8x-1.6x run to run
BEST_OF = 3


def bench_event_loop(n_events: int) -> tuple[dict, dict]:
    """Bare and instrumented dispatch arms, interleaved best-of."""
    owner = _NullOwner()

    def arm(telemetry: Telemetry | None) -> tuple[float, int]:
        loop = EventLoop(telemetry)
        for i in range(n_events):
            loop.push(i * 1e-6, 0, owner, None)
        t0 = time.perf_counter()
        loop.run(math.inf)
        return time.perf_counter() - t0, loop.n_dispatched

    arm(None), arm(Telemetry())  # warmup, untimed
    bare = tel = (math.inf, 0)
    for _ in range(BEST_OF):
        bare = min(bare, arm(None))
        tel = min(tel, arm(Telemetry()))

    def payload(wall: float, dispatched: int) -> dict:
        return {
            "n_events": dispatched,
            "wall_s": wall,
            "events_per_s": dispatched / wall if wall > 0 else float("inf"),
        }

    return payload(*bare), payload(*tel)


def bench_serve(horizon: float) -> tuple[dict, dict, Telemetry]:
    """Bare and instrumented serve arms, warmed and interleaved best-of.

    A fresh simulator (and, on the instrumented arm, a fresh telemetry
    session) per repetition, so every timed run replays the same seeded
    scenario from scratch; the simulated side is identical across all of
    them.  Returns the instrumented arm's last session for the trace
    export.
    """
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    ev = DatabaseEvaluator(plat, layers)
    sh = run_shisha(weights(layers), Trace(ev), "H3")
    conf, cap = sh.result.best_conf, sh.result.best_throughput
    arrivals = PoissonTraffic(rate=0.6 * cap, seed=7).arrivals(horizon)

    def arm(instrumented: bool):
        tl = Telemetry() if instrumented else None
        sim = ServingSimulator(ev, conf, slo=3.0, telemetry=tl)
        t0 = time.perf_counter()
        res = sim.run(arrivals, horizon)
        return time.perf_counter() - t0, sim, res, tl

    arm(False), arm(True)  # warmup, untimed
    bare_wall = tel_wall = math.inf
    sim = res = tl = None
    for _ in range(BEST_OF):
        w, s, r, _ = arm(False)
        if w < bare_wall:
            bare_wall, sim, res = w, s, r
        w2, _, _, t2 = arm(True)
        tl = t2
        tel_wall = min(tel_wall, w2)

    def payload(wall: float) -> dict:
        return {
            "horizon_s": horizon,
            "n_completed": res.n_completed,
            "sim_events": sim.loop.n_dispatched,
            "wall_s": wall,
            "events_per_s": (
                sim.loop.n_dispatched / wall if wall > 0 else float("inf")
            ),
        }

    return payload(bare_wall), payload(tel_wall), tl


def bench_cotenant(horizon: float, n_tenants: int) -> dict:
    """One tenant per EP — the widest shape the partitioner admits."""
    plat = paper_platform(8)
    layers = tuple(network_layers("alexnet"))
    cap_ev = DatabaseEvaluator(plat, layers)
    cap = run_shisha(weights(layers), Trace(cap_ev), "H3").result.best_throughput
    tenants = [
        Tenant(
            name=f"t{i}",
            layers=layers,
            traffic=PoissonTraffic(rate=0.3 * cap / n_tenants, seed=100 + i),
            slo=5.0,
        )
        for i in range(n_tenants)
    ]
    tl = Telemetry()
    t0 = time.perf_counter()
    res = co_serve(plat, tenants, horizon=horizon, elastic=False, telemetry=tl)
    wall = time.perf_counter() - t0
    loop_profile = tl.profile_snapshot().get("event_loop.run", {})
    return {
        "horizon_s": horizon,
        "peak_tenants": n_tenants,
        "n_completed": sum(r.sim.n_completed for r in res.results),
        "wall_s": wall,
        "loop_wall_s": loop_profile.get("wall_s"),
        "completed_per_s": (
            sum(r.sim.n_completed for r in res.results) / wall if wall > 0 else 0.0
        ),
    }


def run(verbose: bool = True, quick: bool = False) -> dict:
    n_events = 50_000 if quick else 200_000
    horizon = 60.0 if quick else 200.0
    co_horizon = 20.0 if quick else 60.0
    n_tenants = 4 if quick else 8

    base_loop, tel_loop = bench_event_loop(n_events)
    base_serve, tel_serve, tl = bench_serve(horizon)
    cotenant = bench_cotenant(co_horizon, n_tenants)

    trace_path = ROOT / "experiments" / "telemetry" / "selfbench_trace.json"
    tl.export_chrome_trace(trace_path)

    payload = {
        "bench": "selfbench",
        "event_loop": {
            "baseline": base_loop,
            "telemetry": tel_loop,
            "overhead_ratio": (
                base_loop["events_per_s"] / tel_loop["events_per_s"]
                if tel_loop["events_per_s"] > 0
                else float("inf")
            ),
        },
        "serve": {
            "baseline": base_serve,
            "telemetry": tel_serve,
            "overhead_ratio": (
                base_serve["events_per_s"] / tel_serve["events_per_s"]
                if tel_serve["events_per_s"] > 0
                else float("inf")
            ),
            "profile": tl.profile_snapshot(),
        },
        "cotenant": cotenant,
        "events_per_s": base_serve["events_per_s"],
        "chrome_trace": str(trace_path.relative_to(ROOT)),
    }
    out = ROOT / "BENCH_selfbench.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    if verbose:
        print(
            f"  selfbench event_loop: {base_loop['events_per_s']:,.0f} ev/s bare, "
            f"{tel_loop['events_per_s']:,.0f} ev/s instrumented "
            f"({payload['event_loop']['overhead_ratio']:.2f}x)"
        )
        print(
            f"  selfbench serve: {base_serve['events_per_s']:,.0f} sim-events/s "
            f"({base_serve['sim_events']} events over {horizon:.0f}s simulated)"
        )
        print(
            f"  selfbench cotenant: {cotenant['peak_tenants']} tenants, "
            f"{cotenant['n_completed']} completions in {cotenant['wall_s']:.2f}s wall"
        )
        print(f"  selfbench payload -> {out.name}, trace -> {trace_path}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="smaller event counts/horizons")
    args = ap.parse_args()
    run(verbose=True, quick=args.quick)


if __name__ == "__main__":
    main()
