"""Fig. 4 — convergence race: Shisha vs SA / HC / RW / ES / Pipe-Search.

SynthNet on 8 EPs, identical simulated cost accounting for every explorer.
SA_s / HC_s start from the Shisha seed (the paper's fairness variant);
ES / PS pay the up-front configuration-database generation cost.

Reported: convergence curves, time-to-converge, and the speedup of Shisha
over each baseline (paper claims ~35× on average).
"""

from __future__ import annotations

import time

from repro.core import (
    exhaustive_search,
    generate_seed,
    hill_climbing,
    pipe_search,
    random_walk,
    run_shisha,
    simulated_annealing,
)

from .common import db_cost, fresh_trace, save, setup

BUDGET_S = 3000.0
MAX_DEPTH = 4  # ES/PS database is generated up to this depth (paper's limit)


def time_to_converge(trace, final_frac: float = 0.99) -> float:
    """Simulated wall time when best-so-far first reaches 99% of its final."""
    curve = trace.convergence_curve()
    if not curve:
        return float("inf")
    final = curve[-1][1]
    for t, tp in curve:
        if tp >= final_frac * final:
            return t
    return curve[-1][0]


def run(verbose: bool = True) -> dict:
    layers, ws, plat = setup("synthnet", 8)
    n = len(ws)
    results = {}

    t0 = time.perf_counter()
    sh = run_shisha(ws, fresh_trace(plat, layers), "H3")
    wall_real = time.perf_counter() - t0
    results["Shisha"] = {
        "trace": sh.trace,
        "best": sh.result.best_throughput,
        "real_s": wall_real,
    }

    seed_conf = generate_seed(ws, plat, choice="rank_w").conf
    setup_db = db_cost(n, 8, MAX_DEPTH)

    runs = {
        "HC": lambda tr: hill_climbing(tr, n, BUDGET_S, seed=0),
        "HC_s": lambda tr: hill_climbing(tr, n, BUDGET_S, start=seed_conf, seed=0),
        "SA": lambda tr: simulated_annealing(tr, n, BUDGET_S, seed=0),
        "SA_s": lambda tr: simulated_annealing(tr, n, BUDGET_S, start=seed_conf, seed=0),
        "RW": lambda tr: random_walk(tr, n, BUDGET_S, seed=0),
    }
    for name, fn in runs.items():
        tr = fresh_trace(plat, layers)
        t0 = time.perf_counter()
        res = fn(tr)
        results[name] = {"trace": tr, "best": res.best_throughput, "real_s": time.perf_counter() - t0}

    tr = fresh_trace(plat, layers, setup_cost=setup_db)
    res = exhaustive_search(tr, n, budget_s=setup_db + BUDGET_S, max_depth=3)
    results["ES"] = {"trace": tr, "best": res.best_throughput, "real_s": 0.0}

    tr = fresh_trace(plat, layers, setup_cost=setup_db)
    res = pipe_search(tr, ws, budget_s=setup_db + BUDGET_S, max_depth=MAX_DEPTH)
    results["PS"] = {"trace": tr, "best": res.best_throughput, "real_s": 0.0}

    sh_t = time_to_converge(results["Shisha"]["trace"])
    payload = {"net": "synthnet", "n_eps": 8, "algorithms": {}}
    speedups = []
    for name, r in results.items():
        tc = time_to_converge(r["trace"])
        sp = tc / sh_t if name != "Shisha" else 1.0
        if name != "Shisha":
            speedups.append(sp)
        payload["algorithms"][name] = {
            "best_throughput": r["best"],
            "n_trials": r["trace"].n_trials,
            "time_to_converge_s": tc,
            "speedup_of_shisha": sp,
            "curve": r["trace"].convergence_curve()[:200],
        }
        if verbose:
            print(
                f"  fig4 {name:7s} best={r['best']:.4f} trials={r['trace'].n_trials:6d} "
                f"t_conv={tc:10.2f}s shisha_speedup={sp:8.1f}x"
            )
    payload["mean_speedup"] = sum(speedups) / len(speedups)
    if verbose:
        print(f"  fig4 mean convergence speedup of Shisha: {payload['mean_speedup']:.1f}x (paper: ~35x)")
    save("fig4_convergence", payload)
    return payload


if __name__ == "__main__":
    run()
