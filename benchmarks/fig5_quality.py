"""Fig. 5 — solution quality normalized to Exhaustive Search.

ResNet50 / YOLOv3 / SynthNet on 4 EPs (the paper's setting: ES is only
tractable there).  Also reports the fraction of the design space each
algorithm explored (paper: Shisha ~0.1% on the big CNNs, ~2.5% SynthNet).
"""

from __future__ import annotations

import itertools

import numpy as np

from repro.core import (
    compositions,
    hill_climbing,
    pipe_search,
    random_walk,
    run_shisha,
    simulated_annealing,
    space_size,
)

from .common import db_cost, fresh_trace, save, setup

MAX_DEPTH = 4
BUDGET_S = 2000.0


def exact_es(ev, n_layers: int, n_eps: int, max_depth: int) -> tuple[float, int]:
    """Vectorized exhaustive search over the FULL space (homogeneous links).

    Uses the same per-(layer, EP) database the explorers query, via prefix
    sums — exact, but ~1000x faster than config-at-a-time evaluation, so
    the paper's ES-as-gold reference is the true optimum, not a depth-capped
    stand-in.
    """
    T = np.array([[ev.layer_time_by_index(i, e) for e in range(n_eps)] for i in range(n_layers)])
    P = np.vstack([np.zeros((1, n_eps)), np.cumsum(T, axis=0)])  # [L+1, E]
    ep0 = ev.platform.eps[0]
    act = np.array([l.act_bytes for l in ev.layers])
    link = act / ep0.link_bw + ep0.link_latency  # homogeneous links
    best, count = -np.inf, 0
    for d in range(1, min(max_depth, n_eps, n_layers) + 1):
        perms = np.array(list(itertools.permutations(range(n_eps), d)))  # [P, d]
        for comp in compositions(n_layers, d):
            bounds = np.cumsum((0,) + comp)
            S = P[bounds[1:]] - P[bounds[:-1]]  # [d, E] stage times per EP
            beats = S[np.arange(d)[None, :], perms]  # [P, d]
            if d > 1:
                beats = beats + np.concatenate([link[bounds[1:-1] - 1], [0.0]])[None, :]
            tp = 1.0 / beats.max(axis=1)
            m = tp.max()
            count += len(perms)
            if m > best:
                best = m
    return float(best), count


def run(verbose: bool = True, nets=("synthnet", "resnet50", "yolov3")) -> dict:
    payload = {}
    for net in nets:
        layers, ws, plat = setup(net, 4)
        n = len(ws)
        tr_es = fresh_trace(plat, layers)
        es_best, es_count = exact_es(tr_es.evaluator, n, 4, MAX_DEPTH)
        space = space_size(n, 4, MAX_DEPTH)

        row = {"ES": {"norm": 1.0, "explored_frac": es_count / space}}
        sh = run_shisha(ws, fresh_trace(plat, layers), "H3")
        row["Shisha"] = {
            "norm": sh.result.best_throughput / es_best,
            "explored_frac": sh.trace.n_trials / space,
        }
        for name, fn in {
            "HC": lambda tr: hill_climbing(tr, n, BUDGET_S, seed=1),
            "SA": lambda tr: simulated_annealing(tr, n, BUDGET_S, seed=1),
            "RW": lambda tr: random_walk(tr, n, BUDGET_S, seed=1),
            "PS": lambda tr: pipe_search(tr, ws, BUDGET_S, max_depth=3),
        }.items():
            tr = fresh_trace(plat, layers)
            res = fn(tr)
            row[name] = {
                "norm": res.best_throughput / es_best,
                "explored_frac": tr.n_trials / space,
            }
        payload[net] = row
        if verbose:
            cells = " ".join(f"{k}={v['norm']:.3f}" for k, v in row.items())
            print(f"  fig5 {net:9s} |space|={space:.2e} {cells}")
            print(f"  fig5 {net:9s} shisha explored {row['Shisha']['explored_frac']*100:.4f}% of space")
    save("fig5_quality", payload)
    return payload


if __name__ == "__main__":
    run()
