"""Heterogeneity model for the pipeline runtime.

On real chiplet hardware the FEP/SEP speed difference is physical.  On this
(homogeneous, CPU) box we keep the paper's semantics by attaching a derate
factor to each EP: measured per-layer times are scaled by the derate of the
EP a stage is mapped to.  The derates come from the same Platform
description the scheduler sees, so the online-tuning loop closes end to
end: measure -> scale -> Alg. 2 move -> re-measure.
"""

from __future__ import annotations

import dataclasses

from ..core.platform import EP, Platform, tpu_slice_ep


@dataclasses.dataclass(frozen=True)
class EPDerates:
    """Relative speed of each EP (1.0 = fastest)."""

    factors: tuple[float, ...]

    @classmethod
    def from_platform(cls, platform: Platform) -> "EPDerates":
        best = max(ep.flops for ep in platform.eps)
        return cls(tuple(best / ep.flops for ep in platform.eps))

    def scale(self, ep_idx: int, t: float) -> float:
        return t * self.factors[ep_idx]

    def compose(self, other: "EPDerates") -> "EPDerates":
        """Elementwise product of two derate vectors.

        The serving simulator uses this to merge independent derate
        sources — scripted platform faults and thermal throttling — into
        the one vector the drift detector observes.
        """
        if len(other.factors) != len(self.factors):
            raise ValueError(
                f"cannot compose derates over {len(self.factors)} and "
                f"{len(other.factors)} EPs"
            )
        return EPDerates(
            tuple(a * b for a, b in zip(self.factors, other.factors))
        )


def tpu_platform_from_mesh(n_stages: int, chips_per_stage: int = 8, slow_fraction: float = 0.5) -> Platform:
    """A Platform whose EPs are slices of a TPU mesh (DESIGN.md §2 mapping)."""
    n_slow = int(n_stages * slow_fraction)
    eps = [
        tpu_slice_ep(f"slice{i}", chips_per_stage, fast=(i >= n_slow))
        for i in range(n_stages)
    ]
    # fast first, as H_e expects descending performance
    eps.sort(key=lambda e: e.perf_class)
    return Platform(name=f"tpu-pipeline-{n_stages}", eps=tuple(eps))
