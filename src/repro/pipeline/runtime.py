"""Shisha-scheduled pipeline runtime.

The paper's deployment story, on JAX: a chain-structured network (the
paper's CNNs, or any LM block stack) is split into N contiguous stages by
a Shisha ``PipelineConfig``; each stage is pinned to one slice of the mesh
("stage" axis = the chiplet axis) and microbatches stream through the
stages with ``jax.lax.ppermute`` — GPipe-style fill/steady/drain, built
with shard_map so every transfer is an explicit neighbour permute (the
paper's inter-chiplet link).

Two oracles close the online-tuning loop:

  * :class:`MeasuringEvaluator` — times each (layer, EP) pair on the real
    device (jitted, synced) and scales by the EP derate (hetero.py).  This
    is the paper's "runtime performance value" — Algorithm 2 consumes it
    exactly like the gem5 database.
  * :func:`pipeline_throughput` — runs the actual pipelined computation
    and measures end-to-end images/s, used to validate that the schedule
    Shisha picked is the schedule that actually runs fastest.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core.config import PipelineConfig
from ..core.cost_model import Layer
from ..core.evaluator import AnalyticEvaluator
from ..core.platform import Platform
from .hetero import EPDerates

# ---------------------------------------------------------------------------
# Measured oracle
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MeasuringEvaluator(AnalyticEvaluator):
    """`execute(conf)` backed by real measured per-layer times.

    Each layer's apply function is jitted and timed once per EP class
    (block_until_ready, best of ``reps``); stage times are sums of measured
    layer times scaled by the stage EP's derate — the live analogue of the
    paper's gem5 database.  Inherits stage_times/throughput plumbing (link
    cost model included) from AnalyticEvaluator.
    """

    layer_fns: Sequence[Callable] | None = None
    layer_args: Sequence[tuple] | None = None
    reps: int = 3

    def __post_init__(self):
        self.derates = EPDerates.from_platform(self.platform)
        self._measured: list[float] = []
        for fn, args in zip(self.layer_fns, self.layer_args):
            jf = jax.jit(fn)
            out = jf(*args)
            jax.block_until_ready(out)  # compile + warm
            best = np.inf
            for _ in range(self.reps):
                t0 = time.perf_counter()
                jax.block_until_ready(jf(*args))
                best = min(best, time.perf_counter() - t0)
            self._measured.append(best)

    def layer_time(self, layer: Layer, ep_idx: int) -> float:  # type: ignore[override]
        li = list(self.layers).index(layer)
        return self.derates.scale(ep_idx, self._measured[li]) + self.layer_overhead

    def stage_times(self, conf: PipelineConfig) -> list[float]:
        times = []
        for s, (a, b) in enumerate(conf.boundaries()):
            ep_idx = conf.eps[s]
            t = sum(self.derates.scale(ep_idx, self._measured[i]) + self.layer_overhead for i in range(a, b))
            if s < conf.depth - 1:
                ep = self.platform.eps[ep_idx]
                nxt = self.platform.eps[conf.eps[s + 1]]
                t += self.layers[b - 1].act_bytes / min(ep.link_bw, nxt.link_bw) + max(
                    ep.link_latency, nxt.link_latency
                )
            times.append(t)
        return times


# ---------------------------------------------------------------------------
# shard_map GPipe pipeline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PipelineRunner:
    """Runs a layer chain as an N-stage microbatched pipeline.

    ``apply_layer(i, x)`` must map a canonical activation shape to itself
    (the CNN model resizes internally; LM blocks are shape-preserving), so
    stages of different depth stay branch-compatible under lax.switch.
    """

    mesh: Mesh
    conf: PipelineConfig
    apply_layer: Callable[[int, jax.Array], jax.Array]
    n_micro: int = 8

    def __post_init__(self):
        if self.mesh.shape["stage"] != self.conf.depth:
            raise ValueError(
                f"mesh stage axis {self.mesh.shape['stage']} != pipeline depth {self.conf.depth}"
            )
        bounds = self.conf.boundaries()

        def make_stage(a, b):
            def stage_fn(x):
                for i in range(a, b):
                    x = self.apply_layer(i, x)
                return x
            return stage_fn

        self._stage_fns = [make_stage(a, b) for a, b in bounds]

    def _pipelined(self, micro: jax.Array) -> jax.Array:
        """micro: [n_micro, ...activation] replicated. Returns outputs."""
        n_stages = self.conf.depth
        n_micro = self.n_micro
        mesh = self.mesh
        stage_fns = self._stage_fns
        ticks = n_micro + n_stages - 1
        fwd = [(i, i + 1) for i in range(n_stages - 1)]

        def local(micro_loc):
            sid = jax.lax.axis_index("stage")
            act_shape = micro_loc.shape[1:]
            buf = jnp.zeros(act_shape, micro_loc.dtype)
            outs = jnp.zeros((n_micro,) + act_shape, micro_loc.dtype)

            def tick(carry, t):
                buf, outs = carry
                # stage 0 ingests microbatch t (when in range)
                take = jnp.clip(t, 0, n_micro - 1)
                inject = micro_loc[take]
                x = jnp.where(sid == 0, jnp.where(t < n_micro, inject, buf * 0), buf)
                y = jax.lax.switch(sid, stage_fns, x)
                # last stage emits microbatch t - (n_stages - 1)
                emit_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
                valid = (t - (n_stages - 1) >= 0) & (sid == n_stages - 1)
                outs = jax.lax.dynamic_update_index_in_dim(
                    outs, jnp.where(valid, y, outs[emit_idx]), emit_idx, 0
                )
                # ship activations one stage forward
                buf = jax.lax.ppermute(y, "stage", fwd)
                return (buf, outs), None

            (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
            # bring results from the last stage to every shard (replicated out)
            outs = jax.lax.psum(
                jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), "stage"
            )
            return outs

        return shard_map(
            local,
            mesh=mesh,
            in_specs=P(),  # microbatches replicated; stages own the compute
            out_specs=P(),
            check_vma=False,
        )(micro)

    def run(self, micro: jax.Array) -> jax.Array:
        """micro: [n_micro, ...]. Returns [n_micro, ...] final activations."""
        return jax.jit(self._pipelined)(micro)


def pipeline_throughput(runner: PipelineRunner, micro: jax.Array, reps: int = 3) -> float:
    """Measured end-to-end microbatches/second of the real pipeline."""
    fn = jax.jit(runner._pipelined)
    jax.block_until_ready(fn(micro))
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(micro))
        best = min(best, time.perf_counter() - t0)
    return runner.n_micro / best
