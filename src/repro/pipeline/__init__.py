"""Shisha-scheduled pipeline runtime (shard_map + ppermute micro-batching)."""

from .hetero import EPDerates, tpu_platform_from_mesh
from .runtime import (
    MeasuringEvaluator,
    PipelineRunner,
    pipeline_throughput,
)

__all__ = [
    "EPDerates",
    "MeasuringEvaluator",
    "PipelineRunner",
    "pipeline_throughput",
    "tpu_platform_from_mesh",
]
