"""Chaos layer: seeded stochastic fault injection and resilience policy.

Off by default; attach a :class:`FaultModel` with
``Platform.with_faults(...)`` (or the ``co_serve(chaos=...)`` knob) and a
:class:`ResiliencePolicy` on the serving lane.  The degenerate
:func:`no_faults` model reproduces every fault-free result bit-for-bit.
Stdlib-only by the layering contract (see ``repro.analysis.layering``).
"""

from .injector import BatchFailureStream, FaultInjector
from .model import FAULT_KINDS, FaultEvent, FaultModel, no_faults
from .resilience import ResiliencePolicy

__all__ = [
    "BatchFailureStream",
    "FAULT_KINDS",
    "FaultEvent",
    "FaultInjector",
    "FaultModel",
    "ResiliencePolicy",
    "no_faults",
]
