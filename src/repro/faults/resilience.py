"""Request-level resilience policy: deadlines, retries, load shedding.

A :class:`ResiliencePolicy` tells the serving simulator how to degrade
*gracefully* when the chaos layer strikes: requests carry deadlines, failed
batches re-enter after seeded exponential backoff (up to a retry cap),
and the admission queue is bounded with deadline-aware shedding instead of
growing without limit.  Every knob defaults to off — a lane with no policy
(or the default one) behaves bit-for-bit as before this layer existed.

Backoff jitter is *keyed*, not streamed: the delay of attempt ``k`` of
request ``r`` is a pure function of (policy seed, r, k) through SHA-256,
so retry timing never depends on the order failures happen to interleave —
the same property that keeps the fabric's tie-breaks replay-stable.
"""

from __future__ import annotations

import dataclasses
import hashlib


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Serving-lane resilience knobs.  All off by default."""

    #: per-request completion deadline, seconds after arrival; None = none.
    #: Completions past their deadline still count as throughput but not as
    #: goodput, and expired queued requests become sheddable.
    deadline_s: float | None = None
    #: how many times a failed batch's requests are re-served before being
    #: counted as failed
    max_retries: int = 3
    #: base retry delay; attempt ``k`` waits ``backoff_s * 2**(k-1)`` plus
    #: keyed jitter
    backoff_s: float = 0.05
    #: jitter amplitude as a fraction of the exponential backoff
    jitter: float = 0.25
    #: key for the jitter hash (NOT a stream seed — see module docstring)
    seed: int = 0
    #: admission-queue bound (stage-0 queued requests); None = unbounded
    queue_cap: int | None = None
    #: shed queued requests that have already missed their deadline instead
    #: of serving them (only meaningful with ``deadline_s`` set)
    shed_expired: bool = True

    def __post_init__(self):
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline must be positive, got {self.deadline_s}")
        if self.max_retries < 0:
            raise ValueError(f"retry cap must be >= 0, got {self.max_retries}")
        if self.backoff_s < 0 or self.jitter < 0:
            raise ValueError("backoff and jitter must be non-negative")
        if self.queue_cap is not None and self.queue_cap < 1:
            raise ValueError(f"queue cap must be >= 1, got {self.queue_cap}")

    def backoff(self, rid: int, attempt: int) -> float:
        """Deterministic exponential backoff with keyed jitter (seconds)."""
        base = self.backoff_s * (2.0 ** (attempt - 1))
        tag = f"{self.seed}|{rid}|{attempt}".encode()
        u = int.from_bytes(hashlib.sha256(tag).digest()[:8], "big") / 2.0**64
        return base * (1.0 + self.jitter * u)

    def expired(self, t_arrival: float, now: float) -> bool:
        """Has a request that arrived at ``t_arrival`` missed its deadline?"""
        return self.deadline_s is not None and now > t_arrival + self.deadline_s
