"""Expanding a :class:`FaultModel` into a concrete, seeded chaos trace.

The injector is where the randomness lives — and where it is pinned.  Every
fault class draws from its own ``random.Random`` stream keyed by
``(model.seed, class, element)`` through SHA-256 (never Python's ``hash``,
whose string salt varies per process), so:

  * the chaos trace is a pure function of (model, platform shape, horizon);
  * adding a fault class, or an element to one, never perturbs the draws of
    any other stream (no shared-stream coupling);
  * the same model replayed against both event engines, or re-run in a
    fresh process, produces the identical trace.

EP and domain failures are alternating up/down renewal processes; an EP's
effective down-time is the *union* of its own process and every domain it
belongs to, merged into disjoint intervals before events are emitted — so
overlapping failures never produce a revival while a correlated fault still
holds the EP down.  Link hard-failures and degradations merge the same way,
with hard failure (factor 0) taking precedence over degradation.
"""

from __future__ import annotations

import hashlib
import random
from typing import Sequence

from .model import FaultEvent, FaultModel


def stream(seed: int, *key: object) -> random.Random:
    """A dedicated RNG for one fault stream, stable across processes."""
    tag = "|".join([str(seed), *[str(k) for k in key]]).encode()
    return random.Random(int.from_bytes(hashlib.sha256(tag).digest()[:8], "big"))


def _down_intervals(
    rng: random.Random, mtbf: float, mttr: float, horizon: float
) -> list[tuple[float, float]]:
    """Down intervals of an alternating Exp(mtbf)/Exp(mttr) renewal process."""
    out: list[tuple[float, float]] = []
    t = rng.expovariate(1.0 / mtbf)
    while t < horizon:
        repair = rng.expovariate(1.0 / mttr)
        out.append((t, t + repair))
        t = t + repair + rng.expovariate(1.0 / mtbf)
    return out


def _merge(intervals: Sequence[tuple[float, float]]) -> list[tuple[float, float]]:
    """Union of half-open intervals as a sorted disjoint list."""
    merged: list[tuple[float, float]] = []
    for s, e in sorted(intervals):
        if merged and s <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], e))
        else:
            merged.append((s, e))
    return merged


def _in_any(t: float, intervals: Sequence[tuple[float, float]]) -> bool:
    return any(s <= t < e for s, e in intervals)


def _link_events(
    key: tuple[int, int],
    fails: Sequence[tuple[float, float]],
    degrades: Sequence[tuple[float, float]],
    degrade_factor: float,
    horizon: float,
) -> list[FaultEvent]:
    """Piecewise link-state changes; hard failure shadows degradation."""
    times = sorted({t for iv in list(fails) + list(degrades) for t in iv if t < horizon})
    out: list[FaultEvent] = []
    factor = 1.0
    for t in times:
        if _in_any(t, fails):
            now = 0.0
        elif _in_any(t, degrades):
            now = degrade_factor
        else:
            now = 1.0
        if now != factor:
            out.append(FaultEvent(t=t, kind="link", link=key, factor=now))
            factor = now
    return out


class BatchFailureStream:
    """Seeded Bernoulli stream: one draw per served batch, in dispatch order.

    Batch completions cannot be pre-drawn (how many batches a run serves is
    itself an outcome), so transient batch errors consume this stream one
    draw per ``_DONE`` dispatch.  Dispatch order is pinned by the event
    engines' ``(time, kind, push-order)`` contract, making the consumption
    order — and therefore every draw — engine-independent and reproducible.
    """

    def __init__(self, p: float, rng: random.Random):
        self.p = p
        self._rng = rng

    def fails(self) -> bool:
        return self._rng.random() < self.p


class FaultInjector:
    """Expands a :class:`FaultModel` against a concrete platform."""

    def __init__(self, model: FaultModel):
        self.model = model

    def trace(self, platform, horizon: float) -> tuple[FaultEvent, ...]:
        """The full chaos trace over ``[0, horizon)``, sorted by time.

        ``platform`` is duck-typed: ``.n_eps``, ``.eps[i].perf_class`` and
        (optionally) ``.fabric.topology.links`` are all the shape the
        injector reads.  Ties at one timestamp order ``dropout`` before
        ``link`` before ``revival`` — a repair never races ahead of a
        failure scheduled at the same instant.
        """
        m = self.model
        horizon = float(horizon)
        events: list[FaultEvent] = []

        down: dict[int, list[tuple[float, float]]] = {
            ep: [] for ep in range(platform.n_eps)
        }
        for ep in range(platform.n_eps):
            mtbf = m.ep_mtbf.get(platform.eps[ep].perf_class)
            if mtbf is None:
                continue
            mttr = m.ep_mttr[platform.eps[ep].perf_class]
            down[ep].extend(_down_intervals(stream(m.seed, "ep", ep), mtbf, mttr, horizon))
        if m.domain_mtbf is not None:
            for d, members in enumerate(m.domains):
                ivs = _down_intervals(
                    stream(m.seed, "domain", d), m.domain_mtbf, m.domain_mttr, horizon
                )
                for ep in members:
                    if not (0 <= ep < platform.n_eps):
                        raise ValueError(f"failure domain EP {ep} outside platform")
                    down[ep].extend(ivs)
        for ep in range(platform.n_eps):
            for s, e in _merge(down[ep]):
                events.append(FaultEvent(t=s, kind="dropout", ep=ep))
                if e < horizon:
                    events.append(FaultEvent(t=e, kind="revival", ep=ep))

        fabric = getattr(platform, "fabric", None)
        if fabric is not None and (m.link_mtbf is not None or m.degrade_mtbf is not None):
            for key in sorted(fabric.topology.links):
                fails = (
                    _merge(_down_intervals(stream(m.seed, "link", key), m.link_mtbf, m.link_mttr, horizon))
                    if m.link_mtbf is not None
                    else []
                )
                degrades = (
                    _merge(_down_intervals(stream(m.seed, "degrade", key), m.degrade_mtbf, m.degrade_mttr, horizon))
                    if m.degrade_mtbf is not None
                    else []
                )
                events.extend(_link_events(key, fails, degrades, m.degrade_factor, horizon))

        kind_rank = {"dropout": 0, "link": 1, "revival": 2}
        events.sort(
            key=lambda e: (
                e.t,
                kind_rank[e.kind],
                -1 if e.ep is None else e.ep,
                e.link if e.link is not None else (-1, -1),
            )
        )
        return tuple(events)

    def batch_failures(self, label: str) -> BatchFailureStream | None:
        """The per-lane transient-batch-error stream, or None when disabled.

        Keyed by the serving lane's label so co-served tenants draw from
        independent streams regardless of their interleaving.
        """
        if self.model.batch_error_p <= 0.0:
            return None
        return BatchFailureStream(
            self.model.batch_error_p, stream(self.model.seed, "batch", label)
        )
