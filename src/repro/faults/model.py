"""Seeded stochastic fault specification — what can break, and how often.

A :class:`FaultModel` is a frozen, declarative description of the failure
physics of a platform: per-EP-class MTBF/MTTR renewal processes, correlated
package-domain failures (a chiplet's EPs die together), fabric link failures
and bandwidth degradations, and transient batch-level errors.  It contains
*no* randomness itself — the :class:`~repro.faults.injector.FaultInjector`
expands a model into a concrete chaos trace from explicit
``random.Random(seed)`` streams, so the trace is a pure function of
(model, platform shape, horizon).

Like the power and fabric layers, the chaos layer is off by default and
degenerate by construction: :func:`no_faults` (or simply never attaching a
model) produces an empty trace and reproduces every fault-free result
bit-for-bit.  This package is stdlib-only and imports nothing from the rest
of ``repro`` — platforms are duck-typed (anything with ``.n_eps`` /
``.eps[i].perf_class`` / ``.fabric``).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

#: fault-event kinds the injector can emit, in dispatch-tie order
FAULT_KINDS = ("dropout", "link", "revival")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault transition in a chaos trace.

    ``kind``:
      * ``"dropout"`` — EP ``ep`` fails (stops serving; in-flight work is
        requeued by the serving layer).
      * ``"revival"`` — EP ``ep`` is repaired and resumes serving.
      * ``"link"`` — fabric link ``link`` changes state: ``factor`` is the
        bandwidth multiplier from this instant on (``0.0`` = link dead,
        ``0 < f < 1`` = degraded, ``1.0`` = fully restored).
    """

    t: float
    kind: str
    ep: int | None = None
    link: tuple[int, int] | None = None
    factor: float | None = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.t < 0:
            raise ValueError(f"fault at negative time {self.t}")
        if self.kind in ("dropout", "revival"):
            if self.ep is None or self.link is not None:
                raise ValueError(f"{self.kind} fault needs an EP target, not a link")
        else:
            if self.link is None or self.factor is None or self.ep is not None:
                raise ValueError("link fault needs a link key and a factor")
            if not (0.0 <= self.factor <= 1.0):
                raise ValueError(f"link factor must be in [0, 1], got {self.factor}")


def _check_rate_pair(what: str, mtbf: float | None, mttr: float | None) -> None:
    if mtbf is None:
        return
    if mtbf <= 0:
        raise ValueError(f"{what} MTBF must be positive, got {mtbf}")
    if mttr is None or mttr <= 0:
        raise ValueError(f"{what} MTBF set but MTTR is {mttr!r} (need > 0)")


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Declarative chaos spec.  Every fault class is off unless configured.

    MTBF/MTTR are *means* of exponential fail/repair renewal processes
    (seconds of simulated time): an element is up for Exp(MTBF), down for
    Exp(MTTR), repeating — the standard availability model, matching the
    paper's §6 framing of runtime drift as an ongoing process rather than a
    one-shot script.
    """

    #: root seed; every fault stream is keyed off (seed, class, element)
    seed: int = 0
    #: EP ``perf_class`` -> mean time between failures; absent class = immune
    ep_mtbf: Mapping[int, float] = dataclasses.field(default_factory=dict)
    #: EP ``perf_class`` -> mean time to repair (required per failing class)
    ep_mttr: Mapping[int, float] = dataclasses.field(default_factory=dict)
    #: correlated failure domains: groups of EP indices that die together
    #: (a package losing power takes every chiplet on it down)
    domains: tuple[tuple[int, ...], ...] = ()
    domain_mtbf: float | None = None
    domain_mttr: float | None = None
    #: fabric link hard failures (link removed from routing while down)
    link_mtbf: float | None = None
    link_mttr: float | None = None
    #: fabric link bandwidth degradations (link keeps routing at reduced bw)
    degrade_mtbf: float | None = None
    degrade_mttr: float | None = None
    #: bandwidth multiplier while a link is degraded
    degrade_factor: float = 0.5
    #: probability a served batch errors and must be re-served
    batch_error_p: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "ep_mtbf", dict(self.ep_mtbf))
        object.__setattr__(self, "ep_mttr", dict(self.ep_mttr))
        object.__setattr__(
            self, "domains", tuple(tuple(d) for d in self.domains)
        )
        for cls in sorted(self.ep_mtbf):
            _check_rate_pair(f"EP class {cls}", self.ep_mtbf[cls], self.ep_mttr.get(cls))
        _check_rate_pair("domain", self.domain_mtbf, self.domain_mttr)
        _check_rate_pair("link", self.link_mtbf, self.link_mttr)
        _check_rate_pair("degrade", self.degrade_mtbf, self.degrade_mttr)
        if self.domain_mtbf is not None and not self.domains:
            raise ValueError("domain MTBF set but no failure domains given")
        for d in self.domains:
            if not d:
                raise ValueError("empty failure domain")
        if not (0.0 <= self.batch_error_p < 1.0):
            raise ValueError(f"batch error probability must be in [0, 1), got {self.batch_error_p}")
        if not (0.0 < self.degrade_factor < 1.0):
            raise ValueError(f"degrade factor must be in (0, 1), got {self.degrade_factor}")

    @property
    def enabled(self) -> bool:
        """True when any fault class can actually fire."""
        return bool(
            self.ep_mtbf
            or self.domain_mtbf is not None
            or self.link_mtbf is not None
            or self.degrade_mtbf is not None
            or self.batch_error_p > 0.0
        )


def no_faults(seed: int = 0) -> FaultModel:
    """The degenerate model: nothing ever fails.

    Attaching it is bit-for-bit equivalent to attaching nothing — the
    injector emits an empty trace and no batch-failure stream, so every
    simulator path stays on its fault-free arithmetic.  This is the chaos
    layer's analogue of :func:`repro.power.degenerate_power` /
    :func:`repro.interconnect.scalar_fabric`.
    """
    return FaultModel(seed=seed)
