"""Checkpoint store: atomic, async, step-addressed, pytree-faithful.

Layout:  <dir>/step_<N>/arrays.npz + tree.json (pytree structure + dtypes)
Writes go to a temp dir renamed into place (atomic on POSIX), optionally on
a background thread (async host offload — the train loop never blocks on
disk).  ``restore_latest`` + the counter-based data pipeline give exact
resume; a torn write (missing _DONE marker) is skipped, which is the
node-failure story: the job restarts from the last complete step.
"""

from __future__ import annotations

import dataclasses
import json
import shutil
import threading
from pathlib import Path
from typing import Any

import numpy as np

import jax


def _flatten(tree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for l in leaves:
        a = np.asarray(l)
        if a.dtype.kind == "V":  # ml_dtypes (bf16/fp8) round-trip npz as void
            a = a.astype(np.float32)
        out.append(a)
    return out, treedef


@dataclasses.dataclass
class CheckpointStore:
    directory: Path
    keep: int = 3

    def __post_init__(self):
        self.directory = Path(self.directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- write ------------------------------------------------------------

    def save(self, step: int, state: dict, *, async_: bool = False) -> None:
        leaves, treedef = _flatten(state)
        if async_:
            self.wait()  # one in-flight save at a time
            self._thread = threading.Thread(
                target=self._write, args=(step, leaves, treedef), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, leaves, treedef)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, leaves, treedef) -> None:
        final = self.directory / f"step_{step:08d}"
        tmp = self.directory / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **{f"a{i}": l for i, l in enumerate(leaves)})
        (tmp / "tree.json").write_text(json.dumps({"treedef": str(treedef), "n": len(leaves)}))
        (tmp / "_DONE").touch()
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)

    # -- read -------------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for p in sorted(self.directory.glob("step_*")):
            if (p / "_DONE").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def restore(self, step: int, like: dict, shardings=None) -> dict:
        """Restore into the structure of ``like`` (shapes/dtypes verified)."""
        path = self.directory / f"step_{step:08d}"
        data = np.load(path / "arrays.npz")
        leaves_like, treedef = jax.tree.flatten(like)
        n = json.loads((path / "tree.json").read_text())["n"]
        if n != len(leaves_like):
            raise ValueError(f"checkpoint has {n} leaves, expected {len(leaves_like)}")
        shard_leaves = jax.tree.leaves(shardings) if shardings is not None else [None] * n
        out = []
        for i, (ref, sh) in enumerate(zip(leaves_like, shard_leaves)):
            arr = data[f"a{i}"]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"leaf {i}: shape {arr.shape} != {ref.shape}")
            arr = arr.astype(ref.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
        return treedef.unflatten(out)

    def restore_latest(self, like: dict, shardings=None) -> tuple[int, dict] | None:
        steps = self.steps()
        if not steps:
            return None
        s = steps[-1]
        return s, self.restore(s, like, shardings)
