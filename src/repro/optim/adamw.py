"""Mixed-precision AdamW with cosine schedule and global-norm clipping.

Pure-JAX pytree optimizer (no optax on this box).  Designed for the memory
budget of the large dry-run cells (DESIGN.md §5): model params may be bf16;
the optimizer keeps an fp32 master copy and (configurably) bf16 moments, so
nemotron-4-340b's state is 10 bytes/param — the difference between fitting
and not fitting 256×16 GB.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


def cosine_schedule(step: jax.Array, *, peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    warm = peak_lr * (step + 1) / max(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.bfloat16
    #: keep an fp32 master copy when params are lower precision
    master_weights: bool = True


@dataclasses.dataclass(frozen=True)
class AdamW:
    cfg: AdamWConfig = AdamWConfig()

    def init(self, params) -> dict:
        zeros = lambda p: jnp.zeros(p.shape, self.cfg.moment_dtype)
        state = {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
        }
        if self.cfg.master_weights:
            state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        return state

    def update(self, grads, state, params):
        c = self.cfg
        step = state["step"]
        lr = cosine_schedule(step, peak_lr=c.peak_lr, warmup=c.warmup, total=c.total_steps)

        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-12))

        t = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - c.b1**t
        bc2 = 1.0 - c.b2**t
        masters = state.get("master", params)

        def upd(g, mu, nu, m):
            g = g.astype(jnp.float32) * scale
            mu32 = c.b1 * mu.astype(jnp.float32) + (1 - c.b1) * g
            nu32 = c.b2 * nu.astype(jnp.float32) + (1 - c.b2) * g * g
            mhat = mu32 / bc1
            vhat = nu32 / bc2
            m32 = m.astype(jnp.float32)
            m32 = m32 - lr * (mhat / (jnp.sqrt(vhat) + c.eps) + c.weight_decay * m32)
            return m32, mu32.astype(c.moment_dtype), nu32.astype(c.moment_dtype)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_mu = jax.tree.leaves(state["mu"])
        flat_nu = jax.tree.leaves(state["nu"])
        flat_m = jax.tree.leaves(masters)
        out = [upd(*args) for args in zip(flat_g, flat_mu, flat_nu, flat_m)]
        new_master = treedef.unflatten([o[0] for o in out])
        new_mu = treedef.unflatten([o[1] for o in out])
        new_nu = treedef.unflatten([o[2] for o in out])

        new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), new_master, params)
        new_state = {"step": step + 1, "mu": new_mu, "nu": new_nu}
        if c.master_weights:
            new_state["master"] = new_master
        return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
