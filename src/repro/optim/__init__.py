from .adamw import AdamW, AdamWConfig, cosine_schedule
from .grad_compress import compressed_psum, dequantize, quantize_int8

__all__ = [
    "AdamW",
    "AdamWConfig",
    "cosine_schedule",
    "compressed_psum",
    "dequantize",
    "quantize_int8",
]
