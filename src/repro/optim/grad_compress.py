"""Int8 gradient compression with error feedback.

Used by the shard_map pipeline trainer, where *we* own the data-parallel
collective (GSPMD owns it in the pjit path): gradients are quantized to
int8 against a globally-agreed per-tensor scale, summed over the DP axis as
int32, and dequantized once — 4× fewer bytes on the wire than fp32 psum,
with the quantization residual carried to the next step (error feedback),
the standard trick that keeps convergence intact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis_name: str, error: dict | None = None):
    """psum ``grads`` over ``axis_name`` in int8. Returns (grads, new_error).

    Must be called inside shard_map/pmap context providing ``axis_name``.
    """
    new_err = {}
    out = {}
    flat, treedef = jax.tree.flatten_with_path(grads)
    err_flat = None
    if error is not None:
        err_flat = [l for _, l in jax.tree.flatten_with_path(error)]
    res_g, res_e = [], []
    for i, (path, g) in enumerate(flat):
        g32 = g.astype(jnp.float32)
        if err_flat is not None:
            g32 = g32 + err_flat[i]
        # globally agreed scale (tiny fp32 collective)
        amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = quantize_int8(g32, scale)
        local_deq = dequantize(q, scale)
        res_e.append(g32 - local_deq)  # error feedback residual
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        res_g.append(dequantize(summed, scale).astype(g.dtype))
    n = jax.lax.psum(1, axis_name)
    res_g = [g / n for g in res_g]  # mean, matching uncompressed pmean
    grads_out = jax.tree.unflatten(treedef, res_g)
    err_out = jax.tree.unflatten(treedef, res_e)
    return grads_out, err_out
