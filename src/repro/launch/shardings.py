"""Sharding assignment for params / optimizer / batch / decode caches.

Everything returns PartitionSpec pytrees matching the corresponding value
trees; ``launch/dryrun.py`` wraps them in NamedShardings for jit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.lm_common import LMConfig, param_shardings
from .mesh import dp_axes_of


def params_pspecs(cfg: LMConfig, mesh: Mesh) -> dict:
    return param_shardings(cfg, fsdp_axis="data", tp_axis="model")


def opt_pspecs(cfg: LMConfig, mesh: Mesh, params_spec: dict) -> dict:
    return {
        "step": P(),
        "mu": params_spec,
        "nu": params_spec,
        "master": params_spec,
    }


def batch_pspecs(cfg: LMConfig, mesh: Mesh, batch: dict) -> dict:
    dp = dp_axes_of(mesh)
    return {k: P(dp, *([None] * (v.ndim - 1))) for k, v in batch.items()}


def _divisible_axis(tp: int, *cands: tuple[int, int]) -> int | None:
    """First candidate (axis, size) whose size divides evenly over tp."""
    for axis, size in cands:
        if size % tp == 0:
            return axis
    return None


def cache_pspecs(cfg: LMConfig, mesh: Mesh, cache: dict) -> dict:
    """Decode-cache shardings.

    KV rings [L, b, W, kvh, hd]: batch over DP; then shard kv-heads over
    ``model`` when divisible, else head_dim (contractions over a sharded
    head_dim become psum'd partials — cheap at decode shapes), else
    replicate.  SSM state [L, b, h, p, n]: same game over (h, p, n).
    """
    dp = dp_axes_of(mesh)
    tp = mesh.shape["model"]
    spec: dict = {}
    for name, v in cache.items():
        if name == "index":
            spec[name] = P()
        elif name in ("pos", "shared_pos"):
            spec[name] = P(None, None)
        elif name in ("k", "v", "shared_k", "shared_v", "cross_k", "cross_v"):
            # flash-decode layout: the cache SEQUENCE axis is TP-sharded, so
            # each model shard scores its slice of the context and only
            # O(b·h) softmax statistics cross the wire — KV-head or head-dim
            # sharding would all-reduce O(b·h·W) score panels instead.
            ax = 2 if v.shape[2] % tp == 0 else _divisible_axis(tp, (3, v.shape[3]), (4, v.shape[4]))
            parts = [None, dp, None, None, None]
            if ax is not None:
                parts[ax] = "model"
            spec[name] = P(*parts)
        elif name == "ssm":  # [L, b, h, p, n]
            ax = _divisible_axis(tp, (2, v.shape[2]), (3, v.shape[3]), (4, v.shape[4]))
            parts = [None, dp, None, None, None]
            if ax is not None:
                parts[ax] = "model"
            spec[name] = P(*parts)
        elif name == "conv":  # [L, b, 3, ch]
            ax = _divisible_axis(tp, (3, v.shape[3]),)
            parts = [None, dp, None, None]
            if ax is not None:
                parts[ax] = "model"
            spec[name] = P(*parts)
        else:
            raise KeyError(name)
    return spec


def sanitize(mesh: Mesh, sds_tree, spec_tree):
    """Drop mesh axes from dims they don't divide evenly.

    jit in_shardings require exact divisibility (unlike constraints), and
    the assigned configs are full of awkward extents — whisper's vocab
    51865, mamba2's fused in_proj 3352.  Such dims fall back to replicated.
    """

    def fix(sds, spec):
        if not isinstance(spec, P):
            return spec
        parts = []
        for i, el in enumerate(spec):
            if el is None:
                parts.append(None)
                continue
            axes = el if isinstance(el, (tuple, list)) else (el,)
            extent = 1
            for a in axes:
                extent *= mesh.shape[a]
            parts.append(el if sds.shape[i] % extent == 0 else None)
        return P(*parts)

    return jax.tree.map(fix, sds_tree, spec_tree)


def to_named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def shaped(tree):
    """Value pytree -> ShapeDtypeStruct pytree (no allocation)."""
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
