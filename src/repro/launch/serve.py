"""Batched serving driver: prefill + decode with the ring KV cache.

  python -m repro.launch.serve --arch granite-3-2b --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config, get_smoke
from ..models.lm_common import LMConfig, init_params
from ..models.transformer import init_cache, prefill_step, serve_step


def serve(
    cfg: LMConfig,
    *,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    seed: int = 0,
    greedy: bool = True,
) -> dict:
    params = init_params(cfg, jax.random.PRNGKey(seed))
    max_len = prompt_len + gen
    rng = np.random.default_rng(seed)
    batch_in = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}
    if cfg.is_encdec:
        batch_in["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.enc_frames, cfg.d_model)), jnp.float32
        )
    if cfg.n_patches:
        batch_in["patch_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_patches, cfg.d_model)), jnp.float32
        )

    pf = jax.jit(lambda p, b: prefill_step(cfg, p, b, max_len=max_len))
    t0 = time.time()
    logits, cache = pf(params, batch_in)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    step = jax.jit(lambda p, c, t: serve_step(cfg, p, c, t))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(gen - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    tokens = jnp.concatenate(out_tokens, 1)
    return {
        "tokens": tokens,
        "prefill_s": t_prefill,
        "decode_tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="granite-3-2b")
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = get_smoke(args.arch) if args.scale == "smoke" else get_config(args.arch)
    out = serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)
    print(
        f"[serve] {args.arch} tokens={out['tokens'].shape} "
        f"prefill={out['prefill_s']:.3f}s decode={out['decode_tok_per_s']:.1f} tok/s"
    )


if __name__ == "__main__":
    main()
