"""Production meshes.

Everything is a FUNCTION — importing this module never touches jax device
state (the dry-run driver must set XLA_FLAGS before first jax init).

Single pod:  (16, 16)        axes ("data", "model")        = 256 chips.
Multi-pod:   (2, 16, 16)     axes ("pod", "data", "model") = 512 chips.

The ``pod`` axis is pure data parallelism over the slow inter-pod link
(DCI); ``data`` is FSDP+DP inside a pod; ``model`` is tensor parallelism
on the fastest (ICI ring) axis.  When Shisha drives pipeline parallelism
(pipeline/runtime.py) the ``pod`` — or a dedicated ``stage`` — axis is the
chiplet axis the paper schedules over.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 (dry-run only)"
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_stage_mesh(n_stages: int, per_stage: int = 1) -> Mesh:
    """Pipeline mesh for the Shisha runtime: ("stage", "inner")."""
    n = n_stages * per_stage
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(n_stages, per_stage), ("stage", "inner"))


def make_test_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    """Tiny mesh over however many (host) devices tests have."""
    n = int(np.prod(shape))
    return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)


def dp_axes_of(mesh: Mesh) -> tuple[str, ...]:
    """Batch axes: everything except the TP axis."""
    return tuple(a for a in mesh.axis_names if a != "model")


def batch_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    return NamedSharding(mesh, P(dp_axes_of(mesh), *([None] * (ndim - 1))))
