"""End-to-end training driver.

Runs a real (CPU-sized or production) training job: synthetic data
pipeline -> jitted train step -> async checkpoints -> fault supervision.
The ~100M-parameter end-to-end example (examples/train_100m.py) calls
straight into :func:`train`.

  python -m repro.launch.train --arch qwen2-0.5b --steps 50 --scale smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointStore
from ..configs import ARCHS, get_config, get_smoke
from ..data import DataConfig, make_batch_iterator
from ..models.lm_common import LMConfig, init_params
from ..models.transformer import make_train_step
from ..optim import AdamW, AdamWConfig
from ..runtime import TrainSupervisor


def train(
    cfg: LMConfig,
    *,
    steps: int = 100,
    schedule_steps: int | None = None,  # cosine horizon (resume must keep it fixed)
    batch: int = 8,
    seq: int = 64,
    lr: float = 3e-4,
    ckpt_dir: Path | None = None,
    save_every: int = 50,
    log_every: int = 10,
    resume: bool = True,
    seed: int = 0,
) -> dict:
    """Returns {'losses': [...], 'state': ..., 'steps_per_s': float}."""
    params = init_params(cfg, jax.random.PRNGKey(seed))
    horizon = schedule_steps or steps
    opt = AdamW(AdamWConfig(peak_lr=lr, warmup=min(20, horizon // 5 + 1), total_steps=horizon))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))

    data_cfg = DataConfig(batch=batch, seq=seq, vocab=cfg.vocab, seed=seed)
    start = 0
    store = None
    state = {"params": params, "opt": opt_state}
    if ckpt_dir is not None:
        store = CheckpointStore(Path(ckpt_dir))
        if resume:
            restored = store.restore_latest(state)
            if restored is not None:
                start, state = restored
                print(f"[train] resumed from step {start}")

    it = make_batch_iterator(cfg, data_cfg, start_step=start)
    losses = []
    t0 = time.time()

    def one_step(st, step):
        batch_np = next(it)
        batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
        p, o, m = step_fn(st["params"], st["opt"], batch_dev)
        return {"params": p, "opt": o}, float(m["loss"])

    if store is not None:
        sup = TrainSupervisor(store=store, save_every=save_every)
        state, losses = sup.run(state, one_step, n_steps=steps, start_step=start)
    else:
        for step in range(start, steps):
            state, loss = one_step(state, step)
            losses.append(loss)
            if log_every and step % log_every == 0:
                print(f"[train] step {step} loss {loss:.4f}")
    dt = time.time() - t0
    return {"losses": losses, "state": state, "steps_per_s": (steps - start) / max(dt, 1e-9)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2-0.5b")
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", type=Path, default=None)
    args = ap.parse_args()
    cfg = get_smoke(args.arch) if args.scale == "smoke" else get_config(args.arch)
    out = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt)
    l = out["losses"]
    print(f"[train] {args.arch} first={l[0]:.4f} last={l[-1]:.4f} steps/s={out['steps_per_s']:.2f}")


if __name__ == "__main__":
    main()
