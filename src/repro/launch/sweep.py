"""Full dry-run sweep driver: one subprocess per cell (fresh XLA state,
bounded memory), resumable via --skip-existing semantics."""

from __future__ import annotations

import argparse
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[3]
OUT = REPO / "experiments" / "dryrun"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    sys.path.insert(0, str(REPO / "src"))
    from repro.configs import ARCHS, SHAPES

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = []
    for mk in meshes:
        for arch in ARCHS:
            for shape in SHAPES:
                out = OUT / f"{arch}__{shape}__{mk}.json"
                if out.exists():
                    print(f"[cached] {arch} {shape} {mk}", flush=True)
                    continue
                t0 = time.time()
                try:
                    r = subprocess.run(
                    [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch, "--shape", shape, "--mesh", mk],
                    cwd=REPO,
                    env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin", "HOME": "/root"},
                        capture_output=True,
                        text=True,
                        timeout=args.timeout,
                    )
                except subprocess.TimeoutExpired:
                    failures.append((arch, shape, mk, "timeout"))
                    print(f"[TIMEOUT] {arch} {shape} {mk} after {args.timeout}s", flush=True)
                    continue
                tail = (r.stdout + r.stderr).strip().splitlines()
                line = next((l for l in reversed(tail) if l.startswith("[")), "?")
                print(f"{line}   ({time.time() - t0:.0f}s)", flush=True)
                if r.returncode != 0:
                    failures.append((arch, shape, mk))
                    print("\n".join(tail[-12:]), flush=True)
    print(f"sweep done; {len(failures)} failures: {failures}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
