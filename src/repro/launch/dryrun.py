import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (architecture × input-shape) cell, build the production mesh,
jit the real step function (train / prefill / decode) with explicit
in/out shardings, ``.lower().compile()`` it against ShapeDtypeStructs
(no allocation), and record:

  * memory_analysis()      — per-device argument/output/temp bytes,
  * cost_analysis()        — per-device HLO FLOPs and bytes accessed,
  * the collective schedule parsed out of the partitioned HLO
    (op kind, dtype, per-device bytes, group size, wire-byte estimate).

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json; the
roofline table (benchmarks/roofline.py) and EXPERIMENTS.md are generated
from these files.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, applicable, for_shape, get_config
from ..models.lm_common import LMConfig, init_params
from ..models.transformer import (
    init_cache,
    layer_costs,
    make_train_step,
    prefill_step,
    serve_step,
    train_loss,
)
from ..optim import AdamW, AdamWConfig
from .mesh import dp_axes_of, make_production_mesh
from .shardings import (
    batch_pspecs,
    cache_pspecs,
    opt_pspecs,
    params_pspecs,
    sanitize,
    shaped,
    to_named,
)
from jax.sharding import PartitionSpec as P

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(?P<kinds>(?:\w+\[[\d,]*\]\{[^}]*\}|\(\s*[^)]*\))\s*)"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nb


def parse_collectives(hlo_text: str) -> list[dict]:
    """Extract collective ops + per-device result bytes from partitioned HLO."""
    out = []
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(.*?)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(-start|-done)?\(", line)
        if not m or (m.group(3) == "-done"):
            continue
        op = m.group(2)
        lhs = m.group(1)
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))
        gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
        if gm:
            group = int(gm.group(2))
        else:
            gm2 = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
            group = len(gm2.group(1).split(",")) if gm2 else 1
        # ring wire-bytes per device
        if op == "all-gather":
            wire = nbytes * (group - 1) / max(group, 1)
        elif op == "all-reduce":
            wire = 2 * nbytes * (group - 1) / max(group, 1)
        elif op == "reduce-scatter":
            wire = nbytes * (group - 1)  # result is the scattered shard
        elif op == "all-to-all":
            wire = nbytes * (group - 1) / max(group, 1)
        else:  # collective-permute
            wire = nbytes
        out.append({"op": op, "bytes": nbytes, "group": group, "wire_bytes": wire})
    return out


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — never allocates)
# ---------------------------------------------------------------------------


def input_specs(cfg: LMConfig, shape_name: str, cell=None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cell = cell or SHAPES[shape_name]
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

    if cfg.is_encdec:
        dec = min(S, cfg.max_decoder_len or S)
        batch = {
            "frames": sds((B, cfg.enc_frames, cfg.d_model), cfg.dtype),
            "tokens": sds((B, dec), i32),
        }
        if cell.phase == "train":
            batch["labels"] = sds((B, dec), i32)
        return batch
    if cfg.n_patches and cell.phase != "decode":
        s_text = S - cfg.n_patches
        batch = {
            "tokens": sds((B, s_text), i32),
            "patch_embeds": sds((B, cfg.n_patches, cfg.d_model), cfg.dtype),
        }
        if cell.phase == "train":
            batch["labels"] = sds((B, s_text), i32)
        return batch
    batch = {"tokens": sds((B, S), i32)}
    if cell.phase == "train":
        batch["labels"] = sds((B, S), i32)
    return batch


def _maybe_dp(mesh, spec_tree, batch_size):
    """Replicate the batch axis when it doesn't divide the DP extent."""
    dp = dp_axes_of(mesh)
    total = 1
    for a in dp:
        total *= mesh.shape[a]
    if batch_size % total == 0:
        return spec_tree
    strip = lambda s: P(*(None if e == dp or e == list(dp) else e for e in s))
    return jax.tree.map(
        lambda s: strip(s) if isinstance(s, P) else s,
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------


def _accum_for(cfg: LMConfig, cell) -> int:
    """Gradient-accumulation depth for train cells (activation-memory fit)."""
    if cell.phase != "train":
        return 1
    if cfg.d_model >= 8192:
        return 8
    if cfg.d_model >= 4096:
        return 4
    return 1


def _scaled_depth(cfg: LMConfig, k: int) -> LMConfig:
    """Config with k 'depth units' (hybrid: k groups; encdec: k enc+dec layers)."""
    if cfg.block_kind == "hybrid":
        return dataclasses.replace(cfg, n_layers=k * cfg.shared_attn_every)
    if cfg.is_encdec:
        return dataclasses.replace(cfg, n_layers=k, enc_layers=k)
    return dataclasses.replace(cfg, n_layers=k)


def _depth_units(cfg: LMConfig) -> int:
    if cfg.block_kind == "hybrid":
        return cfg.n_layers // cfg.shared_attn_every
    return cfg.n_layers


def _build_and_compile(cfg: LMConfig, cell, mesh, shape_name: str, accum: int = 1):
    """jit + lower + compile the cell's step function. Returns compiled."""
    dp = dp_axes_of(mesh)
    params_sds = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspec = sanitize(mesh, params_sds, params_pspecs(cfg, mesh))
    batch_sds = input_specs(cfg, shape_name, cell)
    with mesh:
        if cell.phase == "train":
            opt = AdamW(AdamWConfig())
            opt_sds = jax.eval_shape(opt.init, params_sds)
            ospec = opt_pspecs(cfg, mesh, pspec)
            bspec = _maybe_dp(mesh, batch_pspecs(cfg, mesh, batch_sds), cell.global_batch)
            step = make_train_step(cfg, opt, mesh, dp, "model", accum=accum)
            jitted = jax.jit(
                step,
                in_shardings=to_named(mesh, (pspec, ospec, bspec)),
                out_shardings=to_named(
                    mesh, (pspec, ospec, {"loss": P(), "lr": P(), "grad_norm": P()})
                ),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
        elif cell.phase == "prefill":
            bspec = _maybe_dp(mesh, batch_pspecs(cfg, mesh, batch_sds), cell.global_batch)
            cache_sds = jax.eval_shape(
                lambda p, b: prefill_step(cfg, p, b, None, dp, "model"), params_sds, batch_sds
            )[1]
            cspec = sanitize(mesh, cache_sds, _maybe_dp(mesh, cache_pspecs(cfg, mesh, cache_sds), cell.global_batch))
            lspec = P(dp, None) if cell.global_batch % _dptot(mesh) == 0 else P(None, None)
            fn = lambda p, b: prefill_step(cfg, p, b, mesh, dp, "model")
            jitted = jax.jit(
                fn,
                in_shardings=to_named(mesh, (pspec, bspec)),
                out_shardings=to_named(mesh, (lspec, cspec)),
            )
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            B, S = cell.global_batch, cell.seq_len
            cache_sds = jax.eval_shape(lambda: init_cache(cfg, B, S))
            cspec = sanitize(mesh, cache_sds, _maybe_dp(mesh, cache_pspecs(cfg, mesh, cache_sds), B))
            tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            tspec = P(dp, None) if B % _dptot(mesh) == 0 else P(None, None)
            from ..models.transformer import serve_block

            fn = lambda p, c, t: serve_block(cfg, p, c, t, mesh, dp, "model")
            jitted = jax.jit(
                fn,
                in_shardings=to_named(mesh, (pspec, cspec, tspec)),
                out_shardings=to_named(mesh, (P(tspec[0], None), cspec)),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_sds, cache_sds, tok_sds)

        compiled = lowered.compile()
    return compiled


def _extract(compiled) -> dict:
    """Pull flops / bytes / collective wire-bytes out of a compiled artifact."""
    ca = compiled.cost_analysis() or {}
    colls = parse_collectives(compiled.as_text())
    by_op: dict[str, dict] = {}
    for c in colls:
        d = by_op.setdefault(c["op"], {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
        d["count"] += 1
        d["bytes"] += c["bytes"]
        d["wire_bytes"] += c["wire_bytes"]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "wire": sum(c["wire_bytes"] for c in colls),
        "by_op": by_op,
        "n_ops": len(colls),
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path = OUT_DIR) -> dict:
    runs, reason = applicable(arch, shape_name)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "runs": runs,
        "reason": reason,
    }
    if not runs:
        return rec

    cfg = for_shape(get_config(arch), shape_name)
    cell = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    t0 = time.time()

    accum = _accum_for(cfg, cell)

    # 1) full-config compile: the pass/fail gate + memory analysis + schedule
    compiled = _build_and_compile(cfg, cell, mesh, shape_name, accum=accum)
    full = _extract(compiled)
    ma = compiled.memory_analysis()
    t_full = time.time() - t0

    if mesh_kind == "multi":
        # the multi-pod pass proves the "pod" axis shards; the roofline
        # table is single-pod only (assignment) — skip the fit compiles.
        rec.update(
            {
                "phase": cell.phase,
                "n_chips": n_chips,
                "compile_s": round(t_full, 1),
                "memory": {
                    "argument_bytes_per_dev": ma.argument_size_in_bytes,
                    "output_bytes_per_dev": ma.output_size_in_bytes,
                    "temp_bytes_per_dev": ma.temp_size_in_bytes,
                    "alias_bytes_per_dev": ma.alias_size_in_bytes,
                    "peak_estimate_gib": round(
                        (ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
                        / 2**30, 3,
                    ),
                },
                "cost": {"raw_uncorrected": {"flops_per_dev": full["flops"], "bytes_per_dev": full["bytes"], "wire": full["wire"]}},
                "collectives": {"by_op_single_iteration": full["by_op"], "n_ops": full["n_ops"]},
                "compiled_ok": True,
            }
        )
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}__{shape_name}__{mesh_kind}.json").write_text(json.dumps(rec, indent=2))
        return rec

    # 2) loop-trip-count correction.  XLA's cost analysis counts a while body
    #    ONCE, so per-layer flops/bytes/collectives are undercounted by the
    #    scan trip count.  Compile two reduced-depth variants with every scan
    #    unrolled and fit cost(L) = a + b·L exactly (every loop in the model
    #    scales with L; embedding/head/loss are the constant a).  Train cells
    #    are measured on ONE microbatch and scaled by ``accum`` — each
    #    microbatch is an identical subgraph (incl. its FSDP re-gathers), so
    #    the step cost is accum × microbatch cost + O(optimizer), and the
    #    optimizer update is noise at these scales.
    k1, k2 = 1, 3
    cell_m = dataclasses.replace(cell, global_batch=cell.global_batch // accum)
    unrolled = lambda k: dataclasses.replace(_scaled_depth(cfg, k), scan_unroll=True)
    c1 = _extract(_build_and_compile(unrolled(k1), cell_m, mesh, shape_name))
    c2 = _extract(_build_and_compile(unrolled(k2), cell_m, mesh, shape_name))
    L = _depth_units(cfg)

    def fit(key):
        b = (c2[key] - c1[key]) / (k2 - k1)
        a = c1[key] - b * k1
        return max(a + b * L, 0.0) * accum

    flops_dev = fit("flops")
    bytes_dev = fit("bytes")
    wire = fit("wire")
    model_flops = _model_flops(cfg, cell)

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = wire / LINK_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]

    rec.update(
        {
            "phase": cell.phase,
            "n_chips": n_chips,
            "compile_s": round(t_full, 1),
            "total_s": round(time.time() - t0, 1),
            "memory": {
                "argument_bytes_per_dev": ma.argument_size_in_bytes,
                "output_bytes_per_dev": ma.output_size_in_bytes,
                "temp_bytes_per_dev": ma.temp_size_in_bytes,
                "alias_bytes_per_dev": ma.alias_size_in_bytes,
                "peak_estimate_gib": round(
                    (ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
                    / 2**30, 3,
                ),
            },
            "cost": {
                "flops_per_dev": flops_dev,
                "bytes_per_dev": bytes_dev,
                "hlo_flops_global": flops_dev * n_chips,
                "raw_uncorrected": {"flops_per_dev": full["flops"], "bytes_per_dev": full["bytes"], "wire": full["wire"]},
            },
            "collectives": {
                "total_wire_bytes_per_dev": wire,
                "by_op_single_iteration": full["by_op"],
                "n_ops": full["n_ops"],
            },
            "roofline": {
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": collective_s,
                "dominant": dominant,
                "model_flops": model_flops,
                "useful_flops_ratio": (model_flops / (flops_dev * n_chips)) if flops_dev else None,
            },
        }
    )
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}.json"
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def _dptot(mesh) -> int:
    t = 1
    for a in dp_axes_of(mesh):
        t *= mesh.shape[a]
    return t


def _fake(sds_tree):
    """SDS tree usable as eval_shape arguments."""
    return sds_tree


def _model_flops(cfg: LMConfig, cell) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D forward-only (N = active params)."""
    n_active = cfg.active_param_count()
    if cell.phase == "train":
        dec = min(cell.seq_len, cfg.max_decoder_len or cell.seq_len) if cfg.is_encdec else cell.seq_len
        d_tokens = cell.global_batch * dec
        return 6.0 * n_active * d_tokens
    if cell.phase == "prefill":
        dec = min(cell.seq_len, cfg.max_decoder_len or cell.seq_len) if cfg.is_encdec else cell.seq_len
        return 2.0 * n_active * cell.global_batch * dec
    return 2.0 * n_active * cell.global_batch  # decode: one token per sequence


# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", type=Path, default=OUT_DIR)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = (
        [(a, s) for a in ARCHS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            if args.skip_existing and (args.out / f"{arch}__{shape}__{mk}.json").exists():
                print(f"[CACHED] {arch} {shape} {mk}")
                continue
            try:
                rec = run_cell(arch, shape, mk, args.out)
                if rec["runs"] and "roofline" not in rec:
                    print(
                        f"[OK] {arch:18s} {shape:12s} {mk:6s} compiled "
                        f"mem/dev={rec['memory']['peak_estimate_gib']}GiB compile={rec['compile_s']}s"
                    )
                elif rec["runs"]:
                    r = rec["roofline"]
                    print(
                        f"[OK] {arch:18s} {shape:12s} {mk:6s} "
                        f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                        f"collective={r['collective_s']:.3e}s dom={r['dominant']} "
                        f"mem/dev={rec['memory']['peak_estimate_gib']}GiB "
                        f"compile={rec['compile_s']}s"
                    )
                else:
                    print(f"[SKIP] {arch:18s} {shape:12s} {mk:6s} — {rec['reason']}")
            except Exception:
                failures += 1
                print(f"[FAIL] {arch} {shape} {mk}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
