import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration harness: measure one dry-run cell under config overrides.

Each §Perf iteration is: hypothesis -> override -> re-lower -> re-analyse.
Overrides are LMConfig fields (attn_q_block, remat, scan knobs, dtypes via
string) plus the accumulation depth; results print the three roofline terms
next to the recorded baseline so the delta is immediate.

  python -m repro.launch.hillclimb --arch qwen3-32b --shape train_4k \
      --set attn_q_block=1024 --accum 8 --tag qblock1024
"""

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax.numpy as jnp

from ..configs import SHAPES, for_shape, get_config
from ..models.lm_common import LMConfig
from .dryrun import (
    HBM_BW,
    LINK_BW,
    OUT_DIR,
    PEAK_FLOPS,
    _accum_for,
    _build_and_compile,
    _depth_units,
    _extract,
    _model_flops,
    _scaled_depth,
)
from .mesh import make_production_mesh

PERF_DIR = OUT_DIR.parent / "perf"

_FIELD_TYPES = {f.name: f.type for f in dataclasses.fields(LMConfig)}


def parse_overrides(pairs: list[str]) -> dict:
    out = {}
    for p in pairs:
        k, v = p.split("=", 1)
        if v in ("True", "False"):
            out[k] = v == "True"
        elif v in ("bf16", "f32"):
            out[k] = jnp.bfloat16 if v == "bf16" else jnp.float32
        else:
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = v
    return out


def measure(arch: str, shape: str, overrides: dict, accum: int | None = None, fast: bool = False) -> dict:
    cfg = for_shape(get_config(arch), shape)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = SHAPES[shape]
    mesh = make_production_mesh()
    acc = accum if accum is not None else _accum_for(cfg, cell)

    t0 = time.time()
    ma = None
    if not fast:  # fast mode: fit compiles only (terms, no memory analysis)
        compiled = _build_and_compile(cfg, cell, mesh, shape, accum=acc)
        ma = compiled.memory_analysis()

    cell_m = dataclasses.replace(cell, global_batch=cell.global_batch // acc)
    unrolled = lambda k: dataclasses.replace(_scaled_depth(cfg, k), scan_unroll=True)
    c1 = _extract(_build_and_compile(unrolled(1), cell_m, mesh, shape))
    c2 = _extract(_build_and_compile(unrolled(3), cell_m, mesh, shape))
    L = _depth_units(cfg)

    def fit(key):
        b = (c2[key] - c1[key]) / 2.0
        return max(c1[key] - b + b * L, 0.0) * acc

    flops, bts, wire = fit("flops"), fit("bytes"), fit("wire")
    return {
        "arch": arch,
        "shape": shape,
        "overrides": {k: str(v) for k, v in overrides.items()},
        "accum": acc,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bts / HBM_BW,
        "collective_s": wire / LINK_BW,
        "mem_gib": round((ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 2) if ma else None,
        "useful_ratio": _model_flops(cfg, cell) / (flops * mesh.size) if flops else None,
        "wall_s": round(time.time() - t0, 1),
        "by_op_1iter": c2["by_op"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", nargs="*", default=[], dest="overrides")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--tag", default="iter")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    rec = measure(args.arch, args.shape, parse_overrides(args.overrides), args.accum, fast=args.fast)

    base_p = OUT_DIR / f"{args.arch}__{args.shape}__single.json"
    if base_p.exists():
        base = json.loads(base_p.read_text())
        if "roofline" in base:
            b = base["roofline"]
            print(
                f"baseline : compute={b['compute_s']:.3e} memory={b['memory_s']:.3e} "
                f"collective={b['collective_s']:.3e} mem={base['memory']['peak_estimate_gib']}GiB "
                f"useful={b['useful_flops_ratio']:.3f}"
            )
    print(
        f"this run : compute={rec['compute_s']:.3e} memory={rec['memory_s']:.3e} "
        f"collective={rec['collective_s']:.3e} mem={rec['mem_gib']}GiB "
        f"useful={rec['useful_ratio']:.3f}  ({rec['wall_s']}s)", flush=True
    )
    PERF_DIR.mkdir(parents=True, exist_ok=True)
    out = PERF_DIR / f"{args.arch}__{args.shape}__{args.tag}.json"
    out.write_text(json.dumps(rec, indent=2))
    print(f"saved {out}")


if __name__ == "__main__":
    main()
