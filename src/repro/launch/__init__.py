"""Launchers: production meshes, dry-run driver, training / serving CLIs."""
