"""Arrival-process generators for the serving simulator.

Every generator is a deterministic function of its seed: calling
``arrivals(horizon)`` twice returns the identical timestamp list, and a
recorded trace replays bit-identically (:class:`ReplayTraffic`).  This is
what makes simulator results reproducible across the static-vs-continuous
Shisha comparisons in ``benchmarks/serve_sim.py`` — both arms see the very
same request stream.

Time is *simulated* seconds on the same axis the :class:`~repro.core.evaluator.Trace`
cost accounting uses (a pipeline "beat" = the slowest stage time), so an
arrival rate is directly comparable to the evaluator's steady-state
throughput ``1 / beat``.

Processes:

  * :class:`PoissonTraffic`   — memoryless baseline (open-loop load).
  * :class:`MMPPTraffic`      — 2-state Markov-modulated Poisson process,
    the standard bursty-traffic model (calm/burst states with exponential
    sojourns).
  * :class:`DiurnalTraffic`   — inhomogeneous Poisson with a sinusoidal
    rate profile (a compressed day/night cycle), sampled by thinning.
  * :class:`ReplayTraffic`    — replays an explicit timestamp list; use
    :meth:`ReplayTraffic.record` to freeze any generator into a trace.
"""

from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Protocol, Sequence

import numpy as np


class TrafficGenerator(Protocol):
    """Anything that can produce a sorted list of arrival times."""

    def arrivals(self, horizon: float) -> list[float]: ...


def _rng(seed: int) -> np.random.Generator:
    return np.random.Generator(np.random.PCG64(seed))


# Arrivals are drawn in chunks of this many exponential gaps at a time; the
# value only trades numpy call overhead against overshoot past the horizon,
# it does not affect the emitted timestamps.
_CHUNK = 4096


# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PoissonTraffic:
    """Homogeneous Poisson arrivals at ``rate`` requests/second.

    Gaps are drawn ``_CHUNK`` at a time and accumulated with a *carry-in*
    cumsum: the running timestamp is written into slot 0 of the work buffer
    so ``np.cumsum`` performs exactly the same left-to-right additions as
    the scalar ``t += gap`` loop it replaced.  (The naive
    ``t + np.cumsum(gaps)`` form is NOT bit-exact — it reassociates the
    carry addition and drifts by 1 ulp at chunk boundaries.)  A PCG64
    ``Generator`` consumes the identical stream for ``exponential(s)``
    scalar draws and one ``exponential(s, size=n)`` array draw, so the
    emitted timestamps are bit-for-bit those of the sequential loop;
    ``tests/test_event_engine.py`` pins this against an inline scalar
    reference.
    """

    rate: float
    seed: int = 0

    def arrivals(self, horizon: float) -> list[float]:
        if self.rate <= 0:
            return []
        rng = _rng(self.seed)
        scale = 1.0 / self.rate
        out: list[float] = []
        buf = np.empty(_CHUNK + 1)
        t = 0.0
        while True:
            buf[0] = t
            buf[1:] = rng.exponential(scale, size=_CHUNK)
            ts = np.cumsum(buf)[1:]
            cut = int(np.searchsorted(ts, horizon, side="left"))
            out.extend(ts[:cut].tolist())
            if cut < _CHUNK:
                return out
            t = ts[-1]


@dataclasses.dataclass(frozen=True)
class MMPPTraffic:
    """2-state Markov-modulated Poisson process (bursty traffic).

    The process alternates between a *calm* state (``rate_low``) and a
    *burst* state (``rate_high``); sojourn times in each state are
    exponential with means ``mean_calm`` / ``mean_burst`` seconds.
    """

    rate_low: float
    rate_high: float
    mean_calm: float = 5.0
    mean_burst: float = 1.0
    seed: int = 0

    def arrivals(self, horizon: float) -> list[float]:
        # Stays sequential: each draw's distribution depends on the current
        # state, and state flips are decided by comparing against the drawn
        # gap — the stream cannot be pre-drawn in chunks without changing
        # which variates land where.
        rng = _rng(self.seed)
        out: list[float] = []
        t = 0.0
        burst = False
        state_end = rng.exponential(self.mean_calm)
        while t < horizon:
            rate = self.rate_high if burst else self.rate_low
            dt = rng.exponential(1.0 / rate) if rate > 0 else math.inf
            if t + dt < state_end:
                t += dt
                if t < horizon:
                    out.append(t)
            else:
                t = state_end
                burst = not burst
                state_end = t + rng.exponential(self.mean_burst if burst else self.mean_calm)
        return out


@dataclasses.dataclass(frozen=True)
class DiurnalTraffic:
    """Inhomogeneous Poisson with a sinusoidal day/night rate profile.

    ``lambda(t) = base_rate + (peak_rate - base_rate) * (1 - cos(2*pi*t/period)) / 2``
    starts at the ``base_rate`` trough, peaks at ``period/2``.  Sampled by
    thinning against the ``peak_rate`` envelope (Lewis & Shedler), so the
    output is exact for the profile, not a stepwise approximation.
    """

    base_rate: float
    peak_rate: float
    period: float = 60.0
    seed: int = 0

    def rate_at(self, t: float) -> float:
        swing = (1.0 - math.cos(2.0 * math.pi * t / self.period)) / 2.0
        return self.base_rate + (self.peak_rate - self.base_rate) * swing

    def arrivals(self, horizon: float) -> list[float]:
        # Stays sequential: thinning interleaves exponential and uniform
        # draws per candidate, so chunked array draws would consume the
        # PCG64 stream in a different order and change the trace.
        lam_max = max(self.peak_rate, self.base_rate)
        if lam_max <= 0:
            return []
        rng = _rng(self.seed)
        out: list[float] = []
        t = 0.0
        while True:
            t += rng.exponential(1.0 / lam_max)
            if t >= horizon:
                return out
            if rng.uniform() * lam_max <= self.rate_at(t):
                out.append(t)


def interarrival_cv2(times: Sequence[float]) -> float:
    """Squared coefficient of variation of a trace's inter-arrival times.

    The burstiness statistic the MMPP fit keys on: a Poisson stream has
    CV^2 = 1, a Markov-modulated one (calm/burst switching) pushes it above.
    Returns 1.0 for traces too short to estimate (< 3 arrivals).
    """
    if len(times) < 3:
        return 1.0
    gaps = [b - a for a, b in zip(times, times[1:])]
    mean = sum(gaps) / len(gaps)
    if mean <= 0:
        return 1.0
    var = sum((g - mean) ** 2 for g in gaps) / len(gaps)
    return var / (mean * mean)


@dataclasses.dataclass(frozen=True)
class ReplayTraffic:
    """Replays an explicit, frozen timestamp trace."""

    times: tuple[float, ...]

    @classmethod
    def record(cls, gen: TrafficGenerator, horizon: float) -> "ReplayTraffic":
        """Freeze any generator's output into a replayable trace."""
        return cls(times=tuple(gen.arrivals(horizon)))

    def arrivals(self, horizon: float) -> list[float]:
        return [t for t in self.times if t < horizon]

    def save(self, path: str | Path) -> Path:
        p = Path(path)
        p.write_text(json.dumps(list(self.times)))
        return p

    @classmethod
    def load(cls, path: str | Path) -> "ReplayTraffic":
        return cls(times=tuple(json.loads(Path(path).read_text())))

    def fit_mmpp(
        self,
        horizon: float | None = None,
        window: float | None = None,
        cv2_threshold: float = 1.15,
        seed: int = 0,
    ) -> MMPPTraffic:
        """Calibrate a 2-state MMPP to this recorded trace (moments fit).

        Method of moments on the trace's burstiness statistics, so synthetic
        load can be matched to a production arrival log:

          1. The inter-arrival CV^2 (:func:`interarrival_cv2`) gates the
             model: at or below ``cv2_threshold`` the trace is Poisson-like
             and the fit degenerates to ``rate_low == rate_high == n/T``
             (an MMPP whose states are indistinguishable).
          2. Otherwise arrivals are counted in windows of length ``window``
             (default: sized for ~8 expected arrivals, enough signal to
             separate the states) and windows are classified calm/burst by
             thresholding at the mean count — the two conditional first
             moments give ``rate_low``/``rate_high``, and the mean run
             lengths of consecutive same-class windows give the exponential
             sojourn means ``mean_calm``/``mean_burst``.

        Deterministic; the returned generator replays nothing — it is a
        fresh seeded process whose statistics match the recording.
        """
        times = sorted(self.times)
        if horizon is not None:
            # fit the horizon prefix: arrivals past an explicit (exclusive)
            # horizon would otherwise inflate the mean rate and pile into
            # the last counting window as a spurious burst
            times = [t for t in times if t < horizon]
        T = horizon if horizon is not None else (times[-1] if times else 0.0)
        if T <= 0 or len(times) < 4:
            rate = len(times) / T if T > 0 else 0.0
            return MMPPTraffic(rate_low=rate, rate_high=rate, seed=seed)
        rate_mean = len(times) / T
        if interarrival_cv2(times) <= cv2_threshold:
            return MMPPTraffic(rate_low=rate_mean, rate_high=rate_mean, seed=seed)
        w = window if window is not None else 8.0 / rate_mean
        n_win = max(2, int(math.ceil(T / w)))
        counts = [0] * n_win
        for t in times:
            counts[min(int(t / w), n_win - 1)] += 1
        mean_count = sum(counts) / n_win
        burst = [c > mean_count for c in counts]
        if all(burst) or not any(burst):  # threshold failed to split: flat
            return MMPPTraffic(rate_low=rate_mean, rate_high=rate_mean, seed=seed)
        n_burst = sum(burst)
        arr_burst = sum(c for c, b in zip(counts, burst) if b)
        arr_calm = sum(c for c, b in zip(counts, burst) if not b)
        rate_high = arr_burst / (n_burst * w)
        rate_low = arr_calm / ((n_win - n_burst) * w)
        # mean sojourn = window length x mean run of same-class windows
        runs: dict[bool, list[int]] = {True: [], False: []}
        length = 1
        for prev, cur in zip(burst, burst[1:]):
            if cur == prev:
                length += 1
            else:
                runs[prev].append(length)
                length = 1
        runs[burst[-1]].append(length)
        mean_burst = w * sum(runs[True]) / len(runs[True])
        mean_calm = w * sum(runs[False]) / len(runs[False])
        return MMPPTraffic(
            rate_low=rate_low,
            rate_high=rate_high,
            mean_calm=mean_calm,
            mean_burst=mean_burst,
            seed=seed,
        )
