"""``repro.serve`` — discrete-event serving on top of Shisha scheduling.

The paper's algorithms answer "which configuration is fastest at steady
state"; this subsystem answers the production question layered on top:
"what latency do users see under live, drifting traffic, and when is it
worth paying Algorithm 2's online exploration cost again?".

  * :mod:`.traffic`      — seeded arrival processes (Poisson, bursty MMPP,
                           diurnal, replayable traces).
  * :mod:`.simulator`    — event-driven pipeline server over the evaluator
                           stage-time model: queues, micro-batching, tail
                           latency, SLO accounting, EP occupancy.
  * :mod:`.autotuner`    — continuous Shisha: drift detection and
                           mid-flight re-tuning charged to the simulated
                           clock.
  * :mod:`.multitenant`  — disjoint EP partitioning for co-scheduling
                           several pipelines on one platform.
"""

from .autotuner import (
    ContinuousShisha,
    Drift,
    DriftDetector,
    Retune,
    drifted_platform,
)
from .multitenant import (
    PARTITION_STRATEGIES,
    Tenant,
    TenantResult,
    co_schedule,
    compare_partitions,
    partition_eps,
    subplatform,
)
from .simulator import (
    Request,
    ServingSimulator,
    SimResult,
    percentile,
    slo_violation_rate,
)
from .traffic import (
    DiurnalTraffic,
    MMPPTraffic,
    PoissonTraffic,
    ReplayTraffic,
    TrafficGenerator,
)

__all__ = [
    "ContinuousShisha",
    "DiurnalTraffic",
    "Drift",
    "DriftDetector",
    "MMPPTraffic",
    "PARTITION_STRATEGIES",
    "PoissonTraffic",
    "ReplayTraffic",
    "Request",
    "Retune",
    "ServingSimulator",
    "SimResult",
    "Tenant",
    "TenantResult",
    "TrafficGenerator",
    "co_schedule",
    "compare_partitions",
    "drifted_platform",
    "partition_eps",
    "percentile",
    "slo_violation_rate",
    "subplatform",
]
