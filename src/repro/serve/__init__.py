"""``repro.serve`` — discrete-event serving on top of Shisha scheduling.

The paper's algorithms answer "which configuration is fastest at steady
state"; this subsystem answers the production question layered on top:
"what latency do users see under live, drifting traffic, and when is it
worth paying Algorithm 2's online exploration cost again?".

  * :mod:`.traffic`      — seeded arrival processes (Poisson, bursty MMPP,
                           diurnal, replayable traces).
  * :mod:`.simulator`    — event-driven pipeline server over the evaluator
                           stage-time model: queues, micro-batching, tail
                           latency, SLO accounting, EP occupancy.
  * :mod:`.autotuner`    — continuous Shisha: drift detection, mid-flight
                           re-tuning and batch-knob search charged to the
                           simulated clock.
  * :mod:`.multitenant`  — disjoint EP partitioning plus the shared-clock
                           elastic co-simulator: all tenants on one
                           discrete-event timeline, with mid-flight EP
                           re-allocation under faults.
"""

from .autotuner import (
    DRIFT_KINDS,
    ContinuousShisha,
    Drift,
    DriftDetector,
    Retune,
    drifted_platform,
    tune_batch_policy,
)
from .multitenant import (
    PARTITION_STRATEGIES,
    CoServeResult,
    ElasticPartitioner,
    RepartitionEvent,
    SharedClockCoSimulator,
    Tenant,
    TenantResult,
    co_schedule,
    co_serve,
    compare_partitions,
    partition_eps,
    subplatform,
)
from .simulator import (
    EventLoop,
    HeapEventLoop,
    Replatform,
    Request,
    ServingSimulator,
    SimResult,
    percentile,
    slo_violation_rate,
)
from .traffic import (
    DiurnalTraffic,
    MMPPTraffic,
    PoissonTraffic,
    ReplayTraffic,
    TrafficGenerator,
    interarrival_cv2,
)

__all__ = [
    "CoServeResult",
    "ContinuousShisha",
    "DRIFT_KINDS",
    "DiurnalTraffic",
    "Drift",
    "DriftDetector",
    "ElasticPartitioner",
    "EventLoop",
    "HeapEventLoop",
    "MMPPTraffic",
    "PARTITION_STRATEGIES",
    "PoissonTraffic",
    "RepartitionEvent",
    "Replatform",
    "ReplayTraffic",
    "Request",
    "Retune",
    "ServingSimulator",
    "SharedClockCoSimulator",
    "SimResult",
    "Tenant",
    "TenantResult",
    "TrafficGenerator",
    "co_schedule",
    "co_serve",
    "compare_partitions",
    "drifted_platform",
    "interarrival_cv2",
    "partition_eps",
    "percentile",
    "slo_violation_rate",
    "subplatform",
    "tune_batch_policy",
]
