"""Continuous Shisha: drift detection + mid-flight re-tuning.

Static Shisha tunes once against a steady-state oracle and stops; this
module closes the loop the paper's "online" framing implies.  A
:class:`DriftDetector` watches the per-stage times a monitor observes and
classifies drift into the closed :data:`DRIFT_KINDS` set:

  * ``dropout``    — an EP the configuration uses has died (the paper's
                     elastic-rescale case, cf. ``runtime.fault.ElasticScheduler``);
  * ``slowdown``   — a runtime derate (:class:`~repro.pipeline.hetero.EPDerates`)
                     on an in-use EP crossed a threshold (straggler, cf.
                     ``runtime.fault.StragglerMitigator``);
  * ``throttle``   — the derate on an in-use EP *oscillates* (engage /
                     release / re-engage): the signature of hysteretic
                     thermal throttling (:mod:`repro.power.thermal`), not a
                     sick host.  The detector learns this from its bounded
                     per-EP derate history, so the first engagement is
                     conservatively classified as a slowdown;
  * ``link-loss``  — a stage-boundary transfer can no longer complete: an
                     observed stage time is *infinite* while its EP is alive,
                     the signature of fabric link faults severing the route
                     (the chaos layer's :mod:`repro.faults` injects these).
                     Answered with a placement rescue: EPs marooned by the
                     partition are buried like dead ones and Algorithm 2
                     runs with relocation moves forced on, so the stranded
                     stage is re-hosted inside the surviving component;
  * ``imbalance``  — the bottleneck shifted: max/median observed stage time
                     exceeds a threshold even without an attributable derate.

A further kind, ``recovery``, is raised by :class:`ContinuousShisha` itself
when the drift state *eases* (a derate shrinks or a dead EP revives): the
detector only sees degradation, but recovered hardware is worth re-seeding
for — the current schedule was tuned around it.

Responses differ by kind: ``throttle`` takes a cheap fast path — a DVFS
step-down of the hot EPs (one paid measurement, configuration unchanged)
when the platform carries a :class:`~repro.power.PowerModel` with frequency
headroom — because a full Algorithm 2 re-tune would chase a moving target:
the throttle clears as the chiplet cools and re-engages as it reheats.
Every other kind runs the full exploration below.

On drift, :class:`ContinuousShisha` rebuilds its *model* platform (original
EP specs scaled by the observed derates, dead EPs buried at the bottom of
the H_e ranking so Algorithm 1 never picks them), re-runs ``core.tune`` —
warm-starting from the current configuration for slowdowns exactly as the
paper's online regime intends, re-seeding via Algorithm 1 when the current
configuration references a dead EP — and returns a :class:`Retune` that
charges the **full simulated exploration wall-clock** (``Trace.wall``:
reconfiguration overhead plus ``measure_batches`` beats per trial) to the
simulated clock: the old configuration keeps serving, degraded, until the
exploration window elapses, then one reconfiguration ``downtime`` stalls
admission while the new configuration is installed.  Cheap exploration
(Shisha's whole point) translates directly into earlier recovery.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, FrozenSet, Sequence

from ..core.config import PipelineConfig
from ..core.cost_model import Layer, weights as layer_weights
from ..core.evaluator import AnalyticEvaluator, Trace
from ..core.platform import Platform
from ..core.seed import generate_seed
from ..core.tuner import Balancing, TuneResult, tune
from ..pipeline.hetero import EPDerates

#: perf_class used to bury dead EPs at the bottom of Platform.ranked()
_DEAD_CLASS = 99


def drifted_platform(platform: Platform, drift: EPDerates, dead: FrozenSet[int] | set = frozenset()) -> Platform:
    """The scheduler's *model* of the drifted machine.

    Slowed EPs get their compute and bandwidth divided by the drift factor
    and, when slowed >1.25x, are demoted *below every healthy class* (not
    just one step, which would merely tie them with the SEPs when all FEPs
    throttle at once); dead EPs keep their index — so configurations stay
    comparable — but rank last and near-zero, so Algorithm 1 seeds around
    them.
    """
    worst_healthy = max(ep.perf_class for ep in platform.eps)
    eps = []
    for i, ep in enumerate(platform.eps):
        f = drift.factors[i] if i < len(drift.factors) else 1.0
        if i in dead:
            eps.append(
                dataclasses.replace(
                    ep, flops_per_core=1e-9, mem_bw=1e-9, perf_class=_DEAD_CLASS
                )
            )
        elif f > 1.0:
            eps.append(
                dataclasses.replace(
                    ep,
                    flops_per_core=ep.flops_per_core / f,
                    mem_bw=ep.mem_bw / f,
                    perf_class=worst_healthy + 1 if f > 1.25 else ep.perf_class,
                )
            )
        else:
            eps.append(ep)
    return dataclasses.replace(platform, name=f"{platform.name}~drift", eps=tuple(eps))


#: the closed set of drift classifications.  Validated in
#: :meth:`Drift.__post_init__`, so growing the taxonomy (as ``"throttle"``
#: did) is a checked change here rather than a stringly-typed drive-by.
DRIFT_KINDS = frozenset(
    {"dropout", "slowdown", "throttle", "imbalance", "recovery", "link-loss"}
)


@dataclasses.dataclass
class Drift:
    #: one of :data:`DRIFT_KINDS`
    kind: str
    detail: str
    #: EP indices implicated, when attributable per-EP (throttle/slowdown)
    eps: tuple[int, ...] = ()

    def __post_init__(self):
        if self.kind not in DRIFT_KINDS:
            raise ValueError(
                f"unknown drift kind {self.kind!r}; known: {sorted(DRIFT_KINDS)}"
            )


@dataclasses.dataclass
class DriftDetector:
    """Classifies observed stage times + derates into a drift event.

    Bottleneck shift is judged against *expected* stage times (the model's
    prediction for the current configuration), not against the other
    stages: a well-tuned heterogeneous pipeline is legitimately imbalanced
    (a single heavy layer on its own EP), and only deviation from the
    model indicates drift.
    """

    slowdown_threshold: float = 1.3
    imbalance_threshold: float = 1.5
    #: derate samples kept per EP for oscillation (throttle) classification
    throttle_window: int = 6

    def __post_init__(self):
        self._factor_history: dict[int, deque] = {}

    def _record(self, factors: Sequence[float]) -> None:
        for ep, f in enumerate(factors):
            h = self._factor_history.get(ep)
            if h is None:
                h = self._factor_history[ep] = deque(maxlen=self.throttle_window)
            h.append(f)

    def _oscillating(self, ep: int) -> bool:
        """The EP's derate history shows at least one rise AND one fall.

        A step slowdown only ever rises (then holds); hysteretic thermal
        throttling engages, releases, re-engages — the direction reversal
        is its fingerprint.  Needs three samples, so the first engagement
        is conservatively classified as a slowdown: the detector *learns*
        the oscillation.
        """
        h = self._factor_history.get(ep)
        if h is None or len(h) < 3:
            return False
        rose = fell = False
        prev = None
        for f in h:
            if prev is not None:
                if f > prev + 1e-9:
                    rose = True
                elif f < prev - 1e-9:
                    fell = True
            prev = f
        return rose and fell

    def detect(
        self,
        conf: PipelineConfig,
        observed_times: Sequence[float],
        drift: EPDerates,
        dead: FrozenSet[int],
        expected_times: Sequence[float] | None = None,
    ) -> Drift | None:
        self._record(drift.factors)
        dead_in_use = [ep for ep in conf.eps if ep in dead]
        if dead_in_use:
            return Drift("dropout", f"dead EPs in use: {dead_in_use}", eps=tuple(dead_in_use))
        # an infinite observed stage time on a *live* EP means the stage's
        # boundary transfer can never complete: a fabric link fault severed
        # the route (dead EPs were caught above, so this is unambiguous)
        severed = [
            s
            for s, obs in enumerate(observed_times)
            if math.isinf(obs) and conf.eps[s] not in dead
        ]
        if severed:
            return Drift(
                "link-loss",
                f"stage boundaries severed by link faults at stages {severed}",
                eps=tuple(conf.eps[s] for s in severed),
            )
        # a factors tuple may be shorter than the platform (e.g. a stale
        # monitor snapshot after an elastic re-partition grew the EP set);
        # missing entries mean "no derate observed", exactly like
        # drifted_platform's bounds check
        slowed = [
            ep
            for ep in conf.eps
            if (drift.factors[ep] if ep < len(drift.factors) else 1.0)
            > self.slowdown_threshold
        ]
        if slowed:
            throttling = [ep for ep in slowed if self._oscillating(ep)]
            if throttling and len(throttling) == len(slowed):
                return Drift(
                    "throttle",
                    f"oscillating derate on EPs {throttling} (thermal signature)",
                    eps=tuple(throttling),
                )
            return Drift("slowdown", f"derated EPs in use: {slowed}", eps=tuple(slowed))
        if expected_times is not None and len(expected_times) == len(observed_times):
            worst, stage = 1.0, None
            for s, (obs, exp) in enumerate(zip(observed_times, expected_times)):
                if math.isfinite(obs) and exp > 0 and obs / exp > worst:
                    worst, stage = obs / exp, s
            if worst > self.imbalance_threshold:
                return Drift("imbalance", f"stage {stage} at {worst:.2f}x its model time")
        return None


@dataclasses.dataclass
class Retune:
    """Decision handed to the simulator: new conf + its simulated-time cost.

    ``tuning_cost`` is Algorithm 2's exploration wall-clock (``Trace.wall``);
    during that window the pipeline keeps serving on the *old* configuration
    — the paper's measurement batches are real traffic — and only the final
    ``downtime`` (weights shipped to their new EPs) stalls admission.

    ``kind`` is the :data:`DRIFT_KINDS` classification that triggered the
    re-tune — ``"dropout"`` / ``"slowdown"`` / ``"throttle"`` /
    ``"imbalance"`` / ``"recovery"`` — or ``"repartition"`` when an elastic
    co-simulator forced it after moving the EP partition itself (no drift
    event; the schedule is simply for the wrong machine).  A ``"throttle"``
    retune keeps ``conf`` unchanged and carries the stepped-down
    ``dvfs_levels`` instead.
    """

    conf: PipelineConfig
    #: seconds of exploration during which the old conf keeps serving
    tuning_cost: float
    #: seconds of full stall while the new conf is installed
    downtime: float
    kind: str
    model_throughput: float
    tune_result: TuneResult
    #: per-stage max micro-batch found by the batch-knob search (None keeps
    #: the simulator's flat ``max_batch``)
    batch_policy: tuple[int, ...] | None = None
    #: per-EP DVFS level vector to install with the new configuration
    #: (None leaves the power model's current levels in force)
    dvfs_levels: tuple[int, ...] | None = None

    @property
    def cost(self) -> float:
        return self.tuning_cost + self.downtime


def tune_batch_policy(
    trace: Trace,
    conf: PipelineConfig,
    slo: float,
    *,
    batch_efficiency: float = 0.7,
    max_batch_cap: int = 8,
    latency_margin: float = 0.5,
) -> tuple[int, ...]:
    """Per-stage ``max_batch`` search, explored alongside Algorithm 2 moves.

    The simulator serves a batch of ``b`` in ``t_stage * (1 + (b-1) * eff)``,
    so a stage's effective per-request capacity is ``b / batched_t`` — larger
    batches amortise the beat exactly like larger measure batches amortise
    reconfiguration in :class:`~repro.core.evaluator.Trace`, at the price of
    latency.  Starting from all-1, stages are visited bottleneck-first and
    each is granted the largest power-of-two batch whose *full-batch* pipeline
    latency stays within ``latency_margin * slo`` (the remaining margin is
    queueing headroom).  Every knob candidate tried is a real online trial:
    it is charged to ``trace`` (reconfig + fill + ``measure_batches`` beats)
    so the exploration shows up in ``Trace.wall`` like any Algorithm 2 move.
    """
    times = trace.evaluator.stage_times(conf)
    policy = [1] * conf.depth

    def batched(s: int, b: int) -> float:
        return times[s] * (1.0 + (b - 1) * batch_efficiency)

    candidates = []
    b = 2
    while b <= max_batch_cap:
        candidates.append(b)
        b *= 2
    # bottleneck-first: the slowest stage gets latency headroom before the
    # cheap stages spend it (ties broken by stage index for determinism)
    for s in sorted(range(conf.depth), key=lambda i: (-times[i], i)):
        for b in candidates:
            lat = sum(
                batched(i, b if i == s else policy[i]) for i in range(conf.depth)
            )
            if lat > latency_margin * slo:
                break
            trace.execute(conf)  # trying the knob online costs a measurement
            if b / batched(s, b) > policy[s] / batched(s, policy[s]):
                policy[s] = b
    return tuple(policy)


@dataclasses.dataclass
class ContinuousShisha:
    """The ``observe()`` hook a :class:`~repro.serve.simulator.ServingSimulator` polls.

    Re-tunes at most once per distinct drift state (fingerprinted by the
    derate vector + dead set) and not more often than ``cooldown`` simulated
    seconds, so a persistent derate does not trigger a re-tune storm.
    """

    platform: Platform
    layers: Sequence[Layer]
    #: model-evaluator factory for the tuner's Trace (e.g. DatabaseEvaluator)
    make_evaluator: Callable[[Platform], AnalyticEvaluator] | None = None
    detector: DriftDetector = dataclasses.field(default_factory=DriftDetector)
    alpha: int = 10
    balancing: Balancing = "nlfep"
    #: charged once on top of Trace.wall when the new conf is installed
    reconfig_downtime: float = 0.05
    #: minimum simulated seconds between re-tunes
    cooldown: float = 1.0
    measure_batches: int = 8
    reconfig_overhead: float = 0.05
    #: when set, every re-tune also runs the per-stage batch-knob search
    #: (:func:`tune_batch_policy`) against this latency SLO, charging the
    #: extra trials to the same exploration window
    slo: float | None = None
    batch_policy_search: bool = False
    max_batch_cap: int = 8
    batch_efficiency: float = 0.7
    batch_latency_margin: float = 0.5
    #: enable Algorithm 2's fabric-aware EP-relocation moves in re-tunes
    placement: bool = False
    #: explore per-EP DVFS levels in re-tunes (needs a platform power
    #: model); independent of the throttle fast path, which only needs the
    #: power model itself
    dvfs: bool = False
    #: live co-tenant flow set (node-space) the *model* evaluator prices
    #: transfers against — set by a contention-aware co-simulator each
    #: monitor window; empty = contention-blind tuning
    background_flows: tuple = ()
    #: live telemetry session or None, normally attached by the owning
    #: :class:`~repro.serve.simulator.ServingSimulator`; handed to every
    #: exploration :class:`~repro.core.evaluator.Trace` so each paid trial
    #: records its charged wall cost and move kind
    telemetry: "object | None" = None

    def __post_init__(self):
        if self.make_evaluator is None:
            self.make_evaluator = lambda p: AnalyticEvaluator(p, self.layers)
        self._last_t = -math.inf
        # start from the no-drift state so the intrinsic imbalance of a
        # freshly tuned heterogeneous pipeline never triggers a re-tune
        self._handled: tuple = (
            (1.0,) * self.platform.n_eps,
            frozenset(),
            self._fabric_key(),
        )
        self._model_ev = self.make_evaluator(self.platform)
        self.history: list[Retune] = []
        #: kind of the last response issued; a throttle's subsequent easing
        #: is the step-down working, not hardware worth re-seeding for
        self._last_kind: str | None = None

    def _fabric_key(self) -> tuple:
        """Canonical link-fault state of the platform fabric (``()`` healthy).

        Folded into the drift fingerprint: a link failure changes neither
        the derate vector nor the dead set, so without this the tuner would
        be blind to the one drift class that lives in the fabric.
        """
        fabric = self.platform.fabric
        return fabric.fault_fingerprint() if fabric is not None else ()

    def observe(
        self,
        t: float,
        conf: PipelineConfig,
        observed_times: Sequence[float],
        drift: EPDerates,
        dead: FrozenSet[int],
    ) -> Retune | None:
        fingerprint = (drift.factors, frozenset(dead), self._fabric_key())
        if fingerprint == self._handled:
            return None
        expected = self._model_ev.stage_times(conf)
        event = self.detector.detect(conf, observed_times, drift, dead, expected)
        if event is None:
            # the detector only sees degradation; an *easing* fingerprint
            # (derate shrank, dead EP revived, link healed) is a chance to
            # reclaim hardware the current schedule tuned around
            prev_factors, prev_dead, prev_links = self._handled
            eased = any(
                f < pf - 1e-9 for f, pf in zip(drift.factors, prev_factors)
            )
            revived = bool(set(prev_dead) - set(dead))
            cur_links = dict(fingerprint[2])
            healed = any(
                cur_links.get(k, 1.0) > f for k, f in sorted(dict(prev_links).items())
            )
            if (eased or revived) and self._last_kind == "throttle" and not revived:
                # expected easing: the DVFS step-down (or the cooling it
                # bought) cleared the throttle derate — re-seeding for it
                # would thrash against the thermal cycle
                self._handled = fingerprint
                return None
            if eased or revived or healed:
                event = Drift("recovery", "platform sped up; re-seeding to reclaim it")
        if event is None:
            # benign drift (e.g. an unused EP derated): remember and move on
            self._handled = fingerprint
            return None
        if t - self._last_t < self.cooldown:
            return None
        if event.kind == "throttle":
            retune = self._dvfs_stepdown(event, drift, dead, conf)
            if retune is not None:
                self._last_t = t
                self._handled = fingerprint
                self._last_kind = "throttle"
                return retune
            # no power model or no frequency headroom left: fall through to
            # the full re-tune, which can move work off the hot chiplet
        explore_dead = frozenset(dead)
        if event.kind == "link-loss" and self.platform.fabric is not None:
            # placement rescue: EPs marooned outside the main fabric
            # component are buried like dead ones, so the seed and every
            # relocation move avoid them until the link heals
            explore_dead = explore_dead | frozenset(self.platform.fabric.marooned_eps())
        retune = self._explore(drift, explore_dead, event.kind, warm_conf=conf)
        self._last_t = t
        self._handled = fingerprint
        self._last_kind = event.kind
        return retune

    def _dvfs_stepdown(
        self,
        event: Drift,
        drift: EPDerates,
        dead: FrozenSet[int],
        warm_conf: PipelineConfig,
    ) -> Retune | None:
        """Throttle fast path: drop the hot EPs one DVFS level.

        One paid measurement at the new clocks instead of a full Algorithm 2
        exploration — the configuration is untouched, only the frequency
        vector moves.  Returns None (caller escalates to :meth:`_explore`)
        when the platform has no power model or every implicated EP is
        already at its floor.
        """
        pm = self.platform.power
        if pm is None:
            return None
        hot = [ep for ep in event.eps if ep < pm.n_eps and pm.can_step_down(ep)]
        if not hot:
            return None
        # price the step-down on a model where the throttle derate on the
        # stepped EPs is cleared — removing it is the point of stepping down
        hot_set = set(hot)
        relieved = EPDerates(
            factors=tuple(
                1.0 if i in hot_set else f for i, f in enumerate(drift.factors)
            )
        )
        model = drifted_platform(self.platform, relieved, dead)
        model_ev = self.make_evaluator(model)
        if self.background_flows and model.fabric is not None:
            model_ev.background_flows = tuple(self.background_flows)
        trace = Trace(
            model_ev,
            measure_batches=self.measure_batches,
            reconfig_overhead=self.reconfig_overhead,
            telemetry=self.telemetry,
        )
        for ep in hot:
            pm.set_level(ep, pm.level(ep) + 1)
        tp = trace.execute(warm_conf)  # one paid measurement at the new clocks
        tl = self.telemetry
        if tl is not None and tl.enabled:
            tl.counter("tune.moves.dvfs_down").inc(len(hot))
        levels = pm.snapshot()
        result = TuneResult(
            best_conf=warm_conf,
            best_throughput=tp,
            n_explored=trace.n_trials,
            final_conf=warm_conf,
            dvfs_levels=levels,
        )
        self._model_ev = model_ev
        retune = Retune(
            conf=warm_conf,
            tuning_cost=trace.wall,
            downtime=self.reconfig_downtime,
            kind="throttle",
            model_throughput=tp,
            tune_result=result,
            dvfs_levels=levels,
        )
        self.history.append(retune)
        return retune

    def _explore(
        self,
        drift: EPDerates,
        dead: FrozenSet[int],
        kind: str,
        warm_conf: PipelineConfig | None = None,
    ) -> Retune:
        """Run Algorithm 2 (plus the batch-knob search) on the drift model."""
        model = drifted_platform(self.platform, drift, dead)
        model_ev = self.make_evaluator(model)
        if self.background_flows and model.fabric is not None:
            # contention-aware: the model prices transfers under the live
            # co-tenant flow set, so exploration sees congested links as
            # slow and routes/places around them
            model_ev.background_flows = tuple(self.background_flows)
        trace = Trace(
            model_ev,
            measure_batches=self.measure_batches,
            reconfig_overhead=self.reconfig_overhead,
            telemetry=self.telemetry,
        )
        # a link-loss rescue *must* be allowed to relocate stages — boundary
        # moves alone can never re-host a stage marooned across a dead link
        placement = self.placement or kind == "link-loss"
        if kind in ("dropout", "recovery", "repartition", "link-loss") or warm_conf is None:
            # re-seed via Algorithm 1: a warm start cannot drop a dead (or
            # marooned) EP's stage by itself, nor grow stages onto recovered
            # (or newly granted) hardware
            n_alive = model.n_eps - len(dead)
            if n_alive < 1:
                raise RuntimeError("all EPs dead; nothing to schedule onto")
            seed = generate_seed(
                layer_weights(self.layers),
                model,
                n_stages=min(n_alive, len(self.layers)),
                choice="rank_w",
            )
            result = tune(
                seed,
                trace,
                alpha=self.alpha,
                balancing=self.balancing,
                placement=placement,
                placement_exclude=frozenset(dead),
                dvfs=self.dvfs,
            )
        else:
            # warm start from the serving configuration (paper's online mode)
            result = tune(
                warm_conf,
                trace,
                alpha=self.alpha,
                balancing=self.balancing,
                placement=placement,
                placement_exclude=frozenset(dead),
                dvfs=self.dvfs,
            )
        policy = None
        if self.batch_policy_search and self.slo is not None:
            policy = tune_batch_policy(
                trace,
                result.best_conf,
                self.slo,
                batch_efficiency=self.batch_efficiency,
                max_batch_cap=self.max_batch_cap,
                latency_margin=self.batch_latency_margin,
            )
        self._model_ev = trace.evaluator  # new model baseline for drift checks
        retune = Retune(
            conf=result.best_conf,
            tuning_cost=trace.wall,
            downtime=self.reconfig_downtime,
            kind=kind,
            model_throughput=result.best_throughput,
            tune_result=result,
            batch_policy=policy,
            dvfs_levels=result.dvfs_levels,
        )
        self.history.append(retune)
        return retune

    def retarget(
        self,
        platform: Platform,
        make_evaluator: Callable[[Platform], AnalyticEvaluator] | None = None,
    ) -> None:
        """Point the tuner at a new (sub-)platform after a re-partition.

        The drift fingerprint baseline resets to the new platform's no-drift
        state; callers that immediately :meth:`force_retune` will overwrite
        it with the actual observed state.
        """
        self.platform = platform
        if make_evaluator is not None:
            self.make_evaluator = make_evaluator
        self._handled = ((1.0,) * platform.n_eps, frozenset(), self._fabric_key())
        self._model_ev = self.make_evaluator(platform)

    def force_retune(
        self,
        t: float,
        drift: EPDerates,
        dead: FrozenSet[int],
        kind: str = "repartition",
    ) -> Retune:
        """Unconditional re-seed + tune, bypassing the detector and cooldown.

        Used by the elastic multi-tenant co-simulator after a partition
        change: the EP set itself moved, so there is no drift *event* to
        detect — the schedule is simply for the wrong machine.  The full
        ``Trace.wall`` exploration cost is returned on the Retune for the
        caller to charge to its clock.
        """
        retune = self._explore(drift, dead, kind)
        self._last_t = t
        self._handled = (drift.factors, frozenset(dead), self._fabric_key())
        self._last_kind = kind
        return retune
