"""Discrete-event serving simulator for Shisha-scheduled pipelines.

The simulator drives a pipeline *configuration* (``PipelineConfig`` mapped
onto a ``Platform``) under live traffic and measures what the steady-state
oracle cannot: queueing delay, tail latency, SLO violations and the cost of
re-tuning while requests are in flight.

Model (paper terms in parentheses):

  * Each stage is a FIFO queue in front of its EP (chiplet).  Serving one
    request through a stage takes the evaluator's ``stage_times(conf)[s]``
    (the stage's share of the pipeline *beat*), optionally scaled by a
    runtime drift factor per EP (:class:`~repro.pipeline.hetero.EPDerates`
    — thermal throttling, sick host, shared-link neighbour).
  * Micro-batching: a stage may serve up to ``max_batch`` queued requests
    in one go; a batch of ``b`` takes ``t_stage * (1 + (b-1) *
    batch_efficiency)`` — ``batch_efficiency=1`` is pure serialisation,
    smaller values model amortised weight-streaming exactly like larger
    measure batches amortise reconfiguration in ``Trace``.
  * Faults are scripted on the simulated clock: ``schedule_slowdown`` (EP
    derate, the Fig. 9-style heterogeneity drift) and ``schedule_dropout``
    (EP death — its stage blocks and queues grow until a re-tune).
  * When the platform carries an interconnect fabric
    (:class:`~repro.interconnect.Fabric`), stage times include routed,
    contention-priced transfers; a co-simulator feeds each lane the other
    tenants' live activation flows every monitor window
    (:meth:`ServingSimulator.set_background_flows`), so co-tenant traffic
    congests shared links *on the event loop*, not just at tuning time.
  * Re-tuning (continuous Shisha, ``autotuner.py``) is observed through
    periodic monitor events.  When the autotuner decides to re-tune, the
    simulator *charges the full exploration wall-clock of Algorithm 2*
    (``Trace.wall`` — reconfiguration overhead plus ``measure_batches``
    beats per trial) to the simulated clock: the old configuration keeps
    serving (degraded) for that window, because the paper's measurement
    batches are real traffic, then the new configuration is installed
    under a short admission stall during which in-flight work is cancelled
    and mid-pipeline requests restart from stage 0 (drain-and-restart).
    This is exactly the online-cost regime Shisha is designed for — an
    expensive explorer would serve degraded for far longer before
    recovering.

  * When the platform carries a power model
    (:class:`~repro.power.PowerModel`), the simulator integrates energy on
    the simulated clock — dynamic joules over each EP's busy time, static
    leakage over the whole window — and, if a thermal model is attached,
    steps the per-chiplet RC nodes once per monitor window.  A chiplet that
    crosses its hot threshold throttles (stage times derate) until it
    cools; the throttle derate composes with the fault drift in the vector
    the autotuner observes, which is how ``"throttle"`` drift reaches the
    :class:`~repro.serve.autotuner.DriftDetector`.  Results gain a
    ``power`` block (joules/request, peak package watts); telemetry gains
    ``power.*``/``thermal.*`` metrics and per-chiplet temperature counter
    tracks.

Determinism: the simulator owns no randomness at all; all stochasticity
lives in the seeded ``traffic`` generators, so a (traffic, scenario) pair
replays bit-identically.

Event engine: :class:`EventLoop` is a drain-sorted engine (sort-once
buffers consumed in place, a small near heap for in-flight completions,
bulk arrival priming) that dispatches ~5x faster than the legacy binary
heap while preserving the ``(time, kind, push-order)`` contract exactly;
:class:`HeapEventLoop` keeps the legacy engine as the executable
reference and ``tests/test_event_engine.py`` pins the two bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from bisect import bisect_right
from collections import deque
from typing import Callable, Sequence

from ..core.config import PipelineConfig
from ..core.evaluator import AnalyticEvaluator
from ..pipeline.hetero import EPDerates
from ..telemetry import live
from ..telemetry.tracer import TraceEvent

# event kinds, in tie-break priority order at equal timestamps
_ARRIVAL, _DONE, _PLATFORM, _MONITOR, _RECONFIG = range(5)


class EventLoop:
    """Drain-sorted discrete-event engine, shareable by several pipelines.

    Every event carries its *owner* (the pipeline — or co-simulator — whose
    ``_dispatch`` handles it), so N tenants can advance on one clock: this
    is what makes the multi-tenant simulation a true co-simulation rather
    than N independent replays.  The monotonically increasing sequence
    number both breaks timestamp ties deterministically (push order) and
    guarantees owners are never compared by tuple ordering.

    Engine: events are plain ``(t, kind, seq, owner, payload)`` tuples in
    three structures instead of one big binary heap —

      * ``_staged`` — unsorted append-only list of events at or past the
        drain buffer's tail.  Every pre-run push lands here, and a bulk
        :meth:`push_batch` (arrival priming) is one C-level ``extend``.
      * ``_drain``  — the staged list, sorted **once** when the previous
        drain empties and then consumed *in place* by index (``_i``): a
        dispatch costs one list index instead of a log-N heap sift, and
        the records themselves are reused as the buffer (no copy).
      * ``_near``   — a small binary heap for events that sort *below* the
        drain tail, pushed while the drain is being consumed (in-flight
        ``_DONE`` completions, chained monitor ticks).  The hot loop
        interleaves it with the drain at one tuple compare per dispatch;
        with nothing in flight the check is a single truthiness test.

    Dispatch order is exactly the legacy heap engine's ``(time, kind,
    push-order)`` contract: at every step the dispatched event is the
    minimum over all live events, because staged events are by
    construction ``>=`` the whole undispatched drain and ``_near`` holds
    everything smaller.  :class:`HeapEventLoop` keeps the old engine as
    the executable reference; ``tests/test_event_engine.py`` pins the two
    bit-for-bit against each other on every simulator layer.

    Windowed runs: ``run(h)`` *peeks* before consuming, so an event past
    the horizon stays queued and successive ``run(h1), run(h2), ...``
    calls dispatch exactly what a single ``run(h_max)`` would.  (The
    legacy engine popped the first beyond-horizon event before breaking,
    silently dropping it for windowed callers — fixed in both engines.)
    """

    def __init__(self, telemetry=None):
        self._seq = 0
        self._staged: list = []
        self._drain: list = []
        self._i = 0  # next undispatched index into _drain
        self._near: list = []
        #: events dispatched over the loop's lifetime — the denominator of
        #: ``benchmarks/selfbench.py``'s simulated-events/sec figure
        self.n_dispatched = 0
        #: live telemetry session or None; when live, ``run`` keeps
        #: ``telemetry.now`` on the simulated clock and wall-profiles the
        #: dispatch loop under the ``event_loop.run`` scope
        self.telemetry = live(telemetry)

    def push(self, t: float, kind: int, owner, payload) -> None:
        self._seq += 1
        ev = (t, kind, self._seq, owner, payload)
        drain = self._drain
        # an event sorting below the active drain's tail must interleave
        # with it (the near heap); anything else waits in staged until the
        # next refill sort.  seq is unique, so the tuple compare never
        # reaches `owner`.
        if self._i < len(drain) and ev < drain[-1]:
            heapq.heappush(self._near, ev)
        else:
            self._staged.append(ev)

    def push_batch(self, times: Sequence[float], kind: int, owner, payloads: Sequence) -> None:
        """Push ``zip(times, payloads)`` sharing one kind/owner, in order.

        Equivalent to ``len(payloads)`` sequential :meth:`push` calls —
        same contiguous seq numbering, same dispatch order — minus the
        per-call overhead: outside an active drain (the arrival-priming
        case) the whole batch is one list ``extend``.
        """
        if self._i < len(self._drain):
            for t, p in zip(times, payloads):
                self.push(t, kind, owner, p)
            return
        seq = self._seq
        self._staged.extend(
            (t, kind, s, owner, p)
            for s, (t, p) in enumerate(zip(times, payloads), seq + 1)
        )
        self._seq = seq + len(payloads)

    def __len__(self) -> int:
        """Events still queued (staged + undispatched drain + near)."""
        return len(self._staged) + (len(self._drain) - self._i) + len(self._near)

    def run(self, horizon: float) -> None:
        """Dispatch events in (time, kind, push-order) order up to horizon.

        Peeks before consuming: an event past ``horizon`` stays queued, so
        windowed/incremental callers never lose it.
        """
        tl = self.telemetry
        if tl is None:
            self._advance(horizon, None)
            return
        with tl.timed("event_loop.run"):
            self._advance(horizon, tl)

    def _advance(self, horizon: float, tl) -> None:
        near = self._near
        heappop = heapq.heappop
        dispatched = 0
        try:
            while True:
                drain = self._drain
                i = self._i
                if i >= len(drain):
                    staged = self._staged
                    if staged:
                        # the staged list *becomes* the drain in place:
                        # one sort, no copy, no per-event bookkeeping
                        staged.sort()
                        self._drain = drain = staged
                        self._staged = []
                        self._i = i = 0
                    elif near:
                        # stragglers routed behind a now-exhausted drain
                        if near[0][0] > horizon:
                            break
                        t, kind, _seq, owner, payload = heappop(near)
                        dispatched += 1
                        if tl is not None:
                            tl.now = t
                        owner._dispatch(t, kind, payload)
                        continue
                    else:
                        break
                cut = (
                    len(drain)
                    if horizon == math.inf
                    else bisect_right(drain, (horizon, math.inf))
                )
                if cut <= i:
                    # rest of the drain is beyond the horizon: flush near
                    # events still inside it (all sort below drain[i]),
                    # then leave everything else queued
                    while near and near[0][0] <= horizon:
                        t, kind, _seq, owner, payload = heappop(near)
                        dispatched += 1
                        if tl is not None:
                            tl.now = t
                        owner._dispatch(t, kind, payload)
                    break
                if tl is None:
                    try:
                        while i < cut:
                            ev = drain[i]
                            if near and near[0] < ev:
                                ev = heappop(near)
                            else:
                                i += 1
                            t, kind, _seq, owner, payload = ev
                            owner._dispatch(t, kind, payload)
                            dispatched += 1
                    finally:
                        self._i = i
                else:
                    try:
                        while i < cut:
                            ev = drain[i]
                            if near and near[0] < ev:
                                ev = heappop(near)
                            else:
                                i += 1
                            t, kind, _seq, owner, payload = ev
                            tl.now = t
                            owner._dispatch(t, kind, payload)
                            dispatched += 1
                    finally:
                        self._i = i
        finally:
            self.n_dispatched += dispatched


class HeapEventLoop:
    """The legacy binary-heap engine, kept as the executable reference.

    Same API and — pinned by the equivalence suite — the same dispatch
    sequence as :class:`EventLoop`, paying one heap sift per event.  Use
    it to cross-check engine changes bit-for-bit
    (``tests/test_event_engine.py``, ``benchmarks/selfbench.py``'s legacy
    arms) or to bisect a suspected engine bug.  The historical
    beyond-horizon bug is fixed here too: ``run`` peeks at the heap head
    before popping, so windowed callers never lose an event.
    """

    def __init__(self, telemetry=None):
        self._heap: list = []
        self._seq = 0
        self.n_dispatched = 0
        self.telemetry = live(telemetry)

    def push(self, t: float, kind: int, owner, payload) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, kind, self._seq, owner, payload))

    def push_batch(self, times: Sequence[float], kind: int, owner, payloads: Sequence) -> None:
        for t, p in zip(times, payloads):
            self.push(t, kind, owner, p)

    def __len__(self) -> int:
        return len(self._heap)

    def run(self, horizon: float) -> None:
        """Dispatch events in (time, kind, push-order) order up to horizon."""
        tl = self.telemetry
        heap = self._heap
        heappop = heapq.heappop
        if tl is None:
            while heap and heap[0][0] <= horizon:
                t, kind, _seq, owner, payload = heappop(heap)
                self.n_dispatched += 1
                owner._dispatch(t, kind, payload)
            return
        with tl.timed("event_loop.run"):
            while heap and heap[0][0] <= horizon:
                t, kind, _seq, owner, payload = heappop(heap)
                self.n_dispatched += 1
                tl.now = t
                owner._dispatch(t, kind, payload)


# slots: requests and stages are the per-event hot allocations (one Request
# per arrival, its fields written on every stage hop) — no per-instance dict
@dataclasses.dataclass(slots=True)
class Request:
    rid: int
    t_arrival: float
    tenant: int = 0
    t_start: float = math.nan  # first time any stage began serving it
    t_done: float = math.nan
    #: times this request's batch has errored and been re-served (chaos)
    attempts: int = 0

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrival


@dataclasses.dataclass(slots=True)
class _Stage:
    queue: deque
    busy: bool = False
    token: int = 0  # bumped to invalidate in-flight completions (cancel)
    batch: list | None = None
    service_dt: float = 0.0


def percentile(sorted_vals: Sequence[float], q: float) -> float:
    """Deterministic nearest-rank percentile (q in [0,1])."""
    if not sorted_vals:
        return math.nan
    idx = max(0, math.ceil(q * len(sorted_vals)) - 1)
    return sorted_vals[idx]


def slo_violation_rate(latencies: Sequence[float], slo: float) -> float:
    """Fraction of completed requests whose latency exceeds ``slo``."""
    if not latencies:
        return 0.0
    return sum(1 for l in latencies if l > slo) / len(latencies)


def _requeue(stage: int, r: Request) -> Callable:
    """Platform-event closure re-admitting ``r`` after a retry backoff.

    If a reconfiguration shrank the pipeline while the retry waited, the
    original stage index may no longer exist — the request then restarts
    from stage 0, exactly like drain-and-restart displaces it.
    """

    def apply(sim: "ServingSimulator", now: float) -> None:
        s = stage if stage < len(sim._stages) else 0
        sim._stages[s].queue.append(r)
        sim._try_start(s, now)

    return apply


def _chaos_event(ev) -> Callable:
    """Bind a :class:`~repro.faults.FaultEvent` to its simulator effect."""
    if ev.kind == "dropout":
        return lambda sim, now: sim._chaos_dropout(ev.ep, now)
    if ev.kind == "revival":
        return lambda sim, now: sim._chaos_revival(ev.ep, now)
    return lambda sim, now: sim._chaos_link(ev.link[0], ev.link[1], ev.factor, now)


@dataclasses.dataclass
class SimResult:
    horizon: float
    slo: float
    n_arrived: int
    n_completed: int
    n_in_flight: int
    n_queued: int
    latencies: list[float]
    throughput_rps: float
    #: nearest-rank latency percentiles; None (not NaN — results must stay
    #: strict-JSON) when nothing completed, e.g. every EP dead at t=0
    p50: float | None
    p95: float | None
    p99: float | None
    #: p95 of time from arrival to first service start (pure queueing delay)
    p95_wait: float | None
    #: completed-late requests PLUS requests still in the system at the
    #: horizon that have already outlived the SLO — censoring the backlog
    #: would flatter an arm that stalls and completes nothing
    n_slo_violations: int
    #: n_slo_violations / n_arrived
    slo_rate: float
    #: EP name -> fraction of the horizon the EP spent serving
    occupancy: dict[str, float]
    #: one entry per re-tune: {t, kind, cost_s, new_depth, model_throughput}
    reconfigs: list[dict]
    #: (t, queued + in-flight) sampled at every monitor tick
    load_samples: list[tuple[float, int]]
    #: energy/thermal accounting when the platform carries a power model
    #: (energy_j, joules_per_request, peak_package_w, avg_package_w, cap_w,
    #: throttle_events, max_temp_c, dvfs_levels); None otherwise
    power: dict | None = None
    #: completions that also met their deadline, per second — equals
    #: ``throughput_rps`` when no resilience policy sets a deadline
    goodput_rps: float = 0.0
    #: arrivals turned away or expired in queue (load shedding)
    n_shed: int = 0
    #: requests dropped after exhausting their retry budget
    n_failed: int = 0
    #: batch-error re-serves (each member request counts once per re-serve)
    n_retries: int = 0
    #: 1 - (shed + failed) / arrived — the fraction of offered load the
    #: service answered at all
    availability: float = 1.0

    def summary(self) -> str:
        def ms(x: float | None) -> str:
            return "n/a" if x is None else f"{x * 1e3:.0f}ms"

        return (
            f"arrived={self.n_arrived} done={self.n_completed} "
            f"tp={self.throughput_rps:.1f}/s p50={ms(self.p50)} "
            f"p95={ms(self.p95)} p99={ms(self.p99)} "
            f"slo_viol={self.slo_rate * 100:.1f}% reconfigs={len(self.reconfigs)}"
        )


class ServingSimulator:
    """Event-driven pipeline server over an evaluator's stage-time model.

    ``evaluator`` is the ground truth (the "hardware"): stage times come
    from it and are scaled by the runtime drift factors the fault scenario
    injects.  The autotuner never sees the ground truth directly — only
    the observed per-stage times at monitor ticks, mirroring the paper's
    online measure-then-move loop.
    """

    def __init__(
        self,
        evaluator: AnalyticEvaluator,
        conf: PipelineConfig,
        *,
        max_batch: int = 4,
        batch_efficiency: float = 0.7,
        slo: float = 1.0,
        monitor_interval: float = 0.5,
        autotuner=None,
        batch_policy: Sequence[int] | None = None,
        loop: EventLoop | None = None,
        telemetry=None,
        label: str = "serve",
        resilience=None,
    ):
        self.evaluator = evaluator
        self.conf = conf
        self.max_batch = max(1, max_batch)
        self.batch_efficiency = batch_efficiency
        self.slo = slo
        self.monitor_interval = monitor_interval
        self.autotuner = autotuner
        #: per-stage max micro-batch; defaults to a flat ``max_batch``
        self.batch_policy = self._policy(batch_policy, conf.depth)
        #: the event heap — private by default, shared under co-simulation
        self.loop = loop if loop is not None else EventLoop()
        #: lane name: telemetry metric prefix and trace process (the tenant)
        self.label = label
        #: live telemetry session or None (``NULL`` normalizes to None, so
        #: every per-event guard below is one ``is not None`` check)
        self.telemetry = live(telemetry)
        if self.telemetry is not None:
            if self.loop.telemetry is None:
                self.loop.telemetry = self.telemetry
            fabric = evaluator.platform.fabric
            if fabric is not None:
                fabric.telemetry = self.telemetry
            if autotuner is not None and getattr(autotuner, "telemetry", None) is None:
                autotuner.telemetry = self.telemetry

        n_eps = evaluator.platform.n_eps
        self.drift = EPDerates(factors=(1.0,) * n_eps)
        self.dead: set[int] = set()
        self._base_times = list(evaluator.stage_times(conf))
        self._stages = [_Stage(queue=deque()) for _ in range(conf.depth)]
        self._stall_until = -math.inf
        self._retuning_until = -math.inf
        self._epoch = 0  # bumped per reconfig; invalidates pre-reconfig _DONEs
        self._busy_time = [0.0] * n_eps
        #: occupancy folded in from platforms served before a re-partition,
        #: keyed by EP name (names are global, indices are not)
        self._busy_prev: dict[str, float] = {}
        self._completed: list[Request] = []
        self._n_arrived = 0
        self._reconfigs: list[dict] = []
        self._load_samples: list[tuple[float, int]] = []
        self._scripted: list[tuple[float, Callable]] = []
        #: request-level :class:`~repro.faults.ResiliencePolicy` or None
        #: (None = the pre-chaos blind lane, bit-for-bit)
        self.resilience = resilience
        #: seeded per-lane Bernoulli stream of transient batch errors,
        #: installed by ``prime`` when the platform carries a fault model
        self._batch_faults = None
        self._n_shed = 0
        self._n_failed = 0
        self._n_retries = 0
        #: attached power model or None; energy integrates over monitor
        #: windows (dynamic joules over busy seconds, leakage over the
        #: whole window), thermal nodes step on the same cadence
        self.power = evaluator.platform.power
        self._thermal_factors: list[float] | None = None
        self._init_power_state(n_eps)
        self._bind_metrics()

    def _init_power_state(self, n_eps: int) -> None:
        self._last_power_t = 0.0
        self._energy_j = 0.0
        self._peak_w = 0.0
        self._max_temp_c: float | None = None
        self._throttle_events = 0
        self._busy_since_tick = [0.0] * n_eps
        pm = self.power
        if pm is not None and pm.thermal is not None:
            self._thermal_factors = [pm.thermal.factor(e) for e in range(n_eps)]
        else:
            self._thermal_factors = None

    def _bind_metrics(self) -> None:
        """Pre-resolve the hot-path metric handles and track labels.

        The per-event cost of recording is then one attribute load + method
        call instead of an f-string build and a registry lookup — the serve
        benchmark's instrumented/bare ratio is pinned by a floor test on
        this staying cheap.  Handles are label-keyed and stable; the
        per-stage track labels depend on the configuration and are rebuilt
        on every install (see ``_apply_reconfig``).
        """
        tl = self.telemetry
        if tl is None:
            return
        label = self.label
        #: direct append target for the per-batch/per-request span rows —
        #: identical TraceEvent records, minus two delegation layers per
        #: event (Telemetry.span -> SpanTracer.span -> append)
        self._trace_append = tl.tracer.events.append
        self._m_batch_size = tl.histogram(f"{label}.batch_size")
        self._m_arrivals = tl.counter(f"{label}.arrivals")
        self._m_slo_hit = tl.counter(f"{label}.slo.hit")
        self._m_slo_miss = tl.counter(f"{label}.slo.miss")
        self._m_latency = tl.histogram(f"{label}.latency_s")
        self._m_queue_depth = tl.histogram(f"{label}.queue_depth")
        self._m_in_system = tl.gauge(f"{label}.in_system")
        self._bind_stage_tracks()

    def _bind_stage_tracks(self) -> None:
        #: (span name, EP track) per stage of the current configuration
        self._stage_tracks = [
            (f"stage{s}", f"ep{e}") for s, e in enumerate(self.conf.eps)
        ]

    def _policy(self, policy: Sequence[int] | None, depth: int) -> tuple[int, ...]:
        if policy is None:
            return (self.max_batch,) * depth
        if len(policy) != depth or any(b < 1 for b in policy):
            raise ValueError(f"need {depth} positive batch caps, got {policy}")
        return tuple(policy)

    # -- scenario scripting -------------------------------------------------

    def schedule_slowdown(self, t: float, ep_idx: int, factor: float) -> None:
        """At time ``t`` the EP becomes ``factor``x slower (drift derate)."""
        self._scripted.append(
            (t, lambda sim, now: sim.apply_slowdown(ep_idx, factor))
        )

    def schedule_dropout(self, t: float, ep_idx: int) -> None:
        """At time ``t`` the EP dies: its stage blocks, in-flight work is lost."""
        self._scripted.append((t, lambda sim, now: sim.apply_dropout(ep_idx)))

    def schedule_revival(self, t: float, ep_idx: int) -> None:
        """At time ``t`` a dead EP comes back; its stages may serve again."""
        self._scripted.append((t, lambda sim, now: sim.apply_revival(ep_idx, now)))

    def schedule_link_fault(self, t: float, u: int, v: int, factor: float) -> None:
        """At ``t`` fabric link (u, v) fails (0), degrades, or heals (1)."""
        self._scripted.append(
            (t, lambda sim, now: sim.apply_link_fault(u, v, factor, now))
        )

    # fault effects are methods (not closures) so a co-simulator can apply
    # *global* fault scripts to whichever tenant owns the EP at fault time

    def apply_slowdown(self, ep_idx: int, factor: float) -> None:
        f = list(self.drift.factors)
        f[ep_idx] = f[ep_idx] * factor
        self.drift = EPDerates(factors=tuple(f))

    def apply_dropout(self, ep_idx: int) -> None:
        self.dead.add(ep_idx)
        for s, st in enumerate(self._stages):
            if self.conf.eps[s] == ep_idx and st.busy:
                st.token += 1  # cancel the in-flight completion
                st.busy = False
                for r in st.batch or []:
                    # the aborted service never happened: keep the wait-time
                    # clock honest by letting the next real start restamp it
                    r.t_start = math.nan
                st.queue.extendleft(reversed(st.batch or []))
                st.batch = None

    def apply_revival(self, ep_idx: int, now: float) -> None:
        self.dead.discard(ep_idx)
        for s in range(self.conf.depth):
            if self.conf.eps[s] == ep_idx:
                self._try_start(s, now)

    def apply_link_fault(self, u: int, v: int, factor: float, now: float) -> None:
        """A fabric link's state changes: dead (0), degraded, or healed (1).

        Mutates the shared fabric link-state (visible to every tenant on
        the same fabric), re-prices this lane's stage times under the new
        effective topology, and — on heal/degrade — kicks every stage,
        since a boundary that priced ``inf`` may be serveable again.
        """
        fabric = self.evaluator.platform.fabric
        if fabric is None:
            return
        fabric.set_link_state(u, v, factor)
        self._base_times = list(self.evaluator.stage_times(self.conf))
        if factor > 0.0:
            for s in range(self.conf.depth):
                self._try_start(s, now)

    # chaos wrappers: telemetry lives here, NOT in the apply_* methods, so
    # scripted-fault runs (and their pinned telemetry exports) are untouched

    def _chaos_dropout(self, ep_idx: int, now: float) -> None:
        self.apply_dropout(ep_idx)
        tl = self.telemetry
        if tl is not None:
            tl.counter("chaos.dropouts").inc()
            tl.instant(
                "chaos:dropout", now, cat="chaos", pid=self.label, tid="chaos",
                args={"ep": ep_idx},
            )

    def _chaos_revival(self, ep_idx: int, now: float) -> None:
        self.apply_revival(ep_idx, now)
        tl = self.telemetry
        if tl is not None:
            tl.counter("chaos.revivals").inc()
            tl.instant(
                "chaos:revival", now, cat="chaos", pid=self.label, tid="chaos",
                args={"ep": ep_idx},
            )

    def _chaos_link(self, u: int, v: int, factor: float, now: float) -> None:
        self.apply_link_fault(u, v, factor, now)
        tl = self.telemetry
        if tl is not None:
            tl.counter("chaos.link_faults").inc()
            tl.instant(
                "chaos:link", now, cat="chaos", pid=self.label, tid="chaos",
                args={"link": [u, v], "factor": factor},
            )

    # -- live fabric contention ---------------------------------------------

    def set_background_flows(self, flows) -> None:
        """Install the current co-tenant flow set (fabric contention).

        A co-simulator calls this every monitor window with the *other*
        lanes' steady-state activation flows (node-space
        :class:`~repro.interconnect.Flow`\\ s): the ground-truth evaluator
        re-prices every stage-boundary transfer under the shared-link
        fair-share model, so future service times on this lane reflect the
        congestion.  No-op when the flow set is unchanged or the platform
        has no fabric.
        """
        if self.evaluator.platform.fabric is None:
            return
        flows = tuple(flows)
        if flows == tuple(self.evaluator.background_flows):
            return
        self.evaluator.background_flows = flows
        self._base_times = list(self.evaluator.stage_times(self.conf))

    # -- internals ----------------------------------------------------------

    def _push(self, t: float, kind: int, payload) -> None:
        self.loop.push(t, kind, self, payload)

    def _effective_time(self, stage: int) -> float:
        ep = self.conf.eps[stage]
        t = self.drift.scale(ep, self._base_times[stage])
        tf = self._thermal_factors
        if tf is not None:
            t *= tf[ep]
        return t

    def observed_stage_times(self) -> list[float]:
        """What a monitor sees: drifted stage times, inf for dead EPs."""
        return [
            math.inf if self.conf.eps[s] in self.dead else self._effective_time(s)
            for s in range(self.conf.depth)
        ]

    def _try_start(self, stage: int, t: float) -> None:
        st = self._stages[stage]
        ep = self.conf.eps[stage]
        if st.busy or not st.queue or t < self._stall_until or ep in self.dead:
            return
        base = self._effective_time(stage)
        if not math.isfinite(base):
            return  # stage boundary severed by a link fault: cannot serve
        pol = self.resilience
        if pol is not None and pol.shed_expired and pol.deadline_s is not None:
            # a request that already missed its deadline would only burn
            # service time others still on budget could use — shed it now,
            # at whatever stage it is queued (an outage strands expired
            # work wherever the dead EP sat, not just at admission)
            while st.queue and pol.expired(st.queue[0].t_arrival, t):
                self._shed(st.queue.popleft(), t)
            if not st.queue:
                return
        b = min(len(st.queue), self.batch_policy[stage])
        batch = [st.queue.popleft() for _ in range(b)]
        dt = base * (1.0 + (b - 1) * self.batch_efficiency)
        for r in batch:
            if math.isnan(r.t_start):
                r.t_start = t
        st.busy, st.batch, st.service_dt = True, batch, dt
        if self.telemetry is not None:
            self._m_batch_size.observe(b)
        self._push(t + dt, _DONE, (stage, st.token, self._epoch))

    def _on_done(self, t: float, stage: int, token: int, epoch: int) -> None:
        if epoch != self._epoch:
            return  # batch belonged to a configuration that was replaced
        st = self._stages[stage]
        if token != st.token:
            return  # cancelled (dropout)
        st.busy = False
        ep = self.conf.eps[stage]
        self._busy_time[ep] += st.service_dt
        if self.power is not None:
            self._busy_since_tick[ep] += st.service_dt
        batch, st.batch = st.batch or [], None
        tl = self.telemetry
        if tl is not None and batch:
            # one span per served batch, on the hosting EP's track — the
            # "stage hop" leg of every member request's lifecycle
            span_name, ep_track = self._stage_tracks[stage]
            self._trace_append(
                TraceEvent(
                    t - st.service_dt,
                    span_name,
                    "request",
                    self.label,
                    ep_track,
                    st.service_dt,
                    {"stage": stage, "batch": len(batch)},
                )
            )
        bf = self._batch_faults
        if bf is not None and batch and bf.fails():
            # transient batch error: the work was done (busy time stands)
            # but the output is garbage and must be re-served
            self._on_batch_error(t, stage, batch)
            self._try_start(stage, t)
            return
        if stage == self.conf.depth - 1:
            for r in batch:
                r.t_done = t
                self._completed.append(r)
                if tl is not None:
                    ok = r.latency <= self.slo
                    (self._m_slo_hit if ok else self._m_slo_miss).inc()
                    self._m_latency.observe(r.latency)
                    self._trace_append(
                        TraceEvent(
                            r.t_arrival,
                            "request",
                            "request",
                            self.label,
                            "requests",
                            r.latency,
                            {
                                "rid": r.rid,
                                "wait_s": r.t_start - r.t_arrival,
                                "slo_ok": ok,
                            },
                        )
                    )
        else:
            self._stages[stage + 1].queue.extend(batch)
            self._try_start(stage + 1, t)
        self._try_start(stage, t)

    def _shed(self, r: Request, t: float) -> None:
        self._n_shed += 1
        tl = self.telemetry
        if tl is not None:
            tl.counter("chaos.shed").inc()
            tl.instant(
                "chaos:shed", t, cat="chaos", pid=self.label, tid="chaos",
                args={"rid": r.rid},
            )

    def _on_batch_error(self, t: float, stage: int, batch: list) -> None:
        """A served batch errored (chaos): retry, fail, or blindly re-serve."""
        pol = self.resilience
        tl = self.telemetry
        if tl is not None:
            tl.counter("chaos.batch_errors").inc()
            tl.instant(
                "chaos:batch_error", t, cat="chaos", pid=self.label, tid="chaos",
                args={"stage": stage, "batch": len(batch)},
            )
        if pol is None:
            # blind lane: immediate, unbounded head-of-line re-serve — the
            # failure mode the resilient arm is benchmarked against
            for r in reversed(batch):
                r.attempts += 1
                r.t_start = math.nan
                self._stages[stage].queue.appendleft(r)
            self._n_retries += len(batch)
            return
        for r in batch:
            r.attempts += 1
            if r.attempts > pol.max_retries:
                self._n_failed += 1
                if tl is not None:
                    tl.counter("chaos.failed").inc()
                continue
            self._n_retries += 1
            if tl is not None:
                tl.counter("chaos.retries").inc()
            r.t_start = math.nan
            self._push(t + pol.backoff(r.rid, r.attempts), _PLATFORM, _requeue(stage, r))

    def _begin_reconfig(self, t: float, retune, replatform: "Replatform | None" = None, extra: dict | None = None) -> None:
        # The old configuration keeps serving during the exploration window
        # (measurement batches are real traffic); the new conf lands at its
        # end and only then does the install downtime stall admission.
        self._retuning_until = t + retune.tuning_cost
        entry = {
            "t": t,
            "kind": retune.kind,
            "tuning_cost_s": retune.tuning_cost,
            "downtime_s": retune.downtime,
            "new_depth": retune.conf.depth,
            "model_throughput": retune.model_throughput,
        }
        if retune.batch_policy is not None:
            entry["batch_policy"] = list(retune.batch_policy)
        if extra:
            entry.update(extra)
        tl = self.telemetry
        if tl is not None:
            tl.counter(f"{self.label}.retunes.{retune.kind}").inc()
            tl.histogram(f"{self.label}.retune_cost_s").observe(retune.tuning_cost)
            # the Alg. 2 exploration window as a span: its dur is the charged
            # Trace.wall the old configuration serves degraded through
            tl.span(
                f"retune:{retune.kind}",
                t,
                retune.tuning_cost,
                cat="retune",
                pid=self.label,
                tid="tuner",
                args={k: v for k, v in entry.items() if k != "t"},
            )
        self._push(self._retuning_until, _RECONFIG, (retune, entry, replatform))

    def _fold_busy_time(self) -> None:
        """Accumulate current-platform occupancy into the name-keyed ledger."""
        for i, ep in enumerate(self.evaluator.platform.eps):
            if self._busy_time[i]:
                self._busy_prev[ep.name] = self._busy_prev.get(ep.name, 0.0) + self._busy_time[i]

    def _apply_reconfig(self, t: float, retune, entry: dict, replatform: "Replatform | None" = None) -> None:
        # logged here, not at decision time: a re-tune whose exploration
        # window runs past the horizon never installs and is not reported
        self._reconfigs.append(entry)
        # Drain-and-restart: cancel in-flight work, restart mid-pipeline
        # requests from stage 0 of the new configuration.
        displaced: list[Request] = []
        for st in self._stages:
            if st.busy:
                displaced.extend(st.batch or [])
            displaced.extend(st.queue)
        displaced.sort(key=lambda r: (r.t_arrival, r.rid))
        self._epoch += 1  # outstanding _DONE events of the old conf are void
        if replatform is not None:
            # elastic re-partition: the EP set itself changed, so swap the
            # ground-truth evaluator and re-base drift/dead/occupancy to the
            # new local index space
            self._fold_busy_time()
            if self.power is not None:
                # settle the energy window against the outgoing power model
                # (joules are package-level scalars, so they survive the
                # index-space change; thermal state restarts with the
                # incoming restricted model)
                self._step_power(t)
            self.evaluator = replatform.evaluator
            self.drift = replatform.drift
            self.dead = set(replatform.dead)
            self._busy_time = [0.0] * self.evaluator.platform.n_eps
            self.power = self.evaluator.platform.power
            self._busy_since_tick = [0.0] * self.evaluator.platform.n_eps
            pm = self.power
            if pm is not None and pm.thermal is not None:
                self._thermal_factors = [
                    pm.thermal.factor(e) for e in range(pm.n_eps)
                ]
            else:
                self._thermal_factors = None
            if self.telemetry is not None:
                # the swapped-in evaluator carries a freshly restricted
                # fabric: re-attach the session so routing passes keep
                # recording after the re-partition
                fabric = self.evaluator.platform.fabric
                if fabric is not None:
                    fabric.telemetry = self.telemetry
        old_policy = self.batch_policy
        self.conf = retune.conf
        if retune.dvfs_levels is not None and self.power is not None:
            # the tuner's adopted frequency vector takes force at install
            # time, with the new configuration (base times below are
            # recomputed under it); the energy window settles first so busy
            # seconds already served are priced at the old draw
            if len(retune.dvfs_levels) == self.power.n_eps:
                self._step_power(t)
                self.power.restore(retune.dvfs_levels)
        if retune.batch_policy is not None:
            policy = retune.batch_policy
        elif len(old_policy) == self.conf.depth:
            # no knob search ran: keep the caps currently in force rather
            # than silently resetting a caller-supplied per-stage policy
            policy = old_policy
        else:
            policy = None  # depth changed and nothing better known: flat default
        self.batch_policy = self._policy(policy, self.conf.depth)
        self._base_times = list(self.evaluator.stage_times(self.conf))
        self._stages = [_Stage(queue=deque()) for _ in range(self.conf.depth)]
        self._stages[0].queue.extend(displaced)
        self._stall_until = t + retune.downtime
        tl = self.telemetry
        if tl is not None:
            self._bind_stage_tracks()
            tl.instant(
                "install",
                t,
                cat="retune",
                pid=self.label,
                tid="tuner",
                args={
                    "kind": retune.kind,
                    "displaced": len(displaced),
                    "downtime_s": retune.downtime,
                    "new_depth": self.conf.depth,
                },
            )
        self._push(self._stall_until, _PLATFORM, lambda sim, now: sim._try_start(0, now))

    def _step_power(self, t: float) -> None:
        """Settle the energy/thermal window ``[_last_power_t, t]``.

        Dynamic joules accrue over each EP's busy seconds at its current
        DVFS level's draw (reduced while thermally throttled — the forced
        clock dip burns less); static leakage accrues over the whole
        window.  Thermal RC nodes step once with the window-average draw,
        and the resulting throttle derates take force for the next window.
        """
        pm = self.power
        window = t - self._last_power_t
        if window <= 0.0:
            return
        self._last_power_t = t
        th = pm.thermal
        tl = self.telemetry
        busy = self._busy_since_tick
        eps = self.evaluator.platform.eps
        throttles_before = th.throttle_events if th is not None else 0
        window_j = 0.0
        for ep in range(len(busy)):
            w = pm.dynamic_w(ep)
            if th is not None and th.throttled[ep]:
                w /= th.electrical_derate
            e = busy[ep] * w + pm.static_w(ep) * window
            window_j += e
            if th is not None:
                self._thermal_factors[ep] = th.step(ep, e / window, window)
                if tl is not None:
                    tl.counter_track(
                        f"thermal.temp_c:{eps[ep].name}",
                        t,
                        th.temps[ep],
                        pid=self.label,
                    )
            busy[ep] = 0.0
        self._energy_j += window_j
        w_avg = window_j / window
        if w_avg > self._peak_w:
            self._peak_w = w_avg
        if th is not None:
            hottest = max(th.temps)
            if self._max_temp_c is None or hottest > self._max_temp_c:
                self._max_temp_c = hottest
            self._throttle_events += th.throttle_events - throttles_before
        if tl is not None:
            tl.histogram("power.package_w").observe(w_avg)
            tl.counter("power.energy_j").inc(window_j)
            tl.counter_track("power.package_w", t, w_avg, pid=self.label)
            if th is not None and th.throttle_events > throttles_before:
                tl.counter("thermal.throttles").inc(
                    th.throttle_events - throttles_before
                )

    def _on_monitor(self, t: float, horizon: float) -> None:
        if self.power is not None:
            self._step_power(t)
        queued = sum(len(st.queue) for st in self._stages)
        in_system = queued + sum(
            len(st.batch or []) for st in self._stages if st.busy
        )
        self._load_samples.append((t, in_system))
        if self.telemetry is not None:
            self._m_queue_depth.observe(queued)
            self._m_in_system.set(in_system)
        if self.autotuner is not None and t >= self._stall_until and t >= self._retuning_until:
            drift = self.drift
            tf = self._thermal_factors
            if tf is not None:
                # the monitor cannot tell a hot chiplet from a sick one by
                # looking at one sample: the observed derate is the product
                # of fault drift and thermal throttle, and it is the
                # *detector's* job to classify the composite
                drift = drift.compose(EPDerates(factors=tuple(tf)))
            retune = self.autotuner.observe(
                t, self.conf, self.observed_stage_times(), drift, frozenset(self.dead)
            )
            if retune is not None:
                self._begin_reconfig(t, retune)
        nxt = t + self.monitor_interval
        if nxt < horizon:
            self._push(nxt, _MONITOR, horizon)

    # -- main loop ----------------------------------------------------------

    def prime(self, arrival_times: Sequence[float], horizon: float, tenant: int = 0) -> None:
        """Enqueue arrivals, scripted faults and the first monitor tick.

        Arrivals are primed as **one bulk batch**: traffic generators emit
        a whole seeded timestamp array per horizon, so the engine takes it
        in a single :meth:`EventLoop.push_batch` append instead of N
        per-event pushes (identical seq numbering and dispatch order).
        """
        self.loop.push_batch(
            arrival_times,
            _ARRIVAL,
            self,
            [
                Request(rid=rid, t_arrival=ta, tenant=tenant)
                for rid, ta in enumerate(arrival_times)
            ],
        )
        for t, fn in self._scripted:
            self._push(t, _PLATFORM, fn)
        fm = getattr(self.evaluator.platform, "faults", None)
        if fm is not None and fm.enabled:
            self._prime_chaos(fm, horizon)
        if self.monitor_interval < horizon:
            self._push(self.monitor_interval, _MONITOR, horizon)

    def _prime_chaos(self, fm, horizon: float) -> None:
        """Expand the platform's fault model into scheduled platform events.

        The whole chaos trace is a pure function of (model, seed, horizon):
        it is generated up front by :class:`~repro.faults.FaultInjector` and
        pushed through the ordinary ``_PLATFORM`` path, so both event
        engines dispatch it identically.
        """
        from ..faults import FaultInjector

        fabric = self.evaluator.platform.fabric
        if fabric is not None and fabric.link_state:
            # the chaos trace is generated from a healthy t=0 baseline; a
            # previous run on the same platform object may have left link
            # faults behind — reset so reruns are bit-for-bit reproducible
            fabric.link_state.clear()
            self._base_times = list(self.evaluator.stage_times(self.conf))
        inj = FaultInjector(fm)
        for ev in inj.trace(self.evaluator.platform, horizon):
            self._push(ev.t, _PLATFORM, _chaos_event(ev))
        self._batch_faults = inj.batch_failures(self.label)

    def _dispatch(self, t: float, kind: int, payload) -> None:
        """Handle one event; called by whichever loop owns the clock."""
        if kind == _ARRIVAL:
            self._n_arrived += 1
            if self.telemetry is not None:
                self._m_arrivals.inc()
            pol = self.resilience
            if pol is not None and pol.queue_cap is not None:
                q = self._stages[0].queue
                if pol.shed_expired and pol.deadline_s is not None:
                    # expired requests don't get to hold admission slots
                    while q and pol.expired(q[0].t_arrival, t):
                        self._shed(q.popleft(), t)
                if len(q) >= pol.queue_cap:
                    self._shed(payload, t)
                    return
            self._stages[0].queue.append(payload)
            self._try_start(0, t)
        elif kind == _DONE:
            self._on_done(t, *payload)
        elif kind == _PLATFORM:
            payload(self, t)
        elif kind == _MONITOR:
            self._on_monitor(t, payload)
        elif kind == _RECONFIG:
            self._apply_reconfig(t, *payload)

    def run(self, arrival_times: Sequence[float], horizon: float, tenant: int = 0) -> SimResult:
        self.prime(arrival_times, horizon, tenant)
        self.loop.run(horizon)
        return self._result(horizon)

    def _power_result(self, horizon: float) -> dict | None:
        pm = self.power
        if pm is None:
            return None
        self._step_power(horizon)  # settle the final partial window
        done = len(self._completed)
        return {
            "energy_j": self._energy_j,
            "joules_per_request": self._energy_j / done if done else None,
            "peak_package_w": self._peak_w,
            "avg_package_w": self._energy_j / horizon if horizon > 0 else 0.0,
            # None (not inf) when uncapped, so the block stays strict-JSON
            "cap_w": pm.cap_w if math.isfinite(pm.cap_w) else None,
            "throttle_events": self._throttle_events,
            "max_temp_c": self._max_temp_c,
            "dvfs_levels": list(pm.snapshot()),
        }

    def _result(self, horizon: float) -> SimResult:
        power = self._power_result(horizon)
        lats = sorted(r.latency for r in self._completed)
        n_in_flight = sum(len(st.batch or []) for st in self._stages if st.busy)
        n_queued = sum(len(st.queue) for st in self._stages)
        pending = [
            r
            for st in self._stages
            for r in list(st.queue) + ((st.batch or []) if st.busy else [])
        ]
        n_viol = sum(1 for l in lats if l > self.slo) + sum(
            1 for r in pending if horizon - r.t_arrival > self.slo
        )
        occ = {name: busy / horizon for name, busy in self._busy_prev.items()}
        for i, ep in enumerate(self.evaluator.platform.eps):
            occ[ep.name] = occ.get(ep.name, 0.0) + self._busy_time[i] / horizon
        pol = self.resilience
        deadline = pol.deadline_s if pol is not None else None
        if deadline is None:
            n_good = len(self._completed)
        else:
            n_good = sum(1 for l in lats if l <= deadline)
        lost = self._n_shed + self._n_failed
        return SimResult(
            horizon=horizon,
            slo=self.slo,
            n_arrived=self._n_arrived,
            n_completed=len(self._completed),
            n_in_flight=n_in_flight,
            n_queued=n_queued,
            latencies=lats,
            throughput_rps=len(self._completed) / horizon if horizon > 0 else 0.0,
            p50=percentile(lats, 0.50) if lats else None,
            p95=percentile(lats, 0.95) if lats else None,
            p99=percentile(lats, 0.99) if lats else None,
            p95_wait=(
                percentile(sorted(r.t_start - r.t_arrival for r in self._completed), 0.95)
                if self._completed
                else None
            ),
            n_slo_violations=n_viol,
            slo_rate=n_viol / self._n_arrived if self._n_arrived else 0.0,
            occupancy=occ,
            reconfigs=self._reconfigs,
            load_samples=self._load_samples,
            power=power,
            goodput_rps=n_good / horizon if horizon > 0 else 0.0,
            n_shed=self._n_shed,
            n_failed=self._n_failed,
            n_retries=self._n_retries,
            availability=1.0 - lost / self._n_arrived if self._n_arrived else 1.0,
        )

    def result(self, horizon: float) -> SimResult:
        """Final accounting; used by co-simulators that drive a shared loop."""
        return self._result(horizon)


@dataclasses.dataclass(frozen=True)
class Replatform:
    """Install bundle for a re-partition: the lane's new ground truth.

    Carried alongside a :class:`~repro.serve.autotuner.Retune` through the
    reconfig event so the evaluator/drift/dead swap happens at *install*
    time (end of the exploration window), not at decision time.
    """

    evaluator: AnalyticEvaluator
    drift: EPDerates
    dead: frozenset
