"""Multi-tenant co-scheduling: several CNN pipelines on one platform.

The paper schedules one network onto one chiplet platform; a serving
deployment runs many.  Because Shisha's EP assignment is injective (each
stage owns its EP), the natural multi-tenant form is a *disjoint partition*
of the platform's EPs: each tenant receives a sub-platform and is seeded
and tuned independently (Algorithms 1+2 unchanged).

Launch-time partition strategies over the H_e ranking (``Platform.ranked()``):

  * ``interleaved``   — deal ranked EPs round-robin, so every tenant gets a
                        fair FEP/SEP mix (heterogeneity-preserving).
  * ``blocked``       — contiguous chunks of the ranking: tenant 0 gets the
                        fastest block (priority tiers).
  * ``proportional``  — deal each ranked EP to the tenant with the largest
                        unmet ``share`` (weighted fairness).

Beyond the launch-time split, this module co-simulates all tenants on one
**shared clock** (:class:`SharedClockCoSimulator` / :func:`co_serve`): every
tenant's stage queues advance on a single discrete-event timeline over the
global platform, scripted faults hit *global* EP indices so whichever
tenant owns the EP sees the drift, and — in elastic mode — an
:class:`ElasticPartitioner` re-runs the partition mid-flight: a tenant
whose partition lost an EP steals the lowest-marginal-value EP from donor
tenants (priced by each donor's model throughput and SLO pressure), after
which every affected tenant re-tunes via its
:class:`~repro.serve.autotuner.ContinuousShisha`, paying the full
``Trace.wall`` exploration cost on the shared clock.  Revivals are elastic
too: a dead EP that comes back is granted to the highest-surplus tenant by
the same pricing.  When the global platform carries an interconnect fabric,
the co-simulator additionally injects every lane's live activation flows
into the other lanes each monitor window, so co-tenant traffic congests the
links it shares (§6's contention effect, live on the event loop).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

from ..core.cost_model import Layer, weights as layer_weights
from ..core.evaluator import AnalyticEvaluator, DatabaseEvaluator, Trace
from ..core.heuristics import run_shisha
from ..core.platform import Platform
from ..interconnect import Flow
from ..pipeline.hetero import EPDerates
from ..telemetry import live
from .autotuner import ContinuousShisha, drifted_platform, tune_batch_policy
from .simulator import (
    _MONITOR,
    _PLATFORM,
    _RECONFIG,
    EventLoop,
    Replatform,
    ServingSimulator,
    SimResult,
)
from .traffic import TrafficGenerator

PARTITION_STRATEGIES = ("interleaved", "blocked", "proportional")


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One hosted pipeline: a network, its traffic, and its SLO."""

    name: str
    layers: tuple[Layer, ...]
    traffic: TrafficGenerator
    #: latency SLO in simulated seconds
    slo: float = 1.0
    #: relative EP share under the "proportional" strategy
    share: float = 1.0


def partition_eps(
    platform: Platform,
    n_parts: int,
    strategy: str = "interleaved",
    shares: Sequence[float] | None = None,
) -> list[tuple[int, ...]]:
    """Split the platform's EP indices into ``n_parts`` disjoint groups."""
    if n_parts < 1 or n_parts > platform.n_eps:
        raise ValueError(f"cannot split {platform.n_eps} EPs into {n_parts} parts")
    ranked = platform.ranked()
    shares = list(shares) if shares is not None else [1.0] * n_parts
    if len(shares) != n_parts or any(s <= 0 for s in shares):
        raise ValueError(f"need {n_parts} positive shares, got {shares}")
    parts: list[list[int]] = [[] for _ in range(n_parts)]
    if strategy == "interleaved":
        for i, ep in enumerate(ranked):
            parts[i % n_parts].append(ep)
    elif strategy == "blocked":
        total = sum(shares)
        sizes = [max(1, round(platform.n_eps * s / total)) for s in shares]
        while sum(sizes) > platform.n_eps:
            # rebalance by shrinking the largest *shrinkable* size: taking a
            # tenant to 0 would trip the no-EPs invariant below under
            # sufficiently skewed shares
            i = max(range(n_parts), key=lambda p: (sizes[p] > 1, sizes[p], -p))
            if sizes[i] <= 1:
                raise ValueError(
                    f"cannot fit {n_parts} tenants with shares {shares} "
                    f"onto {platform.n_eps} EPs"
                )
            sizes[i] -= 1
        while sum(sizes) < platform.n_eps:
            sizes[sizes.index(min(sizes))] += 1
        start = 0
        for p, size in enumerate(sizes):
            parts[p] = ranked[start : start + size]
            start += size
    elif strategy == "proportional":
        got = [0.0] * n_parts
        for ep in ranked:
            # largest unmet share takes the next-fastest EP (ties: lower idx)
            p = max(range(n_parts), key=lambda i: (shares[i] - got[i], -i))
            parts[p].append(ep)
            got[p] += 1.0 * sum(shares) / platform.n_eps
    else:
        raise ValueError(f"unknown strategy {strategy!r}; have {PARTITION_STRATEGIES}")
    if any(not p for p in parts):
        raise ValueError(f"strategy {strategy!r} left a tenant with no EPs: {parts}")
    return [tuple(p) for p in parts]


def subplatform(platform: Platform, ep_idxs: Sequence[int], name: str) -> Platform:
    """A tenant's private view: the selected EPs, reindexed from 0.

    An attached fabric is restricted, not rebuilt: the tenant's transfers
    still route over the *global* topology (through routers of chiplets it
    does not own), which is exactly what lets co-tenant flows contend.

    An attached power model is restricted to a per-lane copy carrying the
    owned EPs' current DVFS levels.  Two documented simplifications: each
    lane enforces the whole-package cap against its own draw (conservative
    — the sum of lane draws may still exceed what any single lane sees),
    and a lane rebuilt after a repartition restarts from the global model's
    levels and ambient thermal state.
    """
    fabric = platform.fabric.restrict(ep_idxs) if platform.fabric is not None else None
    power = platform.power.restrict(ep_idxs) if platform.power is not None else None
    return Platform(
        name=name,
        eps=tuple(platform.eps[i] for i in ep_idxs),
        fabric=fabric,
        power=power,
    )


@dataclasses.dataclass
class TenantResult:
    tenant: Tenant
    ep_idxs: tuple[int, ...]  # global EP indices owned by this tenant (final)
    conf_pretty: str
    model_throughput: float
    n_trials: int
    sim: SimResult
    #: per-stage max micro-batch installed at launch (batch-knob search)
    batch_policy: tuple[int, ...] | None = None


@dataclasses.dataclass
class RepartitionEvent:
    """One elastic re-allocation, as recorded by the co-simulator.

    ``kind`` distinguishes a ``"dropout"`` steal (an EP died, the victim
    stole a replacement) from a ``"revival"`` grant (a dead EP came back and
    was offered to the highest-surplus tenant: ``victim`` is the *receiving*
    tenant, ``stolen_ep`` the revived EP, ``price`` its winning gain,
    ``donor`` None).
    """

    t: float
    dead_ep: int  # global EP index whose death/revival triggered the event
    victim: str  # tenant that lost the EP (dropout) / received it (revival)
    donor: str | None  # tenant that gave one up (None: nobody could)
    stolen_ep: int | None  # global EP index moved donor -> victim
    price: float | None  # donor's marginal value of the stolen EP
    #: post-event global partitions (alive EPs only), tenant name -> indices
    partitions: dict[str, tuple[int, ...]]
    #: tenant name -> Trace.wall exploration seconds charged on the shared
    #: clock for the forced re-tune this event caused
    retune_costs: dict[str, float]
    kind: str = "dropout"
    #: the full package deal, one pricing-breakdown dict per steal (first
    #: entry mirrors donor/stolen_ep/price); a single-steal rebalance has
    #: exactly one entry, so pre-bundle consumers keep working unchanged
    bundle: tuple[dict, ...] = ()


class ElasticPartitioner:
    """Mid-flight EP re-allocation across tenants.

    When a global EP dies, the tenant owning it loses capacity its schedule
    was tuned for.  Rather than leaving the victim to shrink, the
    partitioner re-runs the partition: every *donor* tenant (anyone holding
    at least two alive EPs) offers each of its EPs, and offers are valued
    in the one currency the aggregate SLO metric is measured in —
    **requests/second of demand put at risk**:

        ``at_risk(tenant, C) = max(0, headroom * demand + urgency - C)``

    where ``C`` is the *tuned* model throughput of a full Shisha re-tune
    on the candidate EP set (Algorithm 1 seeds undervalue what tuning can
    extract, so pricing re-tunes — pure model-side arithmetic, the
    scheduler thinking rather than measuring, so it costs no simulated
    time), ``demand`` is the tenant's observed arrival rate, ``headroom``
    covers burstiness/queueing slack, and ``urgency = backlog / slo`` is
    the SLO pressure of requests already waiting.  An offer's *price* is
    the donor's at-risk increase from giving the EP up; the victim's
    *gain* is its at-risk decrease from receiving it.  The victim steals
    the offer with the largest positive surplus (gain minus price): a
    donor with real headroom gives up even a fast EP almost for free, a
    donor near saturation prices it high and keeps its partition, and an
    EP the victim's pipeline cannot exploit (its bottleneck lies
    elsewhere) is never stolen just because it is cheap.  Only the
    re-tunes that follow a steal charge ``Trace.wall`` to the clock.
    """

    def __init__(
        self,
        platform: Platform,
        make_evaluator: Callable[[Platform, Sequence[Layer]], AnalyticEvaluator],
        heuristic: str = "H3",
        headroom: float = 2.0,
    ):
        self.platform = platform
        self.make_evaluator = make_evaluator
        self.heuristic = heuristic
        self.headroom = headroom
        self._tp_cache: dict[tuple[str, tuple[int, ...]], float] = {}

    def tuned_throughput(self, tenant: Tenant, part: Sequence[int]) -> float:
        """Model throughput of a full Shisha re-tune of ``tenant`` on ``part``.

        Cached per (tenant, EP set); ``use_cache`` also dedups revisits
        inside the throwaway pricing trace.
        """
        key = (tenant.name, tuple(sorted(part)))
        if key not in self._tp_cache:
            if not part:
                self._tp_cache[key] = 0.0
            else:
                sub = subplatform(self.platform, part, f"{self.platform.name}/price")
                ev = self.make_evaluator(sub, tenant.layers)
                sh = run_shisha(
                    layer_weights(tenant.layers), Trace(ev, use_cache=True), self.heuristic
                )
                self._tp_cache[key] = sh.result.best_throughput
        return self._tp_cache[key]

    def _at_risk(self, capacity: float, demand: float, urgency: float) -> float:
        return max(0.0, self.headroom * demand + urgency - capacity)

    def price(
        self, tenant: Tenant, part: Sequence[int], ep: int, demand: float, urgency: float
    ) -> float:
        """Donor-side price: req/s of demand put at risk by giving ``ep`` up."""
        c_with = self.tuned_throughput(tenant, part)
        c_without = self.tuned_throughput(tenant, [e for e in part if e != ep])
        return self._at_risk(c_without, demand, urgency) - self._at_risk(
            c_with, demand, urgency
        )

    def gain(
        self, tenant: Tenant, part: Sequence[int], ep: int, demand: float, urgency: float
    ) -> float:
        """Victim-side value: req/s of at-risk demand recovered by ``ep``."""
        c_now = self.tuned_throughput(tenant, part)
        c_plus = self.tuned_throughput(tenant, list(part) + [ep])
        if c_now <= 0 < c_plus:
            return math.inf  # a tenant with no EPs must be re-housed
        return self._at_risk(c_now, demand, urgency) - self._at_risk(
            c_plus, demand, urgency
        )

    def rebalance(
        self,
        partitions: dict[str, tuple[int, ...]],
        victim: str,
        tenants: dict[str, Tenant],
        loads: dict[str, tuple[float, float]],
    ) -> tuple[str, int, float] | None:
        """Pick (donor, ep, price) for ``victim`` to steal, or None.

        ``loads`` maps tenant name to (observed demand req/s, urgency
        req/s).  Returns the offer with the largest positive surplus
        (victim gain minus donor price); None when no transfer is worth
        it.  Deterministic: ties resolve to the lower price, then the
        lower global EP index, then the donor name.
        """
        offers: list[tuple[float, float, int, str]] = []
        v_part = partitions[victim]
        v_demand, v_urgency = loads[victim]
        # iterate donors by name, not dict insertion order: the offer sort
        # key below is total anyway, but pinning the scan order keeps the
        # pricing cache fill (and any future early-exit) independent of
        # the order a caller happened to assemble `partitions` in
        for name, part in sorted(partitions.items()):
            if name == victim or len(part) < 2:
                continue
            d_demand, d_urgency = loads[name]
            for ep in part:
                price = self.price(tenants[name], part, ep, d_demand, d_urgency)
                gain = self.gain(tenants[victim], v_part, ep, v_demand, v_urgency)
                offers.append((gain - price, price, ep, name))
        if not offers:
            return None
        offers.sort(key=lambda o: (-o[0], o[1], o[2], o[3]))
        surplus, price, ep, donor = offers[0]
        if surplus <= 0:
            return None  # every offer hurts the donor more than it helps
        return donor, ep, price

    def rebalance_bundle(
        self,
        partitions: dict[str, tuple[int, ...]],
        victim: str,
        tenants: dict[str, Tenant],
        loads: dict[str, tuple[float, float]],
        max_bundle: int = 1,
    ) -> tuple[list[dict], dict[str, tuple[int, ...]]]:
        """Package deal: up to ``max_bundle`` priced steals for ``victim``.

        A tenant under *extreme pressure* — at-risk demand exceeding its own
        arrival rate even after a steal, i.e. more than the burst headroom's
        worth of traffic still uncovered — may need several EPs at once; a
        one-EP-per-monitor-window drip would leave it violating its SLO for
        windows on end while paying a full exploration wall per EP.  So the
        rebalance is iterated *at decision time*: each round re-prices every
        donor offer against the updated partitions (a donor that just gave
        an EP up prices its next one higher) and stops at ``max_bundle``
        steals, when no offer has positive surplus, or as soon as the
        victim's residual at-risk demand drops to its arrival rate —
        whichever comes first.  With ``max_bundle=1`` the deal is exactly
        :meth:`rebalance`.

        Returns ``(deals, new_partitions)``: one pricing-breakdown dict per
        steal (``inf`` gains serialized as ``None`` for strict JSON) and the
        partitions after the whole bundle moved.  Does not mutate
        ``partitions``.
        """
        parts = {k: tuple(v) for k, v in partitions.items()}
        deals: list[dict] = []
        v_demand, v_urgency = loads[victim]
        for _ in range(max(1, max_bundle)):
            deal = self.rebalance(parts, victim, tenants, loads)
            if deal is None:
                break
            donor, ep, price = deal
            gain = self.gain(tenants[victim], parts[victim], ep, v_demand, v_urgency)
            parts[donor] = tuple(e for e in parts[donor] if e != ep)
            parts[victim] = parts[victim] + (ep,)
            at_risk_after = self._at_risk(
                self.tuned_throughput(tenants[victim], parts[victim]),
                v_demand,
                v_urgency,
            )
            deals.append(
                {
                    "donor": donor,
                    "ep": ep,
                    "price": price,
                    "gain": None if math.isinf(gain) else gain,
                    "surplus": None if math.isinf(gain) else gain - price,
                    "victim_at_risk_after": at_risk_after,
                }
            )
            if at_risk_after <= v_demand:
                break  # pressure back within burst headroom: stop stealing
        return deals, parts


class SharedClockCoSimulator:
    """All tenants' stage queues on one discrete-event timeline.

    Each tenant is a *lane*: a :class:`ServingSimulator` over its
    sub-platform, bound to the shared :class:`EventLoop`.  Lanes never touch
    each other's queues — the cross-tenant channels are exactly (a) the
    partition, which the :class:`ElasticPartitioner` may rewrite mid-flight
    (dropout steals *and* revival grants), (b) the global fault script,
    which hits global EP indices and lands on whichever lane owns the EP at
    fault time, and (c) the interconnect fabric, when the global platform
    carries one: every monitor window each lane's live activation flows are
    injected into the other lanes' evaluators (and, when
    ``contention_aware``, their tuners), so co-tenant traffic fair-shares
    the links it crosses.

    The co-simulator's own monitor tick runs *before* the lanes' ticks at
    equal timestamps (it is pushed first), so a re-partition decision
    pre-empts a lane-local dropout re-seed that would otherwise pay a
    redundant exploration window.
    """

    def __init__(
        self,
        platform: Platform,
        tenants: Sequence[Tenant],
        *,
        strategy: str = "interleaved",
        make_evaluator: Callable[[Platform, Sequence[Layer]], AnalyticEvaluator] | None = None,
        heuristic: str = "H3",
        max_batch: int = 4,
        batch_efficiency: float = 0.7,
        elastic: bool = True,
        batch_policy_search: bool = False,
        monitor_interval: float = 0.5,
        measure_batches: int = 8,
        alpha: int = 10,
        contention_aware: bool = True,
        placement: bool = False,
        dvfs: bool = False,
        telemetry=None,
        max_bundle: int = 1,
        loop: EventLoop | None = None,
        chaos=None,
        resilience=None,
    ):
        if make_evaluator is None:
            make_evaluator = lambda p, layers: DatabaseEvaluator(p, layers)
        self.platform = platform
        self.tenants = list(tenants)
        self.make_evaluator = make_evaluator
        self.heuristic = heuristic
        self.max_batch = max_batch
        self.batch_efficiency = batch_efficiency
        self.elastic = elastic
        self.batch_policy_search = batch_policy_search
        self.monitor_interval = monitor_interval
        #: ground truth always prices co-tenant flows (physics); this knob
        #: decides whether the lanes' *tuners* also see them (scheduler
        #: knowledge) — the contention-blind/-aware comparison of
        #: benchmarks/fig9_interconnect.py
        self.contention_aware = contention_aware
        #: enable Algorithm 2's placement moves in every lane re-tune
        self.placement = placement
        #: explore per-EP DVFS levels in every lane re-tune (needs a
        #: platform power model; lanes see restricted per-lane copies)
        self.dvfs = dvfs
        #: exploration-cost knobs for the lanes' mid-flight re-tunes: fewer
        #: measurement batches / a smaller α shorten the window the old
        #: (degraded) configuration keeps serving — the Shisha trade-off
        self.measure_batches = measure_batches
        self.alpha = alpha
        #: live telemetry session or None, shared by every lane, the shared
        #: loop and the (restricted) fabrics — one timeline for the whole run
        self.telemetry = live(telemetry)
        #: max EPs a victim under extreme pressure may receive per
        #: repartition (package deal); 1 = classic single steal
        self.max_bundle = max(1, max_bundle)
        #: seeded :class:`~repro.faults.FaultModel` expanded over the
        #: *global* platform at run() time, or None (no chaos)
        self.chaos = chaos
        #: request-level :class:`~repro.faults.ResiliencePolicy` installed
        #: in every lane, or None (blind lanes)
        self.resilience = resilience

        #: the shared event engine; injectable so the old-vs-new
        #: equivalence suite can drive a whole co-simulation on the legacy
        #: :class:`~repro.serve.simulator.HeapEventLoop` reference engine
        self.loop = loop if loop is not None else EventLoop(self.telemetry)
        parts = partition_eps(
            platform, len(tenants), strategy, shares=[t.share for t in tenants]
        )
        #: tenant name -> global EP indices (alive only; maintained elastically)
        self.partitions: dict[str, tuple[int, ...]] = {}
        self.lanes: dict[str, ServingSimulator] = {}
        self._launch: dict[str, dict] = {}
        for tenant, ep_idxs in zip(self.tenants, parts):
            self.partitions[tenant.name] = tuple(ep_idxs)
            self.lanes[tenant.name] = self._build_lane(tenant, ep_idxs)
        #: what each lane is *currently serving on* — lags ``partitions``
        #: by the exploration window while a re-partition is in flight, and
        #: is the mapping runtime fault effects must use
        self._installed: dict[str, tuple[int, ...]] = dict(self.partitions)

        self.elastic_partitioner = ElasticPartitioner(platform, make_evaluator, heuristic)
        self.repartitions: list[RepartitionEvent] = []
        self.global_drift: list[float] = [1.0] * platform.n_eps
        self.global_dead: set[int] = set()
        self._unhandled_dead: list[int] = []
        self._unhandled_revived: list[int] = []
        self._scripted: list[tuple[float, Callable]] = []

    # -- lane construction --------------------------------------------------

    def _sub(self, tenant: Tenant, ep_idxs: Sequence[int]) -> Platform:
        return subplatform(
            self.platform, ep_idxs, f"{self.platform.name}/{tenant.name}"
        )

    def _build_lane(self, tenant: Tenant, ep_idxs: Sequence[int]) -> ServingSimulator:
        sub = self._sub(tenant, ep_idxs)
        ev = self.make_evaluator(sub, tenant.layers)
        trace = Trace(ev)
        sh = run_shisha(
            layer_weights(tenant.layers), trace, self.heuristic, placement=self.placement
        )
        conf = sh.result.best_conf
        policy = None
        if self.batch_policy_search:
            policy = tune_batch_policy(
                trace,
                conf,
                tenant.slo,
                batch_efficiency=self.batch_efficiency,
                max_batch_cap=self.max_batch,
            )
        tuner = ContinuousShisha(
            sub,
            tenant.layers,
            make_evaluator=lambda p, L=tenant.layers: self.make_evaluator(p, L),
            slo=tenant.slo,
            batch_policy_search=self.batch_policy_search,
            max_batch_cap=self.max_batch,
            batch_efficiency=self.batch_efficiency,
            measure_batches=self.measure_batches,
            alpha=self.alpha,
            placement=self.placement,
            dvfs=self.dvfs,
        )
        self._launch[tenant.name] = {
            "conf_pretty": conf.pretty([ep.name for ep in sub.eps]),
            "model_throughput": sh.result.best_throughput,
            "n_trials": trace.n_trials,
            "batch_policy": policy,
        }
        return ServingSimulator(
            ev,
            conf,
            slo=tenant.slo,
            max_batch=self.max_batch,
            batch_efficiency=self.batch_efficiency,
            batch_policy=policy,
            monitor_interval=self.monitor_interval,
            autotuner=tuner,
            loop=self.loop,
            telemetry=self.telemetry,
            label=tenant.name,
            resilience=self.resilience,
        )

    # -- global fault script (global EP indices) ----------------------------

    def schedule_slowdown(self, t: float, ep_idx: int, factor: float) -> None:
        """At ``t`` global EP ``ep_idx`` derates; its owner lane sees it."""

        def apply(sim: "SharedClockCoSimulator", now: float) -> None:
            sim.global_drift[ep_idx] *= factor
            owner = sim._serving_owner_of(ep_idx)
            if owner is not None:
                local = sim._installed[owner].index(ep_idx)
                sim.lanes[owner].apply_slowdown(local, factor)

        self._scripted.append((t, apply))

    def schedule_dropout(self, t: float, ep_idx: int) -> None:
        """At ``t`` global EP ``ep_idx`` dies; elastic mode re-partitions."""

        def apply(sim: "SharedClockCoSimulator", now: float) -> None:
            if ep_idx in sim.global_dead:
                return
            sim.global_dead.add(ep_idx)
            # runtime effect lands on whoever is *serving* on the EP ...
            serving = sim._serving_owner_of(ep_idx)
            if serving is not None:
                local = sim._installed[serving].index(ep_idx)
                sim.lanes[serving].apply_dropout(local)
            # ... while the allocation response follows ownership
            if sim.elastic and sim._owner_of(ep_idx) is not None:
                sim._unhandled_dead.append(ep_idx)
            # non-elastic mode: the owner's own ContinuousShisha re-seeds
            # within its (shrunken) partition at its next monitor tick

        self._scripted.append((t, apply))

    def schedule_revival(self, t: float, ep_idx: int) -> None:
        """At ``t`` dead global EP ``ep_idx`` comes back.

        If some lane still serves on it (static partitions, or a dropout
        whose re-partition has not landed yet), the revival is a lane-local
        recovery.  Otherwise — elastic mode, the EP was rebalanced out of
        every partition — it is offered to the highest-surplus tenant via
        the ElasticPartitioner pricing at the next co-monitor tick.
        """

        def apply(sim: "SharedClockCoSimulator", now: float) -> None:
            if ep_idx not in sim.global_dead:
                return
            sim.global_dead.discard(ep_idx)
            # runtime effect: a lane still serving on the EP (static mode,
            # or an elastic re-partition whose install has not landed yet)
            # resumes its stages immediately ...
            serving = sim._serving_owner_of(ep_idx)
            if serving is not None:
                local = sim._installed[serving].index(ep_idx)
                sim.lanes[serving].apply_revival(local, now)
            # ... while the allocation response follows ownership, exactly
            # like schedule_dropout: if the partitions no longer contain the
            # EP (it was rebalanced away), it must be re-granted even when
            # some lane transiently serves on it during its install window
            if sim.elastic and sim._owner_of(ep_idx) is None:
                sim._unhandled_revived.append(ep_idx)

        self._scripted.append((t, apply))

    def schedule_link_fault(self, t: float, u: int, v: int, factor: float) -> None:
        """At ``t`` the global fabric link (u, v) fails/degrades/heals.

        Link state is shared by reference between the global fabric and
        every lane's restricted copy, so one mutation is instantly visible
        to all tenants; each lane then re-prices its stage times under the
        new effective topology and gets its stages kicked (a healed link
        may unblock a boundary that priced ``inf``).
        """

        def apply(sim: "SharedClockCoSimulator", now: float) -> None:
            fabric = sim.platform.fabric
            if fabric is None:
                return
            fabric.set_link_state(u, v, factor)
            for name in sorted(sim.lanes):
                sim._refresh_lane_links(name, now, kick=factor > 0.0)

        self._scripted.append((t, apply))

    def _refresh_lane_links(self, name: str, now: float, kick: bool) -> None:
        lane = self.lanes[name]
        lane._base_times = list(lane.evaluator.stage_times(lane.conf))
        if kick:
            for s in range(lane.conf.depth):
                lane._try_start(s, now)

    # -- chaos (seeded stochastic fault model over the global platform) ------

    def _expand_chaos(self, horizon: float) -> None:
        """Turn the attached fault model into global scripted events.

        Dropouts/revivals reuse the global-index fault script (so the
        elastic partitioner responds exactly as it would to a scripted
        death); link events go through :meth:`schedule_link_fault`; each
        lane additionally draws transient batch errors from its own
        tenant-name-keyed stream.
        """
        from ..faults import FaultInjector

        fabric = self.platform.fabric
        if fabric is not None and fabric.link_state:
            # chaos traces start from a healthy fabric: reset leftovers a
            # previous run on the same platform object left behind
            fabric.link_state.clear()
            for name in sorted(self.lanes):
                self._refresh_lane_links(name, 0.0, kick=False)
        inj = FaultInjector(self.chaos)
        for ev in inj.trace(self.platform, horizon):
            if ev.kind == "dropout":
                self.schedule_dropout(ev.t, ev.ep)
                self._mark_chaos(ev.t, "dropouts", {"ep": ev.ep})
            elif ev.kind == "revival":
                self.schedule_revival(ev.t, ev.ep)
                self._mark_chaos(ev.t, "revivals", {"ep": ev.ep})
            else:
                self.schedule_link_fault(ev.t, ev.link[0], ev.link[1], ev.factor)
                self._mark_chaos(
                    ev.t, "link_faults", {"link": list(ev.link), "factor": ev.factor}
                )
        for tenant in self.tenants:
            bf = inj.batch_failures(tenant.name)
            if bf is not None:
                self.lanes[tenant.name]._batch_faults = bf

    def _mark_chaos(self, t: float, counter: str, args: dict) -> None:
        # pushed after the effect closure at the same timestamp, so the
        # instant lands once the fault has actually been applied
        def apply(sim: "SharedClockCoSimulator", now: float) -> None:
            tl = sim.telemetry
            if tl is not None:
                tl.counter(f"chaos.{counter}").inc()
                tl.instant(
                    f"chaos:{counter}", now, cat="chaos", pid="coserve", tid="chaos",
                    args=args,
                )

        self._scripted.append((t, apply))

    def _owner_of(self, ep_idx: int) -> str | None:
        """Allocation truth: which tenant the EP is assigned to."""
        for name, part in self.partitions.items():
            if ep_idx in part:
                return name
        return None

    def _serving_owner_of(self, ep_idx: int) -> str | None:
        """Runtime truth: which lane's *installed* platform contains the EP."""
        for name, part in self._installed.items():
            if ep_idx in part:
                return name
        return None

    # -- elastic re-partitioning --------------------------------------------

    def _load(self, name: str, t: float) -> tuple[float, float]:
        """(observed demand req/s, urgency req/s) for the pricing model.

        Urgency is the service rate needed to clear the requests already
        in the lane within one SLO window — the SLO pressure of the
        backlog a fault (or an exploration stall) has built up.
        """
        lane = self.lanes[name]
        tenant = next(x for x in self.tenants if x.name == name)
        demand = lane._n_arrived / t if t > 0 else 0.0
        in_system = sum(len(st.queue) for st in lane._stages) + sum(
            len(st.batch or []) for st in lane._stages if st.busy
        )
        urgency = in_system / tenant.slo if tenant.slo > 0 else 0.0
        return demand, urgency

    def _pricer(self) -> ElasticPartitioner:
        """Decision-time pricer over the drift-adjusted platform.

        Price on what the hardware can do *now*: a derated EP must not be
        valued as if healthy, so the pricer sees the drift-adjusted
        platform (fresh per decision — its cache is drift-specific).
        """
        return ElasticPartitioner(
            drifted_platform(
                self.platform, EPDerates(factors=tuple(self.global_drift))
            ),
            self.make_evaluator,
            self.heuristic,
            self.elastic_partitioner.headroom,
        )

    def _repartition(self, t: float, dead_ep: int) -> None:
        victim = self._owner_of(dead_ep)
        if victim is None:  # already rebalanced away (duplicate dropout)
            return
        tenants = {x.name: x for x in self.tenants}
        # dead EPs leave every partition: the invariant is that partitions
        # stay disjoint and cover only alive EPs
        self.partitions[victim] = tuple(
            e for e in self.partitions[victim] if e != dead_ep
        )
        loads = {name: self._load(name, t) for name in self.partitions}
        deals, new_parts = self._pricer().rebalance_bundle(
            self.partitions, victim, tenants, loads, max_bundle=self.max_bundle
        )
        donor = stolen = price = None
        affected = [victim]
        gains_lost: dict[str, tuple[list, list]] = {victim: ([], [dead_ep])}
        if deals:
            donor, stolen, price = deals[0]["donor"], deals[0]["ep"], deals[0]["price"]
            for d in deals:
                if d["donor"] not in affected:
                    affected.append(d["donor"])
                gains_lost[victim][0].append(d["ep"])
                gains_lost.setdefault(d["donor"], ([], []))[1].append(d["ep"])
                self.partitions[d["donor"]] = new_parts[d["donor"]]
            self.partitions[victim] = new_parts[victim]
        retune_costs = self._stage_retunes(t, affected, gains_lost)
        event = RepartitionEvent(
            t=t,
            dead_ep=dead_ep,
            victim=victim,
            donor=donor,
            stolen_ep=stolen,
            price=price,
            partitions={k: tuple(v) for k, v in self.partitions.items()},
            retune_costs=retune_costs,
            kind="dropout",
            bundle=tuple(deals),
        )
        self.repartitions.append(event)
        tl = self.telemetry
        if tl is not None:
            tl.counter("coserve.repartitions.dropout").inc()
            tl.counter("coserve.eps_stolen").inc(len(deals))
            tl.instant(
                "repartition",
                t,
                cat="coserve",
                pid="coserve",
                tid="partitioner",
                args={
                    "dead_ep": dead_ep,
                    "victim": victim,
                    "bundle": list(deals),
                    "partitions": {k: list(v) for k, v in self.partitions.items()},
                    "retune_costs": retune_costs,
                },
            )

    def _stage_retunes(
        self,
        t: float,
        affected: Sequence[str],
        gains_lost: dict[str, tuple[list, list]],
    ) -> dict[str, float]:
        """Force-retune every affected lane onto its new partition.

        Shared tail of every partition change (dropout steal or revival
        grant): each lane retargets its tuner, pays a full exploration
        window, and installs atomically — every affected lane installs when
        the *slowest* exploration finishes, so a moved EP is never part of
        two serving platforms at once (the donor keeps it exactly until the
        receiver takes it over).
        """
        tenants = {x.name: x for x in self.tenants}
        retune_costs: dict[str, float] = {}
        staged: list[tuple[str, object, Replatform, dict]] = []
        for name in affected:
            part = self.partitions[name]
            if not part:
                continue  # victim starved and nobody could donate
            lane = self.lanes[name]
            tenant = tenants[name]
            sub = self._sub(tenant, part)
            ldrift = EPDerates(
                factors=tuple(self.global_drift[g] for g in part)
            )
            lane.autotuner.retarget(
                sub, make_evaluator=lambda p, L=tenant.layers: self.make_evaluator(p, L)
            )
            retune = lane.autotuner.force_retune(
                t, ldrift, frozenset(), kind="repartition"
            )
            replat = Replatform(
                evaluator=self.make_evaluator(sub, tenant.layers),
                drift=ldrift,
                dead=frozenset(),
            )
            gained, lost = gains_lost.get(name, ([], []))
            extra = {
                "eps": list(part),
                "gained": gained,
                "lost": lost,
                "explore_wall_s": retune.tuning_cost,
            }
            staged.append((name, retune, replat, extra))
            retune_costs[name] = retune.tuning_cost
        window = max((r.tuning_cost for _, r, _, _ in staged), default=0.0)
        for name, retune, replat, extra in staged:
            synced = dataclasses.replace(retune, tuning_cost=window)
            self.lanes[name]._begin_reconfig(t, synced, replat, extra=extra)
            # same timestamp + kind as the lane's install event but pushed
            # after it, so the refresh runs once the new platform is live:
            # it re-bases the installed mapping and overwrites the decision-
            # time drift/dead snapshot with whatever faults landed during
            # the exploration window
            self.loop.push(
                t + window,
                _RECONFIG,
                self,
                lambda sim, now, n=name, p=self.partitions[name]: sim._finish_install(n, p),
            )
        return retune_costs

    def _revive(self, t: float, ep_idx: int) -> None:
        """Offer a revived global EP to the highest-surplus tenant.

        The revived EP belongs to nobody, so there is no donor side: every
        tenant's *gain* (req/s of at-risk demand the EP would recover,
        priced by the same ElasticPartitioner arithmetic as a dropout
        steal) is its bid, and exactly one tenant wins.  Ties — including
        the all-idle case where every gain is zero — resolve to the tenant
        with the fewest EPs, then the lexicographically first name, so the
        EP always rejoins exactly one partition deterministically.
        """
        if ep_idx in self.global_dead:
            return  # died again before the monitor got to it
        if any(ep_idx in part for part in self.partitions.values()):
            return  # already owned (duplicate revival)
        tenants = {x.name: x for x in self.tenants}
        loads = {name: self._load(name, t) for name in self.partitions}
        pricer = self._pricer()
        # bid tuples end in the unique tenant name, so the sort is total;
        # scanning in name order additionally pins cache-fill order
        bids = sorted(
            (
                -pricer.gain(tenants[name], part, ep_idx, *loads[name]),
                len(part),
                name,
            )
            for name, part in sorted(self.partitions.items())
        )
        neg_gain, _, winner = bids[0]
        # a starved tenant bids inf (it must be re-housed); record that as
        # an unpriced grant so serialized payloads stay strict-JSON clean
        gain = -neg_gain
        self.partitions[winner] = self.partitions[winner] + (ep_idx,)
        retune_costs = self._stage_retunes(
            t, [winner], {winner: ([ep_idx], [])}
        )
        self.repartitions.append(
            RepartitionEvent(
                t=t,
                dead_ep=ep_idx,
                victim=winner,
                donor=None,
                stolen_ep=ep_idx,
                price=None if math.isinf(gain) else gain,
                partitions={k: tuple(v) for k, v in self.partitions.items()},
                retune_costs=retune_costs,
                kind="revival",
            )
        )
        tl = self.telemetry
        if tl is not None:
            tl.counter("coserve.repartitions.revival").inc()
            tl.instant(
                "revival",
                t,
                cat="coserve",
                pid="coserve",
                tid="partitioner",
                args={
                    "ep": ep_idx,
                    "winner": winner,
                    "gain": None if math.isinf(gain) else gain,
                    "retune_costs": retune_costs,
                },
            )

    def _finish_install(self, name: str, part: tuple[int, ...]) -> None:
        self._installed[name] = tuple(part)
        lane = self.lanes[name]
        lane.drift = EPDerates(
            factors=tuple(self.global_drift[g] for g in part)
        )
        lane.dead = {i for i, g in enumerate(part) if g in self.global_dead}

    # -- event handling ------------------------------------------------------

    def _dispatch(self, t: float, kind: int, payload) -> None:
        if kind in (_PLATFORM, _RECONFIG):
            payload(self, t)
        elif kind == _MONITOR:
            self._on_monitor(t, payload)

    def _on_monitor(self, t: float, horizon: float) -> None:
        while self._unhandled_dead or self._unhandled_revived:
            # any lane mid-exploration (or mid-install) defers the decision:
            # a re-partition may touch any lane as donor, and overlapping
            # reconfig windows would install stale configurations
            if any(
                lane._retuning_until > t or lane._stall_until > t
                for lane in self.lanes.values()
            ):
                break
            if self._unhandled_dead:
                dead_ep = self._unhandled_dead.pop(0)
                if dead_ep in self.global_dead:  # not revived in the meantime
                    self._repartition(t, dead_ep)
            else:
                self._revive(t, self._unhandled_revived.pop(0))
        self._refresh_flows(t)
        nxt = t + self.monitor_interval
        if nxt < horizon:
            self.loop.push(nxt, _MONITOR, self, horizon)

    # -- live fabric contention ----------------------------------------------

    def _lane_flows(self, name: str) -> tuple[Flow, ...]:
        """The lane's current steady-state activation flows, in node space.

        A lane with nothing queued or in flight generates no traffic this
        window; otherwise every stage boundary of its serving configuration
        ships its activations once per beat over the global fabric.
        """
        lane = self.lanes[name]
        if not any(st.busy or st.queue for st in lane._stages):
            return ()
        part = self._installed[name]
        conf = lane.conf
        fabric = self.platform.fabric
        bounds = conf.boundaries()
        return tuple(
            Flow(
                src=fabric.node(part[conf.eps[s]]),
                dst=fabric.node(part[conf.eps[s + 1]]),
                nbytes=lane.evaluator.layers[bounds[s][1] - 1].act_bytes,
                nodes=True,
            )
            for s in range(conf.depth - 1)
        )

    def _refresh_flows(self, t: float = 0.0) -> None:
        """Per-window flow injection: each lane serves (and, when
        ``contention_aware``, tunes) against the other lanes' live flows."""
        if self.platform.fabric is None:
            return
        flows = {name: self._lane_flows(name) for name in self.lanes}
        tl = self.telemetry
        if tl is not None:
            tl.counter("coserve.flow_windows").inc()
            tl.gauge("coserve.live_flows").set(sum(len(f) for f in flows.values()))
            for name in sorted(flows):
                if flows[name]:
                    # one span per lane per monitor window: the flow set the
                    # other lanes contend against until the next refresh
                    tl.span(
                        "flow_window",
                        t,
                        self.monitor_interval,
                        cat="fabric",
                        pid=name,
                        tid="flows",
                        args={
                            "n": len(flows[name]),
                            "bytes": sum(f.nbytes for f in flows[name]),
                            "links": [[f.src, f.dst] for f in flows[name]],
                        },
                    )
        for name, lane in self.lanes.items():
            bg = tuple(
                f for other, fl in flows.items() if other != name for f in fl
            )
            lane.set_background_flows(bg)
            lane.autotuner.background_flows = bg if self.contention_aware else ()

    # -- main ---------------------------------------------------------------

    def run(self, horizon: float) -> "CoServeResult":
        # co-simulator monitor first: at equal tick times its re-partition
        # decision must precede (and thereby suppress) lane-local re-tunes
        if self.monitor_interval < horizon:
            self.loop.push(self.monitor_interval, _MONITOR, self, horizon)
        if self.chaos is not None and self.chaos.enabled:
            self._expand_chaos(horizon)
        for t, fn in self._scripted:
            self.loop.push(t, _PLATFORM, self, fn)
        for idx, tenant in enumerate(self.tenants):
            self.lanes[tenant.name].prime(
                tenant.traffic.arrivals(horizon), horizon, tenant=idx
            )
        self.loop.run(horizon)
        results = []
        for tenant in self.tenants:
            lane = self.lanes[tenant.name]
            launch = self._launch[tenant.name]
            results.append(
                TenantResult(
                    tenant=tenant,
                    ep_idxs=self.partitions[tenant.name],
                    conf_pretty=launch["conf_pretty"],
                    model_throughput=launch["model_throughput"],
                    n_trials=launch["n_trials"],
                    sim=lane.result(horizon),
                    batch_policy=launch["batch_policy"],
                )
            )
        return CoServeResult(
            results=results,
            repartitions=self.repartitions,
            partitions={k: tuple(v) for k, v in self.partitions.items()},
            dead=frozenset(self.global_dead),
        )


@dataclasses.dataclass
class CoServeResult:
    """Everything a shared-clock co-simulation run produced."""

    results: list[TenantResult]
    repartitions: list[RepartitionEvent]
    #: final global partitions (alive EPs only)
    partitions: dict[str, tuple[int, ...]]
    dead: frozenset

    @property
    def aggregate_slo_rate(self) -> float:
        arrived = sum(r.sim.n_arrived for r in self.results)
        viol = sum(r.sim.n_slo_violations for r in self.results)
        return viol / arrived if arrived else 0.0

    @property
    def aggregate_throughput_rps(self) -> float:
        return sum(r.sim.throughput_rps for r in self.results)

    @property
    def aggregate_energy_j(self) -> float | None:
        """Total package joules across tenants (None without power models)."""
        vals = [
            r.sim.power["energy_j"] for r in self.results if r.sim.power is not None
        ]
        return sum(vals) if vals else None

    @property
    def joules_per_request(self) -> float | None:
        energy = self.aggregate_energy_j
        done = sum(r.sim.n_completed for r in self.results)
        return energy / done if energy is not None and done else None


def co_serve(
    platform: Platform,
    tenants: Sequence[Tenant],
    *,
    strategy: str = "interleaved",
    horizon: float = 30.0,
    make_evaluator: Callable[[Platform, Sequence[Layer]], AnalyticEvaluator] | None = None,
    heuristic: str = "H3",
    max_batch: int = 4,
    batch_efficiency: float = 0.7,
    elastic: bool = True,
    batch_policy_search: bool = False,
    monitor_interval: float = 0.5,
    measure_batches: int = 8,
    alpha: int = 10,
    contention_aware: bool = True,
    placement: bool = False,
    dvfs: bool = False,
    faults: Sequence[tuple] | None = None,
    telemetry=None,
    max_bundle: int = 1,
    loop: EventLoop | None = None,
    chaos=None,
    resilience=None,
) -> CoServeResult:
    """Partition, tune and co-serve all tenants on one shared clock.

    ``faults`` is a script of ``("slowdown", t, global_ep, factor)``,
    ``("dropout", t, global_ep)``, ``("revival", t, global_ep)`` and
    ``("link", t, u, v, factor)`` entries applied to the global platform.
    ``chaos`` (a :class:`~repro.faults.FaultModel`) additionally expands a
    seeded stochastic fault trace — EP deaths/repairs, correlated domain
    failures, link faults, transient batch errors — over the global
    platform; ``resilience`` (a :class:`~repro.faults.ResiliencePolicy`)
    gives every lane deadlines, retries and load shedding.  ``telemetry``
    (a :class:`~repro.telemetry.Telemetry` session; default off) records
    the whole run — tenants as trace processes, EPs/links as tracks.
    ``max_bundle`` allows a victim under extreme pressure to receive up to
    that many EPs in one priced package deal per repartition.
    """
    co = SharedClockCoSimulator(
        platform,
        tenants,
        strategy=strategy,
        make_evaluator=make_evaluator,
        heuristic=heuristic,
        max_batch=max_batch,
        batch_efficiency=batch_efficiency,
        elastic=elastic,
        batch_policy_search=batch_policy_search,
        monitor_interval=monitor_interval,
        measure_batches=measure_batches,
        alpha=alpha,
        contention_aware=contention_aware,
        placement=placement,
        dvfs=dvfs,
        telemetry=telemetry,
        max_bundle=max_bundle,
        loop=loop,
        chaos=chaos,
        resilience=resilience,
    )
    for fault in faults or ():
        if fault[0] == "slowdown":
            co.schedule_slowdown(fault[1], fault[2], fault[3])
        elif fault[0] == "dropout":
            co.schedule_dropout(fault[1], fault[2])
        elif fault[0] == "revival":
            co.schedule_revival(fault[1], fault[2])
        elif fault[0] == "link":
            co.schedule_link_fault(fault[1], fault[2], fault[3], fault[4])
        else:
            raise ValueError(f"unknown fault kind {fault[0]!r}")
    return co.run(horizon)


def co_schedule(
    platform: Platform,
    tenants: Sequence[Tenant],
    *,
    strategy: str = "interleaved",
    horizon: float = 30.0,
    make_evaluator: Callable[[Platform, Sequence[Layer]], AnalyticEvaluator] | None = None,
    heuristic: str = "H3",
    max_batch: int = 4,
    batch_efficiency: float = 0.7,
) -> list[TenantResult]:
    """Partition, tune each tenant with Shisha, and co-simulate its traffic.

    Fault-free, fixed-partition wrapper over :func:`co_serve` — with no
    faults and no elasticity the shared clock reproduces the per-tenant
    independent simulations exactly (disjoint partitions have no other
    interference channel), so this keeps its original contract.
    """
    return co_serve(
        platform,
        tenants,
        strategy=strategy,
        horizon=horizon,
        make_evaluator=make_evaluator,
        heuristic=heuristic,
        max_batch=max_batch,
        batch_efficiency=batch_efficiency,
        elastic=False,
        batch_policy_search=False,
    ).results


def compare_partitions(
    platform: Platform,
    tenants: Sequence[Tenant],
    strategies: Sequence[str] = PARTITION_STRATEGIES,
    **kwargs,
) -> dict[str, list[TenantResult]]:
    """Run ``co_schedule`` under each partition strategy (same traffic)."""
    return {s: co_schedule(platform, tenants, strategy=s, **kwargs) for s in strategies}
