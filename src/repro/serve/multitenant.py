"""Multi-tenant co-scheduling: several CNN pipelines on one platform.

The paper schedules one network onto one chiplet platform; a serving
deployment runs many.  Because Shisha's EP assignment is injective (each
stage owns its EP), the natural multi-tenant form is a *disjoint partition*
of the platform's EPs: each tenant receives a sub-platform, is seeded and
tuned independently (Algorithms 1+2 unchanged), and is simulated under its
own traffic.  Disjointness makes the per-tenant simulations exact — there
is no cross-tenant interference channel other than the partition choice
itself, which is precisely the knob this module compares.

Partition strategies over the H_e ranking (``Platform.ranked()``):

  * ``interleaved``   — deal ranked EPs round-robin, so every tenant gets a
                        fair FEP/SEP mix (heterogeneity-preserving).
  * ``blocked``       — contiguous chunks of the ranking: tenant 0 gets the
                        fastest block (priority tiers).
  * ``proportional``  — deal each ranked EP to the tenant with the largest
                        unmet ``share`` (weighted fairness).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from ..core.cost_model import Layer, weights as layer_weights
from ..core.evaluator import AnalyticEvaluator, DatabaseEvaluator, Trace
from ..core.heuristics import run_shisha
from ..core.platform import Platform
from .simulator import ServingSimulator, SimResult
from .traffic import TrafficGenerator

PARTITION_STRATEGIES = ("interleaved", "blocked", "proportional")


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One hosted pipeline: a network, its traffic, and its SLO."""

    name: str
    layers: tuple[Layer, ...]
    traffic: TrafficGenerator
    #: latency SLO in simulated seconds
    slo: float = 1.0
    #: relative EP share under the "proportional" strategy
    share: float = 1.0


def partition_eps(
    platform: Platform,
    n_parts: int,
    strategy: str = "interleaved",
    shares: Sequence[float] | None = None,
) -> list[tuple[int, ...]]:
    """Split the platform's EP indices into ``n_parts`` disjoint groups."""
    if n_parts < 1 or n_parts > platform.n_eps:
        raise ValueError(f"cannot split {platform.n_eps} EPs into {n_parts} parts")
    ranked = platform.ranked()
    shares = list(shares) if shares is not None else [1.0] * n_parts
    if len(shares) != n_parts or any(s <= 0 for s in shares):
        raise ValueError(f"need {n_parts} positive shares, got {shares}")
    parts: list[list[int]] = [[] for _ in range(n_parts)]
    if strategy == "interleaved":
        for i, ep in enumerate(ranked):
            parts[i % n_parts].append(ep)
    elif strategy == "blocked":
        total = sum(shares)
        sizes = [max(1, round(platform.n_eps * s / total)) for s in shares]
        while sum(sizes) > platform.n_eps:
            sizes[sizes.index(max(sizes))] -= 1
        while sum(sizes) < platform.n_eps:
            sizes[sizes.index(min(sizes))] += 1
        start = 0
        for p, size in enumerate(sizes):
            parts[p] = ranked[start : start + size]
            start += size
    elif strategy == "proportional":
        got = [0.0] * n_parts
        for ep in ranked:
            # largest unmet share takes the next-fastest EP (ties: lower idx)
            p = max(range(n_parts), key=lambda i: (shares[i] - got[i], -i))
            parts[p].append(ep)
            got[p] += 1.0 * sum(shares) / platform.n_eps
    else:
        raise ValueError(f"unknown strategy {strategy!r}; have {PARTITION_STRATEGIES}")
    if any(not p for p in parts):
        raise ValueError(f"strategy {strategy!r} left a tenant with no EPs: {parts}")
    return [tuple(p) for p in parts]


def subplatform(platform: Platform, ep_idxs: Sequence[int], name: str) -> Platform:
    """A tenant's private view: the selected EPs, reindexed from 0."""
    return Platform(name=name, eps=tuple(platform.eps[i] for i in ep_idxs))


@dataclasses.dataclass
class TenantResult:
    tenant: Tenant
    ep_idxs: tuple[int, ...]  # global EP indices owned by this tenant
    conf_pretty: str
    model_throughput: float
    n_trials: int
    sim: SimResult


def co_schedule(
    platform: Platform,
    tenants: Sequence[Tenant],
    *,
    strategy: str = "interleaved",
    horizon: float = 30.0,
    make_evaluator: Callable[[Platform, Sequence[Layer]], AnalyticEvaluator] | None = None,
    heuristic: str = "H3",
    max_batch: int = 4,
    batch_efficiency: float = 0.7,
) -> list[TenantResult]:
    """Partition, tune each tenant with Shisha, and simulate its traffic."""
    if make_evaluator is None:
        make_evaluator = lambda p, layers: DatabaseEvaluator(p, layers)
    parts = partition_eps(
        platform, len(tenants), strategy, shares=[t.share for t in tenants]
    )
    results: list[TenantResult] = []
    for idx, (tenant, ep_idxs) in enumerate(zip(tenants, parts)):
        sub = subplatform(platform, ep_idxs, f"{platform.name}/{tenant.name}")
        ev = make_evaluator(sub, tenant.layers)
        trace = Trace(ev)
        sh = run_shisha(layer_weights(tenant.layers), trace, heuristic)
        conf = sh.result.best_conf
        sim = ServingSimulator(
            ev,
            conf,
            slo=tenant.slo,
            max_batch=max_batch,
            batch_efficiency=batch_efficiency,
        )
        res = sim.run(tenant.traffic.arrivals(horizon), horizon, tenant=idx)
        results.append(
            TenantResult(
                tenant=tenant,
                ep_idxs=ep_idxs,
                conf_pretty=conf.pretty([ep.name for ep in sub.eps]),
                model_throughput=sh.result.best_throughput,
                n_trials=trace.n_trials,
                sim=res,
            )
        )
    return results


def compare_partitions(
    platform: Platform,
    tenants: Sequence[Tenant],
    strategies: Sequence[str] = PARTITION_STRATEGIES,
    **kwargs,
) -> dict[str, list[TenantResult]]:
    """Run ``co_schedule`` under each partition strategy (same traffic)."""
    return {s: co_schedule(platform, tenants, strategy=s, **kwargs) for s in strategies}
