"""Shared LM-architecture machinery: config, parameter trees, shardings.

One flexible block zoo covers all 10 assigned architectures:

  * dense GQA transformer (qwen2/3, granite, nemotron, internvl backbone)
    with the per-arch switches the pool requires: qkv_bias (qwen2),
    qk_norm (qwen3), squared-ReLU FFN (nemotron), explicit head_dim
    (qwen3: 128 ≠ d_model/n_heads).
  * MoE transformer (phi3.5-moe top-2/16, llama4-scout top-1/16 + 1 shared
    expert), with a shard_map token-dispatch that keeps MoE FLOPs *active*
    (capacity-based, sort-free local dispatch; see blocks.py).
  * Mamba2 SSD stack (mamba2-130m) and the Zamba2 hybrid (Mamba2 backbone +
    one shared attention block applied every ``shared_attn_every`` layers).
  * Whisper encoder-decoder (audio frontend stubbed to precomputed frame
    embeddings per the assignment).

Parameters are stored **stacked over layers** (`[L, ...]` leading axis) so
the layer loop is a `lax.scan` — the HLO stays small enough to compile all
40 dry-run cells on 512 host devices, and remat policy applies per scan
step.

Sharding convention (GSPMD, mesh axes ``("pod", "data", "model")``):
  * batch/sequence activations: batch over ``("pod","data")``.
  * weight matrices: the "feature" dim (d_ff, heads, experts' d_ff, vocab)
    over ``model``; the other big dim over ``data`` (FSDP — re-gathered per
    scan step by XLA).
  * parameters are bf16 with fp32 master copies inside the optimizer
    (see optim/adamw.py) so nemotron-4-340b's optimizer state fits 256×16 GB.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 => d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    ffn_kind: str = "swiglu"  # swiglu | relu2
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    #: "attn" for pure transformers, "ssd" for mamba2, "hybrid" for zamba2
    block_kind: str = "attn"
    #: hybrid: apply the shared attention block after every k-th SSD layer
    shared_attn_every: int = 6
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_frames: int = 0
    max_decoder_len: int = 0  # whisper caps self-attn context at 448
    # VLM
    n_patches: int = 0  # internvl: patch embeddings prepended (stub frontend)
    sliding_window: int = 0  # 0 => full attention
    attn_q_block: int = 256  # blockwise-attention q-chunk (memory/roofline knob)
    loss_chunk: int = 512  # chunked-xent sequence chunk
    #: unroll every lax.scan — used by the dry-run's reduced-depth cost
    #: compiles so XLA's cost analysis sees every loop iteration
    scan_unroll: bool = False
    # --- perf-iteration knobs (§Perf; defaults = paper-faithful baseline) ---
    #: Megatron-style sequence-parallel residual stream (seq over TP)
    sp_residuals: bool = True
    #: keep attention scores/softmax in fp32 (False: bf16 scores)
    attn_fp32_scores: bool = True
    #: gradient-accumulation carry dtype
    accum_dtype: Any = jnp.float32
    #: materialize K/V per q-head (repeat over groups) so attention shards
    #: over all n_heads instead of replicating when n_kv_heads < TP
    attn_repeat_kv: bool = False
    #: decode this many tokens per serve_step call (greedy feedback) —
    #: amortizes the per-call FSDP weight gathers across tokens
    decode_block: int = 1
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    remat: str = "full"  # full | none

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Total parameter count N (for MODEL_FLOPS = 6·N·D accounting)."""
        leaves = jax.eval_shape(lambda: init_params(self, jax.random.PRNGKey(0)))
        return sum(math.prod(l.shape) for l in jax.tree.leaves(leaves))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top_k + shared only)."""
        total = self.param_count()
        if not self.is_moe:
            return total
        per_expert = _ffn_param_count(self)
        inactive = (self.n_experts - self.top_k) * per_expert * self.n_layers
        return total - inactive


def _ffn_param_count(cfg: LMConfig) -> int:
    mats = 3 if cfg.ffn_kind == "swiglu" else 2
    return mats * cfg.d_model * cfg.d_ff


# ---------------------------------------------------------------------------
# Initializers (params stacked over layers)
# ---------------------------------------------------------------------------


def _dense(key, shape, scale_dim, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(scale_dim)).astype(dtype)


def _attn_params(cfg: LMConfig, key, n_layers: int, dtype) -> dict:
    ks = jax.random.split(key, 8)
    d, q, kv, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.hd
    L = n_layers
    p = {
        "wq": _dense(ks[0], (L, d, q), d, dtype),
        "wk": _dense(ks[1], (L, d, kv), d, dtype),
        "wv": _dense(ks[2], (L, d, kv), d, dtype),
        "wo": _dense(ks[3], (L, q, d), q, dtype),
        "ln1": jnp.ones((L, d), jnp.float32),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((L, q), dtype)
        p["bk"] = jnp.zeros((L, kv), dtype)
        p["bv"] = jnp.zeros((L, kv), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((L, hd), jnp.float32)
        p["k_norm"] = jnp.ones((L, hd), jnp.float32)
    return p


def _ffn_params(cfg: LMConfig, key, n_layers: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    d, f, L = cfg.d_model, cfg.d_ff, n_layers
    if cfg.is_moe:
        E = cfg.n_experts
        ke = jax.random.split(ks[0], 3)
        p = {
            "router": _dense(ks[2], (L, d, E), d, jnp.float32),
            "we_gate": _dense(ke[0], (L, E, d, f), d, dtype),
            "we_up": _dense(ke[1], (L, E, d, f), d, dtype),
            "we_down": _dense(ke[2], (L, E, f, d), f, dtype),
            "ln2": jnp.ones((L, d), jnp.float32),
        }
        if cfg.n_shared_experts:
            kss = jax.random.split(ks[1], 3)
            fs = f * cfg.n_shared_experts
            p["ws_gate"] = _dense(kss[0], (L, d, fs), d, dtype)
            p["ws_up"] = _dense(kss[1], (L, d, fs), d, dtype)
            p["ws_down"] = _dense(kss[2], (L, fs, d), f, dtype)
        return p
    if cfg.ffn_kind == "swiglu":
        return {
            "w_gate": _dense(ks[0], (L, d, f), d, dtype),
            "w_up": _dense(jax.random.split(ks[2])[0], (L, d, f), d, dtype),
            "w_down": _dense(ks[1], (L, f, d), f, dtype),
            "ln2": jnp.ones((L, d), jnp.float32),
        }
    if cfg.ffn_kind == "relu2":
        return {
            "w_in": _dense(ks[0], (L, d, f), d, dtype),
            "w_out": _dense(ks[1], (L, f, d), f, dtype),
            "ln2": jnp.ones((L, d), jnp.float32),
        }
    raise ValueError(cfg.ffn_kind)


def _ssd_params(cfg: LMConfig, key, n_layers: int, dtype) -> dict:
    ks = jax.random.split(key, 4)
    d, di, n, h, L = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, n_layers
    # in_proj emits [z (di), x (di), B (n), C (n), dt (h)]
    return {
        "in_proj": _dense(ks[0], (L, d, 2 * di + 2 * n + h), d, dtype),
        "conv_w": _dense(ks[1], (L, 4, di + 2 * n), 4, dtype),  # causal depthwise conv
        "A_log": jnp.zeros((L, h), jnp.float32),
        "D": jnp.ones((L, h), jnp.float32),
        "dt_bias": jnp.zeros((L, h), jnp.float32),
        "out_proj": _dense(ks[2], (L, di, d), di, dtype),
        "ln": jnp.ones((L, d), jnp.float32),
        "gate_ln": jnp.ones((L, di), jnp.float32),
    }


def init_params(cfg: LMConfig, key: jax.Array) -> dict:
    """Full parameter tree for any supported architecture."""
    dtype = cfg.dtype
    keys = jax.random.split(key, 10)
    p: dict[str, Any] = {
        "embed": _dense(keys[0], (cfg.vocab, cfg.d_model), cfg.d_model, dtype),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        "unembed": _dense(keys[1], (cfg.d_model, cfg.vocab), cfg.d_model, dtype),
    }
    if cfg.block_kind == "attn":
        p["blocks"] = {
            **_attn_params(cfg, keys[2], cfg.n_layers, dtype),
            **_ffn_params(cfg, keys[3], cfg.n_layers, dtype),
        }
    elif cfg.block_kind == "ssd":
        p["blocks"] = _ssd_params(cfg, keys[2], cfg.n_layers, dtype)
    elif cfg.block_kind == "hybrid":
        p["blocks"] = _ssd_params(cfg, keys[2], cfg.n_layers, dtype)
        shared_cfg = dataclasses.replace(cfg, qkv_bias=False, qk_norm=False, n_experts=0, ffn_kind="swiglu")
        p["shared"] = {
            **_attn_params(shared_cfg, keys[4], 1, dtype),
            **_ffn_params(shared_cfg, keys[5], 1, dtype),
        }
    else:
        raise ValueError(cfg.block_kind)
    if cfg.is_encdec:
        enc_cfg = dataclasses.replace(cfg, qkv_bias=cfg.qkv_bias, n_experts=0)
        p["enc_blocks"] = {
            **_attn_params(enc_cfg, keys[6], cfg.enc_layers, dtype),
            **_ffn_params(enc_cfg, keys[7], cfg.enc_layers, dtype),
        }
        p["enc_ln_f"] = jnp.ones((cfg.d_model,), jnp.float32)
        # decoder cross-attention (stacked over decoder layers)
        ks = jax.random.split(keys[8], 4)
        d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
        L = cfg.n_layers
        p["cross"] = {
            "wq": _dense(ks[0], (L, d, q), d, dtype),
            "wk": _dense(ks[1], (L, d, kv), d, dtype),
            "wv": _dense(ks[2], (L, d, kv), d, dtype),
            "wo": _dense(ks[3], (L, q, d), q, dtype),
            "ln": jnp.ones((L, d), jnp.float32),
        }
    if cfg.n_patches:
        p["patch_proj"] = _dense(keys[9], (cfg.d_model, cfg.d_model), cfg.d_model, dtype)
    return p


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


def param_shardings(cfg: LMConfig, *, fsdp_axis: str | None = "data", tp_axis: str = "model") -> dict:
    """PartitionSpec tree matching init_params' structure.

    TP shards the feature dim; FSDP shards the other matrix dim.  Vectors
    (norm scales, biases) are replicated except long ones sharded on TP.
    """
    f, d = fsdp_axis, tp_axis

    def attn(L_prefix=True):
        sp = {
            "wq": P(None, f, d),
            "wk": P(None, f, d),
            "wv": P(None, f, d),
            "wo": P(None, d, f),
            "ln1": P(None, None),
        }
        if cfg.qkv_bias:
            sp.update(bq=P(None, d), bk=P(None, d), bv=P(None, d))
        if cfg.qk_norm:
            sp.update(q_norm=P(None, None), k_norm=P(None, None))
        return sp

    def ffn():
        if cfg.is_moe:
            sp = {
                "router": P(None, f, None),
                "we_gate": P(None, None, f, d),
                "we_up": P(None, None, f, d),
                "we_down": P(None, None, d, f),
                "ln2": P(None, None),
            }
            if cfg.n_shared_experts:
                sp.update(ws_gate=P(None, f, d), ws_up=P(None, f, d), ws_down=P(None, d, f))
            return sp
        if cfg.ffn_kind == "relu2":
            return {"w_in": P(None, f, d), "w_out": P(None, d, f), "ln2": P(None, None)}
        return {
            "w_gate": P(None, f, d),
            "w_up": P(None, f, d),
            "w_down": P(None, d, f),
            "ln2": P(None, None),
        }

    def ssd():
        return {
            "in_proj": P(None, f, d),
            "conv_w": P(None, None, d),
            "A_log": P(None, None),
            "D": P(None, None),
            "dt_bias": P(None, None),
            "out_proj": P(None, d, f),
            "ln": P(None, None),
            "gate_ln": P(None, d),
        }

    sp: dict[str, Any] = {
        # embed: vocab REPLICATED, d_model TP-sharded — token lookup stays a
        # local gather (vocab-sharded embeddings force an all-gather of the
        # table or one-hot matmuls through the lookup).  unembed: d_model
        # FSDP, vocab TP-sharded — the head matmul emits vocab-sharded
        # logits and the chunked loss reduces over the shard in place.
        "embed": P(None, d),
        "ln_f": P(None),
        "unembed": P(f, d),
    }
    if cfg.block_kind == "attn":
        sp["blocks"] = {**attn(), **ffn()}
    elif cfg.block_kind == "ssd":
        sp["blocks"] = ssd()
    else:  # hybrid
        sp["blocks"] = ssd()
        sp["shared"] = {
            "wq": P(None, f, d),
            "wk": P(None, f, d),
            "wv": P(None, f, d),
            "wo": P(None, d, f),
            "ln1": P(None, None),
            "w_gate": P(None, f, d),
            "w_up": P(None, f, d),
            "w_down": P(None, d, f),
            "ln2": P(None, None),
        }
    if cfg.is_encdec:
        enc_sp = {
            "wq": P(None, f, d),
            "wk": P(None, f, d),
            "wv": P(None, f, d),
            "wo": P(None, d, f),
            "ln1": P(None, None),
            "w_gate": P(None, f, d),
            "w_up": P(None, f, d),
            "w_down": P(None, d, f),
            "ln2": P(None, None),
        }
        if cfg.qkv_bias:
            enc_sp.update(bq=P(None, d), bk=P(None, d), bv=P(None, d))
        sp["enc_blocks"] = enc_sp
        sp["enc_ln_f"] = P(None)
        sp["cross"] = {
            "wq": P(None, f, d),
            "wk": P(None, f, d),
            "wv": P(None, f, d),
            "wo": P(None, d, f),
            "ln": P(None, None),
        }
    if cfg.n_patches:
        sp["patch_proj"] = P(f, d)
    return sp


# ---------------------------------------------------------------------------
# Activation-sharding constraints (GSPMD guard rails)
#
# Without these, XLA's sharding propagation can drift into pathological
# layouts (replicated batch + factor-sharded head dims ⇒ hundred-GiB score
# all-reduces — observed on qwen2, whose 14 heads don't divide TP=16).
# Every layer boundary pins activations to (batch over DP, rest replicated);
# head tensors opt into TP sharding only when the head count divides.
# ---------------------------------------------------------------------------

_DIST: dict = {"mesh": None, "dp": ("data",), "tp": "model", "seq_shard": True}


@contextlib.contextmanager
def dist_context(mesh, dp_axes=("data",), tp_axis: str = "model", seq_shard: bool = True):
    old = dict(_DIST)
    _DIST.update(mesh=mesh, dp=tuple(dp_axes), tp=tp_axis, seq_shard=seq_shard)
    try:
        yield
    finally:
        _DIST.update(old)


def _dp_if_divisible(x, mesh, dp):
    total = 1
    for a in dp:
        total *= mesh.shape[a]
    return dp if x.shape[0] % total == 0 else None


def cstr_act(x: jax.Array) -> jax.Array:
    """Pin [batch, seq, ...] activations: batch over DP, seq over TP.

    Sequence-sharding the residual stream over the ``model`` axis is
    Megatron-style sequence parallelism: remat-saved per-layer residuals
    shrink by the TP extent (nemotron-4-340b: 232 GiB -> 14.5 GiB per
    device), paid for with the per-layer all-gather/reduce-scatter pair XLA
    inserts around the TP matmuls.  Falls back to replicated seq when the
    length doesn't divide (whisper's 1500 frames).
    """
    mesh = _DIST["mesh"]
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = _dp_if_divisible(x, mesh, _DIST["dp"])
    tp = _DIST["tp"]
    seq = tp if (_DIST["seq_shard"] and x.ndim >= 3 and x.shape[1] % mesh.shape[tp] == 0) else None
    rest = [None] * (x.ndim - 2)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, seq, *rest) if x.ndim >= 2 else P(dp))
    )


def cstr_heads(x: jax.Array, head_axis: int) -> jax.Array:
    """Pin [batch, ..., heads, ...]: batch over DP, heads over TP if divisible."""
    return cstr_custom(x, batch_axis=0, tp_axis_at=head_axis)


def cstr_custom(x: jax.Array, *, batch_axis: int | None = None, tp_axis_at: int | None = None) -> jax.Array:
    """Pin arbitrary axes: DP at ``batch_axis``, TP at ``tp_axis_at`` —
    both only when the axis length divides the mesh extent."""
    mesh = _DIST["mesh"]
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    parts: list = [None] * x.ndim
    if batch_axis is not None:
        dp = _DIST["dp"]
        total = 1
        for a in dp:
            total *= mesh.shape[a]
        if x.shape[batch_axis] % total == 0:
            parts[batch_axis] = dp
    tp = _DIST["tp"]
    if tp_axis_at is not None and x.shape[tp_axis_at] % mesh.shape[tp] == 0:
        parts[tp_axis_at] = tp
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*parts)))


# ---------------------------------------------------------------------------
# Small shared ops
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale).astype(x.dtype)


def rotary(x: jax.Array, positions: jax.Array, base: float = 10_000.0) -> jax.Array:
    """Apply RoPE.  x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(base) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
