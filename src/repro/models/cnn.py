"""The paper's CNN workloads: ResNet50, YOLOv3 (Darknet-53), AlexNet, SynthNet.

Two views of each network:

  1. ``*_layers()`` — the per-layer Eq.-1 cost tables the scheduler consumes
     (the paper's "50 compute intensive layers in ResNet50 / 52 in YOLOv3",
     §7.1).  These drive the faithful-reproduction benchmarks.
  2. ``CNNModel`` — a runnable JAX network built from the same table
     (Im2Col+GEMM conv operator, optionally through the Pallas kernel),
     used by the pipeline-inference example and the live-measured oracle.

SynthNet is the paper's synthetic 18-layer network: AlexNet's five conv
layers replicated (channels chained across repeats) to reach 18 layers.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.cost_model import Layer, conv_layer


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    h_out: int
    w_out: int
    c_in: int
    r: int
    s: int
    k: int
    stride: int = 1


def _to_layers(specs: Sequence[ConvSpec]) -> list[Layer]:
    return [
        conv_layer(sp.name, sp.h_out, sp.w_out, sp.c_in, sp.r, sp.s, sp.k)
        for sp in specs
    ]


# ---------------------------------------------------------------------------
# ResNet50 — 50 compute-intensive layers (stem + 16 bottlenecks×3 + fc)
# ---------------------------------------------------------------------------


def resnet50_specs() -> list[ConvSpec]:
    specs = [ConvSpec("stem", 112, 112, 3, 7, 7, 64, stride=2)]
    stage_cfg = [  # (spatial, n_blocks, mid_channels, out_channels)
        (56, 3, 64, 256),
        (28, 4, 128, 512),
        (14, 6, 256, 1024),
        (7, 3, 512, 2048),
    ]
    c_in = 64  # after stem maxpool
    for si, (hw, n_blocks, mid, out) in enumerate(stage_cfg):
        for b in range(n_blocks):
            p = f"s{si + 1}b{b + 1}"
            specs.append(ConvSpec(f"{p}_1x1a", hw, hw, c_in, 1, 1, mid))
            specs.append(ConvSpec(f"{p}_3x3", hw, hw, mid, 3, 3, mid))
            specs.append(ConvSpec(f"{p}_1x1b", hw, hw, mid, 1, 1, out))
            c_in = out
    specs.append(ConvSpec("fc", 1, 1, 2048, 1, 1, 1000))
    assert len(specs) == 50
    return specs


# ---------------------------------------------------------------------------
# YOLOv3 backbone (Darknet-53) — 52 compute-intensive conv layers @416²
# ---------------------------------------------------------------------------


def yolov3_specs() -> list[ConvSpec]:
    specs = [ConvSpec("conv0", 416, 416, 3, 3, 3, 32)]
    c_in = 32
    plan = [  # (spatial after downsample, out_channels, n_residual_blocks)
        (208, 64, 1),
        (104, 128, 2),
        (52, 256, 8),
        (26, 512, 8),
        (13, 1024, 4),
    ]
    for hw, ch, n_res in plan:
        specs.append(ConvSpec(f"down{ch}", hw, hw, c_in, 3, 3, ch, stride=2))
        c_in = ch
        for b in range(n_res):
            specs.append(ConvSpec(f"res{ch}_{b}_1x1", hw, hw, ch, 1, 1, ch // 2))
            specs.append(ConvSpec(f"res{ch}_{b}_3x3", hw, hw, ch // 2, 3, 3, ch))
    assert len(specs) == 52
    return specs


# ---------------------------------------------------------------------------
# AlexNet convs + SynthNet (paper §7.1: AlexNet convs replicated to 18)
# ---------------------------------------------------------------------------


def alexnet_specs(c_in: int = 3, tag: str = "") -> list[ConvSpec]:
    return [
        ConvSpec(f"a{tag}conv1", 55, 55, c_in, 11, 11, 96, stride=4),
        ConvSpec(f"a{tag}conv2", 27, 27, 96, 5, 5, 256),
        ConvSpec(f"a{tag}conv3", 13, 13, 256, 3, 3, 384),
        ConvSpec(f"a{tag}conv4", 13, 13, 384, 3, 3, 384),
        ConvSpec(f"a{tag}conv5", 13, 13, 384, 3, 3, 256),
    ]


def synthnet_specs(n_layers: int = 18) -> list[ConvSpec]:
    specs: list[ConvSpec] = []
    c_in, rep = 3, 0
    while len(specs) < n_layers:
        block = alexnet_specs(c_in, tag=f"r{rep}_")
        specs.extend(block[: n_layers - len(specs)])
        c_in = specs[-1].k
        rep += 1
    return specs


NETWORKS = {
    "resnet50": resnet50_specs,
    "yolov3": yolov3_specs,
    "alexnet": alexnet_specs,
    "synthnet": synthnet_specs,
}


def network_layers(name: str) -> list[Layer]:
    """Per-layer Eq.-1 cost table for a paper network."""
    return _to_layers(NETWORKS[name]())


# ---------------------------------------------------------------------------
# Runnable JAX CNN built from the same spec table
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CNNModel:
    """A runnable conv chain (inference) matching a spec table.

    Spatial dims are synthetic (every layer runs at its table resolution via
    resize), which keeps the chain runnable layer-by-layer — exactly what the
    pipeline runtime needs: each stage applies its own contiguous slice.
    """

    specs: tuple[ConvSpec, ...]

    def init(self, key: jax.Array) -> list[dict[str, jax.Array]]:
        params = []
        for sp in self.specs:
            key, k1 = jax.random.split(key)
            fan_in = sp.c_in * sp.r * sp.s
            w = jax.random.normal(k1, (sp.r, sp.s, sp.c_in, sp.k), jnp.float32)
            params.append({"w": w / np.sqrt(fan_in), "b": jnp.zeros((sp.k,), jnp.float32)})
        return params

    def apply_layer(self, i: int, p: dict[str, jax.Array], x: jax.Array, *, use_pallas: bool = False) -> jax.Array:
        sp = self.specs[i]
        # bring x to this layer's expected input grid
        in_h = sp.h_out * sp.stride
        if x.shape[1] != in_h or x.shape[3] != sp.c_in:
            x = jax.image.resize(x, (x.shape[0], in_h, in_h, sp.c_in), "nearest")
        if use_pallas:
            from ..kernels import ops

            y = ops.conv2d_im2col(x, p["w"], stride=sp.stride)
        else:
            y = jax.lax.conv_general_dilated(
                x,
                p["w"],
                window_strides=(sp.stride, sp.stride),
                padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
        return jax.nn.relu(y + p["b"])

    def apply_range(self, params, x: jax.Array, start: int, end: int, **kw) -> jax.Array:
        for i in range(start, end):
            x = self.apply_layer(i, params[i], x, **kw)
        return x

    def __call__(self, params, x: jax.Array, **kw) -> jax.Array:
        return self.apply_range(params, x, 0, len(self.specs), **kw)


def canonical_pipeline_apply(model: CNNModel, params, input_shape: tuple[int, int, int]):
    """Shape-uniform layer application for the stage pipeline.

    Pipeline stages must be branch-compatible under lax.switch, so every
    layer maps a canonical zero-padded activation [B, Hc, Wc, Cc] to itself.
    Padding + exact cropping (never resizing through the pad) keeps the
    pipelined result bit-identical to sequential execution.

    Returns (apply_fn, to_canon, crop_out, canon_shape).
    """
    specs = model.specs
    hc = max([input_shape[0]] + [sp.h_out * sp.stride for sp in specs] + [sp.h_out for sp in specs])
    wc = max([input_shape[1]] + [sp.w_out * sp.stride for sp in specs] + [sp.w_out for sp in specs])
    cc = max([input_shape[2]] + [sp.c_in for sp in specs] + [sp.k for sp in specs])
    canon = (hc, wc, cc)

    def to_canon(x):
        return jnp.pad(
            x,
            ((0, 0), (0, hc - x.shape[1]), (0, wc - x.shape[2]), (0, cc - x.shape[3])),
        )

    def shape_into(i):
        if i == 0:
            return input_shape
        sp = specs[i - 1]
        return (sp.h_out, sp.w_out, sp.k)

    def apply_fn(i, xc):
        h, w, c = shape_into(i)
        x = xc[:, :h, :w, :c]
        y = model.apply_layer(i, params[i], x)
        return to_canon(y)

    def crop_out(xc):
        sp = specs[-1]
        return xc[..., : sp.h_out, : sp.w_out, : sp.k]

    return apply_fn, to_canon, crop_out, canon


def make_cnn(name: str, scale: float = 1.0) -> CNNModel:
    """Runnable model; ``scale`` shrinks channels for CPU smoke tests."""
    specs = NETWORKS[name]()
    if scale != 1.0:
        scaled = []
        prev_k = None
        for sp in specs:
            c_in = prev_k if prev_k is not None else sp.c_in
            k = max(8, int(sp.k * scale))
            h = max(4, int(sp.h_out * scale))
            scaled.append(dataclasses.replace(sp, h_out=h, w_out=h, c_in=c_in, k=k))
            prev_k = k
        specs = scaled
    return CNNModel(specs=tuple(specs))
