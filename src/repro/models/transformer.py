"""Model assembly: forward / loss / train / decode for every architecture.

One entry point per phase, uniform across the 10 assigned architectures:

  * ``train_loss(cfg, params, batch, mesh)``   — full fwd + chunked xent.
  * ``make_train_step(cfg, opt, mesh)``        — loss + grad + AdamW update.
  * ``init_cache(cfg, batch, max_len)``        — decode-state pytree.
  * ``make_serve_step(cfg, mesh)``             — one-token decode.
  * ``prefill(cfg, params, batch, cache)``     — encoder pass / KV warmup.

The layer loop is `lax.scan` over `[L, ...]`-stacked params; remat is a
`jax.checkpoint` around the scan body (policy: save the per-layer residual
stream only).  Hybrid (zamba2) runs an outer scan over groups of
``shared_attn_every`` SSD layers with the shared attention block applied
between groups.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from . import blocks
from .lm_common import LMConfig, cstr_act, dist_context, init_params, param_shardings, rms_norm


def _scan(cfg: LMConfig, f, init, xs):
    """lax.scan that fully unrolls under cfg.scan_unroll (dry-run cost mode)."""
    return jax.lax.scan(f, init, xs, unroll=bool(cfg.scan_unroll))

# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_tokens(cfg: LMConfig, params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embed"], tokens, axis=0)


def lm_head_loss(cfg: LMConfig, params: dict, h: jax.Array, labels: jax.Array, mask: jax.Array) -> jax.Array:
    """Chunked softmax cross-entropy: never materializes [B, S, V] at once."""
    b, s, d = h.shape
    cs = s
    for cand in (cfg.loss_chunk, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if s % cand == 0:
            cs = cand
            break
    n_chunks = s // cs
    hc = h.reshape(b, n_chunks, cs, d).transpose(1, 0, 2, 3)
    yc = labels.reshape(b, n_chunks, cs).transpose(1, 0, 2)
    mc = mask.reshape(b, n_chunks, cs).transpose(1, 0, 2)

    def body(acc, inp):
        hh, yy, mm = inp
        hh = cstr_act(hh)
        logits = (hh @ params["unembed"]).astype(jnp.float32)
        # reductions over the (TP-sharded) vocab axis partition cleanly;
        # the gold logit is a one-hot contraction — a take_along_axis here
        # would force XLA to all-gather the full logits chunk.
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        logz = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        onehot = jax.nn.one_hot(yy, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.sum(logits * onehot, axis=-1)
        nll = (logz - gold) * mm
        return acc + nll.sum(), None

    # checkpoint: backward re-computes each logits chunk instead of saving
    # n_chunks × [B, cs, V] residuals (the whole point of chunking).
    total, _ = _scan(cfg, jax.checkpoint(body, prevent_cse=False), jnp.zeros((), jnp.float32), (hc, yc, mc))
    return total / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Backbones (full sequence)
# ---------------------------------------------------------------------------


def _attn_block_fwd(cfg: LMConfig, lp: dict, x: jax.Array, positions, mesh, dp_axes, tp_axis):
    x = blocks.attention(cfg, lp, x, positions, causal=True, window=cfg.sliding_window)
    if cfg.is_moe:
        x, aux = blocks.moe_ffn(cfg, lp, x, mesh, dp_axes, tp_axis)
    else:
        x, aux = blocks.dense_ffn(cfg, lp, x), jnp.zeros(())
    return x, aux


def _maybe_remat(cfg: LMConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    return fn


def backbone(
    cfg: LMConfig,
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    mesh=None,
    dp_axes=("data",),
    tp_axis: str = "model",
) -> tuple[jax.Array, jax.Array]:
    """Run the layer stack on embedded inputs x. Returns (h, aux_loss)."""
    if cfg.block_kind == "attn":

        def body(carry, lp):
            h, aux = carry
            h = cstr_act(h)
            h, a = _attn_block_fwd(cfg, lp, h, positions, mesh, dp_axes, tp_axis)
            return (cstr_act(h), aux + a), None

        (x, aux), _ = _scan(cfg, _maybe_remat(cfg, body), (x, jnp.zeros(())), params["blocks"])
        return rms_norm(x, params["ln_f"], cfg.norm_eps), aux

    if cfg.block_kind == "ssd":

        def body(carry, lp):
            (h,) = carry
            return (cstr_act(blocks.ssd_block(cfg, lp, cstr_act(h))),), None

        (x,), _ = _scan(cfg, _maybe_remat(cfg, body), (x,), params["blocks"])
        return rms_norm(x, params["ln_f"], cfg.norm_eps), jnp.zeros(())

    if cfg.block_kind == "hybrid":
        k = cfg.shared_attn_every
        n_groups = cfg.n_layers // k
        grouped = jax.tree.map(lambda a: a.reshape(n_groups, k, *a.shape[1:]), params["blocks"])
        shared = jax.tree.map(lambda a: a[0], params["shared"])  # strip L=1

        def inner(carry, lp):
            (h,) = carry
            return (cstr_act(blocks.ssd_block(cfg, lp, cstr_act(h))),), None

        def group_body(carry, group_params):
            (h,) = carry
            (h,), _ = _scan(cfg, _maybe_remat(cfg, inner), (h,), group_params)
            h = blocks.attention(cfg, shared, h, positions, causal=True, window=cfg.sliding_window)
            h = blocks.dense_ffn(
                dataclasses.replace(cfg, ffn_kind="swiglu", n_experts=0), shared, h
            )
            return (h,), None

        (x,), _ = _scan(cfg, group_body, (x,), grouped)
        return rms_norm(x, params["ln_f"], cfg.norm_eps), jnp.zeros(())

    raise ValueError(cfg.block_kind)


def encoder(cfg: LMConfig, params: dict, frames: jax.Array) -> jax.Array:
    """Whisper encoder: bidirectional attention over (stubbed) frame embeds."""
    positions = jnp.arange(frames.shape[1])[None, :] * jnp.ones((frames.shape[0], 1), jnp.int32)
    enc_cfg = dataclasses.replace(cfg, n_experts=0, ffn_kind="swiglu", sliding_window=0)

    def body(carry, lp):
        (h,) = carry
        h = blocks.attention(enc_cfg, lp, cstr_act(h), positions, causal=False)
        h = blocks.dense_ffn(enc_cfg, lp, h)
        return (cstr_act(h),), None

    (h,), _ = _scan(cfg, _maybe_remat(cfg, body), (frames,), params["enc_blocks"])
    return rms_norm(h, params["enc_ln_f"], cfg.norm_eps)


def decoder_with_cross(
    cfg: LMConfig, params: dict, x: jax.Array, positions: jax.Array, enc_out: jax.Array
) -> jax.Array:
    def body(carry, lps):
        (h,) = carry
        lp, cp = lps
        h = blocks.attention(cfg, lp, cstr_act(h), positions, causal=True)
        h = blocks.cross_attention(cfg, cp, h, enc_out)
        h = blocks.dense_ffn(cfg, lp, h)
        return (cstr_act(h),), None

    (h,), _ = _scan(cfg, _maybe_remat(cfg, body), (x,), (params["blocks"], params["cross"]))
    return rms_norm(h, params["ln_f"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------


def train_loss(cfg: LMConfig, params: dict, batch: dict, mesh=None, dp_axes=("data",), tp_axis="model") -> jax.Array:
    """Next-token loss for any architecture family."""
    with dist_context(mesh, dp_axes, tp_axis, seq_shard=cfg.sp_residuals):
        return _train_loss(cfg, params, batch, mesh, dp_axes, tp_axis)


def _train_loss(cfg, params, batch, mesh, dp_axes, tp_axis):
    if cfg.is_encdec:
        enc_out = encoder(cfg, params, batch["frames"].astype(cfg.dtype))
        tokens = batch["tokens"]
        x = embed_tokens(cfg, params, tokens)
        positions = jnp.arange(tokens.shape[1])[None, :] * jnp.ones((tokens.shape[0], 1), jnp.int32)
        h = decoder_with_cross(cfg, params, x, positions, enc_out)
        return lm_head_loss(cfg, params, h, batch["labels"], batch["labels"] >= 0)

    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    if cfg.n_patches:
        patches = batch["patch_embeds"].astype(cfg.dtype) @ params["patch_proj"]
        x = jnp.concatenate([patches, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :] * jnp.ones((b, 1), jnp.int32)
    h, aux = backbone(cfg, params, x, positions, mesh, dp_axes, tp_axis)
    if cfg.n_patches:
        h = h[:, cfg.n_patches :, :]
    loss = lm_head_loss(cfg, params, h, batch["labels"], batch["labels"] >= 0)
    return loss + 0.01 * aux


def make_train_step(cfg: LMConfig, optimizer, mesh=None, dp_axes=("data",), tp_axis="model", accum: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``accum > 1`` splits the global batch into that many microbatches and
    accumulates fp32 gradients under lax.scan — the standard way to fit
    activation memory for the multi-hundred-B train cells.
    """

    def loss_fn(p, b):
        return train_loss(cfg, p, b, mesh, dp_axes, tp_axis)

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), batch
            )
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.accum_dtype), params)

            def body(carry, mbatch):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                gsum = jax.tree.map(lambda a, b: a + b.astype(cfg.accum_dtype), gsum, g)
                return (gsum, lsum + l), None

            (grads, loss), _ = _scan(cfg, body, (g0, jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
        params, opt_state, om = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, **om}

    return train_step


# ---------------------------------------------------------------------------
# Decoding / serving
# ---------------------------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> dict:
    """Decode-state pytree. Ring KV for attention; SSM state for SSD."""
    cache: dict[str, Any] = {"index": jnp.zeros((), jnp.int32)}
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    if cfg.block_kind == "attn":
        L = cfg.n_layers
        cache["k"] = jnp.zeros((L, batch, W, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        cache["v"] = jnp.zeros((L, batch, W, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        cache["pos"] = -jnp.ones((L, W), jnp.int32)
    elif cfg.block_kind in ("ssd", "hybrid"):
        L = cfg.n_layers
        cache["ssm"] = jnp.zeros((L, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), cfg.dtype)
        cache["conv"] = jnp.zeros((L, batch, 3, cfg.d_inner + 2 * cfg.ssm_state), cfg.dtype)
        if cfg.block_kind == "hybrid":
            g = cfg.n_layers // cfg.shared_attn_every
            cache["shared_k"] = jnp.zeros((g, batch, W, cfg.n_kv_heads, cfg.hd), cfg.dtype)
            cache["shared_v"] = jnp.zeros((g, batch, W, cfg.n_kv_heads, cfg.hd), cfg.dtype)
            cache["shared_pos"] = -jnp.ones((g, W), jnp.int32)
    if cfg.is_encdec:
        # decoder self-attn ring (W capped at whisper's 448) + cross K/V set at prefill
        Wd = min(max_len, cfg.max_decoder_len or max_len)
        L = cfg.n_layers
        cache["k"] = jnp.zeros((L, batch, Wd, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        cache["v"] = jnp.zeros((L, batch, Wd, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        cache["pos"] = -jnp.ones((L, Wd), jnp.int32)
        cache["cross_k"] = jnp.zeros((L, batch, cfg.enc_frames, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        cache["cross_v"] = jnp.zeros((L, batch, cfg.enc_frames, cfg.n_kv_heads, cfg.hd), cfg.dtype)
    return cache


def prefill(cfg: LMConfig, params: dict, batch: dict, cache: dict) -> dict:
    """Encoder pass + cross-KV warmup (enc-dec only; LM prefill = train fwd)."""
    if not cfg.is_encdec:
        return cache
    enc_out = encoder(cfg, params, batch["frames"].astype(cfg.dtype))
    b, se, _ = enc_out.shape

    def per_layer(cp):
        k = (enc_out @ cp["wk"]).reshape(b, se, cfg.n_kv_heads, cfg.hd)
        v = (enc_out @ cp["wv"]).reshape(b, se, cfg.n_kv_heads, cfg.hd)
        return k, v

    k, v = jax.vmap(per_layer)(params["cross"])
    return {**cache, "cross_k": k, "cross_v": v}


def serve_step(cfg: LMConfig, params: dict, cache: dict, tokens: jax.Array, mesh=None, dp_axes=("data",), tp_axis="model"):
    """Decode one token.  tokens: [b, 1] -> (logits [b, vocab], cache')."""
    with dist_context(mesh, dp_axes, tp_axis, seq_shard=cfg.sp_residuals):
        return _serve_step(cfg, params, cache, tokens, mesh, dp_axes, tp_axis)


def _serve_step(cfg, params, cache, tokens, mesh, dp_axes, tp_axis):
    index = cache["index"]
    x = embed_tokens(cfg, params, tokens)

    if cfg.is_encdec:

        def body(h, inp):
            lp, cp, ck, cv, cpos, xk, xv = inp
            h, ck, cv, cpos = blocks.attention_decode(cfg, lp, h, ck, cv, cpos, index)
            # cross attention against prefilled encoder KV
            hq = rms_norm(h, cp["ln"], cfg.norm_eps)
            b = h.shape[0]
            q = (hq @ cp["wq"]).reshape(b, 1, cfg.n_heads, cfg.hd)
            o = blocks._sdpa(cfg, q, xk, xv, causal=False)
            h = h + o @ cp["wo"]
            h = blocks.dense_ffn(cfg, lp, h)
            return h, (ck, cv, cpos)

        x, (k2, v2, p2) = _scan(cfg, 
            body,
            x,
            (params["blocks"], params["cross"], cache["k"], cache["v"], cache["pos"], cache["cross_k"], cache["cross_v"]),
        )
        cache = {**cache, "k": k2, "v": v2, "pos": p2, "index": index + 1}

    elif cfg.block_kind == "attn":

        def body(h, inp):
            lp, ck, cv, cpos = inp
            h, ck, cv, cpos = blocks.attention_decode(
                cfg, lp, h, ck, cv, cpos, index, window=cfg.sliding_window
            )
            if cfg.is_moe:
                h2, _ = blocks.moe_ffn(cfg, lp, h, mesh, dp_axes, tp_axis)
            else:
                h2 = blocks.dense_ffn(cfg, lp, h)
            return h2, (ck, cv, cpos)

        x, (k2, v2, p2) = _scan(cfg, body, x, (params["blocks"], cache["k"], cache["v"], cache["pos"]))
        cache = {**cache, "k": k2, "v": v2, "pos": p2, "index": index + 1}

    elif cfg.block_kind == "ssd":

        def body(h, inp):
            lp, ssm, conv = inp
            h, ssm, conv = blocks.ssd_decode(cfg, lp, h, ssm, conv)
            return h, (ssm, conv)

        x, (ssm2, conv2) = _scan(cfg, body, x, (params["blocks"], cache["ssm"], cache["conv"]))
        cache = {**cache, "ssm": ssm2, "conv": conv2, "index": index + 1}

    elif cfg.block_kind == "hybrid":
        k = cfg.shared_attn_every
        n_groups = cfg.n_layers // k
        grouped = jax.tree.map(lambda a: a.reshape(n_groups, k, *a.shape[1:]), params["blocks"])
        shared = jax.tree.map(lambda a: a[0], params["shared"])
        g_ssm = cache["ssm"].reshape(n_groups, k, *cache["ssm"].shape[1:])
        g_conv = cache["conv"].reshape(n_groups, k, *cache["conv"].shape[1:])

        def inner(h, inp):
            lp, ssm, conv = inp
            h, ssm, conv = blocks.ssd_decode(cfg, lp, h, ssm, conv)
            return h, (ssm, conv)

        def group_body(h, inp):
            gp, ssm, conv, sk, sv, spos = inp
            h, (ssm2, conv2) = _scan(cfg, inner, h, (gp, ssm, conv))
            h, sk, sv, spos = blocks.attention_decode(
                cfg, shared, h, sk, sv, spos, index, window=cfg.sliding_window
            )
            h = blocks.dense_ffn(dataclasses.replace(cfg, ffn_kind="swiglu", n_experts=0), shared, h)
            return h, (ssm2, conv2, sk, sv, spos)

        x, (ssm2, conv2, sk2, sv2, sp2) = _scan(cfg, 
            group_body,
            x,
            (grouped, g_ssm, g_conv, cache["shared_k"], cache["shared_v"], cache["shared_pos"]),
        )
        cache = {
            **cache,
            "ssm": ssm2.reshape(cache["ssm"].shape),
            "conv": conv2.reshape(cache["conv"].shape),
            "shared_k": sk2,
            "shared_v": sv2,
            "shared_pos": sp2,
            "index": index + 1,
        }
    else:
        raise ValueError(cfg.block_kind)

    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (h[:, 0, :] @ params["unembed"]).astype(jnp.float32)
    return logits, cache


def make_serve_step(cfg: LMConfig, mesh=None, dp_axes=("data",), tp_axis="model"):
    return partial(serve_step, cfg, mesh=mesh, dp_axes=dp_axes, tp_axis=tp_axis)


def serve_block(cfg: LMConfig, params: dict, cache: dict, tokens: jax.Array, mesh=None, dp_axes=("data",), tp_axis="model"):
    """Decode ``cfg.decode_block`` tokens in one call (greedy feedback).

    One jit invocation = one pass of FSDP weight gathers amortized over the
    whole block — the §Perf fix for collective-bound decode cells.  Returns
    (logits of the LAST token, cache).
    """
    k = cfg.decode_block
    if k <= 1:
        return serve_step(cfg, params, cache, tokens, mesh, dp_axes, tp_axis)

    def body(carry, _):
        tok, cache = carry
        logits, cache = serve_step(cfg, params, cache, tok, mesh, dp_axes, tp_axis)
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(tok.dtype)
        return (nxt, cache), None

    (tok, cache), _ = _scan(cfg, body, (tokens, cache), jnp.arange(k - 1))
    logits, cache = serve_step(cfg, params, cache, tok, mesh, dp_axes, tp_axis)
    return logits, cache


# ---------------------------------------------------------------------------
# Prefill: full-sequence forward that also materializes the decode cache
# ---------------------------------------------------------------------------


def prefill_step(cfg: LMConfig, params: dict, batch: dict, mesh=None, dp_axes=("data",), tp_axis="model", max_len: int | None = None):
    """Serving prefill: forward over the prompt, emitting the decode cache.

    Returns (last-token logits [b, vocab], cache).  The cache layout matches
    ``init_cache(cfg, b, seq)`` so decode can continue from it directly —
    and the dry-run's prefill cells account the real cache-write traffic.
    """
    with dist_context(mesh, dp_axes, tp_axis, seq_shard=cfg.sp_residuals):
        return _prefill_step(cfg, params, batch, mesh, dp_axes, tp_axis, max_len)


def _prefill_step(cfg, params, batch, mesh, dp_axes, tp_axis, max_len=None):
    if cfg.is_encdec:
        # whisper: encode + cross-KV, then prefill the (capped) decoder prompt
        cache = init_cache(cfg, batch["tokens"].shape[0], cfg.max_decoder_len)
        cache = prefill(cfg, params, batch, cache)
        tokens = batch["tokens"][:, : cfg.max_decoder_len]
        x = embed_tokens(cfg, params, tokens)
        b, s, _ = x.shape
        positions = jnp.arange(s)[None, :] * jnp.ones((b, 1), jnp.int32)
        enc_out = encoder(cfg, params, batch["frames"].astype(cfg.dtype))

        def body(carry, lps):
            (h,) = carry
            lp, cp = lps
            h, k, v = blocks.attention(cfg, lp, h, positions, causal=True, return_kv=True)
            h = blocks.cross_attention(cfg, cp, h, enc_out)
            h = blocks.dense_ffn(cfg, lp, h)
            return (h,), (k, v)

        (h,), (ks, vs) = _scan(cfg, _maybe_remat(cfg, body), (x,), (params["blocks"], params["cross"]))
        h = rms_norm(h, params["ln_f"], cfg.norm_eps)
        W = cache["k"].shape[2]
        cache = {
            **cache,
            "k": jnp.zeros_like(cache["k"]).at[:, :, :s].set(ks[:, :, :W].astype(cfg.dtype)),
            "v": jnp.zeros_like(cache["v"]).at[:, :, :s].set(vs[:, :, :W].astype(cfg.dtype)),
            "pos": jnp.where(jnp.arange(W)[None, :] < s, jnp.arange(W)[None, :], -1)
            * jnp.ones((cfg.n_layers, 1), jnp.int32),
            "index": jnp.asarray(s, jnp.int32),
        }
        logits = (h[:, -1, :] @ params["unembed"]).astype(jnp.float32)
        return logits, cache

    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    if cfg.n_patches:
        patches = batch["patch_embeds"].astype(cfg.dtype) @ params["patch_proj"]
        x = jnp.concatenate([patches, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :] * jnp.ones((b, 1), jnp.int32)
    pos_row = jnp.arange(s, dtype=jnp.int32)

    if cfg.block_kind == "attn":

        def body(carry, lp):
            (h,) = carry
            h, k, v = blocks.attention(
                cfg, lp, h, positions, causal=True, window=cfg.sliding_window, return_kv=True
            )
            if cfg.is_moe:
                h, _ = blocks.moe_ffn(cfg, lp, h, mesh, dp_axes, tp_axis)
            else:
                h = blocks.dense_ffn(cfg, lp, h)
            return (h,), (k.astype(cfg.dtype), v.astype(cfg.dtype))

        (h,), (ks, vs) = _scan(cfg, _maybe_remat(cfg, body), (x,), params["blocks"])
        W = max(max_len or s, s)
        if W > s:  # leave room for decode continuation
            pad = ((0, 0), (0, 0), (0, W - s), (0, 0), (0, 0))
            ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
        pos = jnp.where(jnp.arange(W) < s, jnp.arange(W), -1)
        cache = {
            "k": ks,
            "v": vs,
            "pos": jnp.broadcast_to(pos[None, :], (cfg.n_layers, W)).astype(jnp.int32),
            "index": jnp.asarray(s, jnp.int32),
        }
    elif cfg.block_kind == "ssd":

        def body(carry, lp):
            (h,) = carry
            h, state, conv_tail = blocks.ssd_block(cfg, lp, h, return_state=True)
            return (h,), (state, conv_tail)

        (h,), (ssm, conv) = _scan(cfg, _maybe_remat(cfg, body), (x,), params["blocks"])
        cache = {"ssm": ssm, "conv": conv, "index": jnp.asarray(s, jnp.int32)}
    elif cfg.block_kind == "hybrid":
        k_every = cfg.shared_attn_every
        n_groups = cfg.n_layers // k_every
        grouped = jax.tree.map(lambda a: a.reshape(n_groups, k_every, *a.shape[1:]), params["blocks"])
        shared = jax.tree.map(lambda a: a[0], params["shared"])

        def inner(carry, lp):
            (h,) = carry
            h, state, conv_tail = blocks.ssd_block(cfg, lp, h, return_state=True)
            return (h,), (state, conv_tail)

        def group_body(carry, gp):
            (h,) = carry
            (h,), (ssm, conv) = _scan(cfg, _maybe_remat(cfg, inner), (h,), gp)
            h, sk, sv = blocks.attention(
                cfg, shared, h, positions, causal=True, window=cfg.sliding_window, return_kv=True
            )
            h = blocks.dense_ffn(dataclasses.replace(cfg, ffn_kind="swiglu", n_experts=0), shared, h)
            return (h,), (ssm, conv, sk.astype(cfg.dtype), sv.astype(cfg.dtype))

        (h,), (ssm, conv, sks, svs) = _scan(cfg, group_body, (x,), grouped)
        W = min(s, cfg.sliding_window) if cfg.sliding_window else s
        # ring layout: slot = pos % W; for prefill keep the LAST W positions
        sel = pos_row[-W:]
        slots = sel % W
        sk_ring = jnp.zeros((n_groups, b, W, cfg.n_kv_heads, cfg.hd), cfg.dtype).at[:, :, slots].set(sks[:, :, -W:])
        sv_ring = jnp.zeros((n_groups, b, W, cfg.n_kv_heads, cfg.hd), cfg.dtype).at[:, :, slots].set(svs[:, :, -W:])
        spos = -jnp.ones((n_groups, W), jnp.int32)
        spos = spos.at[:, slots].set(jnp.broadcast_to(sel[None, :], (n_groups, W)))
        cache = {
            "ssm": ssm.reshape(cfg.n_layers, b, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            "conv": conv.reshape(cfg.n_layers, b, 3, cfg.d_inner + 2 * cfg.ssm_state),
            "shared_k": sk_ring,
            "shared_v": sv_ring,
            "shared_pos": spos,
            "index": jnp.asarray(s, jnp.int32),
        }
    else:
        raise ValueError(cfg.block_kind)

    h = rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = (h[:, -1, :] @ params["unembed"]).astype(jnp.float32)
    return logits, cache


# ---------------------------------------------------------------------------
# Shisha integration: per-layer static costs (generalized Eq. 1)
# ---------------------------------------------------------------------------


def layer_costs(cfg: LMConfig, seq: int, batch: int = 1):
    """Per-block cost Layers for the scheduler (DESIGN.md §4)."""
    from ..core.cost_model import Layer, attention_layer, ffn_layer, fuse, ssd_layer

    out: list[Layer] = []
    if cfg.is_encdec:
        for i in range(cfg.enc_layers):
            a = attention_layer(f"enc{i}.attn", cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.enc_frames, batch=batch)
            f = ffn_layer(f"enc{i}.ffn", cfg.d_model, cfg.d_ff, seq=cfg.enc_frames, batch=batch)
            out.append(fuse(f"enc{i}", [a, f]))
        dec_len = min(seq, cfg.max_decoder_len or seq)
        for i in range(cfg.n_layers):
            a = attention_layer(f"dec{i}.attn", cfg.d_model, cfg.n_heads, cfg.n_kv_heads, dec_len, batch=batch)
            c = attention_layer(f"dec{i}.cross", cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.enc_frames, batch=batch)
            f = ffn_layer(f"dec{i}.ffn", cfg.d_model, cfg.d_ff, seq=dec_len, batch=batch)
            out.append(fuse(f"dec{i}", [a, c, f]))
        return out
    if cfg.block_kind == "attn":
        for i in range(cfg.n_layers):
            a = attention_layer(
                f"blk{i}.attn", cfg.d_model, cfg.n_heads, cfg.n_kv_heads, seq, batch=batch,
                window=cfg.sliding_window or None,
            )
            f = ffn_layer(
                f"blk{i}.ffn", cfg.d_model, cfg.d_ff, seq=seq, batch=batch,
                gated=cfg.ffn_kind == "swiglu",
                n_experts=cfg.n_experts, top_k=cfg.top_k,
            )
            out.append(fuse(f"blk{i}", [a, f], kind="moe" if cfg.is_moe else "block"))
        return out
    # ssd / hybrid
    for i in range(cfg.n_layers):
        s = ssd_layer(f"blk{i}.ssd", cfg.d_model, cfg.ssm_state, seq=seq, batch=batch, expand=cfg.ssm_expand)
        if cfg.block_kind == "hybrid" and (i + 1) % cfg.shared_attn_every == 0:
            a = attention_layer(
                f"blk{i}.shared_attn", cfg.d_model, cfg.n_heads, cfg.n_kv_heads, seq, batch=batch,
                window=cfg.sliding_window or None,
            )
            f = ffn_layer(f"blk{i}.shared_ffn", cfg.d_model, cfg.d_ff, seq=seq, batch=batch)
            out.append(fuse(f"blk{i}", [s, a, f], kind="hybrid"))
        else:
            out.append(s)
    return out
