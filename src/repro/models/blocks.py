"""Forward blocks: GQA attention, dense/MoE FFN, Mamba2 SSD.

All functions take the *per-layer* parameter slice (scan has already
stripped the leading [L] axis) and are shape-polymorphic in batch/sequence.

MoE dispatch (`moe_ffn_local`) is deliberately **local and sort-free**: it
runs per data-shard inside `shard_map`, so token routing never crosses
devices — expert weights are tensor-parallel on d_ff over the ``model``
axis and the only collective is the same psum a dense TP FFN needs.  This
keeps compiled MoE FLOPs proportional to *active* experts (top_k), which is
what the roofline table must reflect (DESIGN.md §4).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..compat import shard_map
from .lm_common import LMConfig, cstr_act, cstr_custom, cstr_heads, rms_norm, rotary

# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _qkv(cfg: LMConfig, p: dict, x: jax.Array, positions: jax.Array):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.hd)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rotary(q, positions)
    k = rotary(k, positions)
    return cstr_heads(q, 2), cstr_heads(k, 2), cstr_heads(v, 2)


def _sdpa_chunk(cfg: LMConfig, qg, k, v, q_pos, *, causal: bool, window: int):
    """Exact attention for one q chunk.

    qg: [b, bq, kvh, g, d]; k/v: [b, skv, kvh, d] — or, under
    ``attn_repeat_kv`` (k/v pre-repeated per q-head and the group axis
    merged), qg: [b, bq, H, 1, d]; k/v: [b, skv, H, d].
    """
    d = qg.shape[-1]
    skv = k.shape[1]
    score_t = jnp.float32 if cfg.attn_fp32_scores else jnp.bfloat16
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(score_t) / math.sqrt(d)
    k_pos = jnp.arange(skv)
    mask = jnp.ones((qg.shape[1], skv), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    scores = jnp.where(mask, scores, jnp.asarray(-jnp.inf, scores.dtype))
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(qg.dtype) \
        if cfg.attn_fp32_scores else jax.nn.softmax(scores, axis=-1).astype(qg.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def _sdpa(cfg: LMConfig, q, k, v, *, causal: bool, q_offset: jax.Array | int = 0, window: int = 0):
    """Blockwise softmax attention with GQA head grouping.

    q: [b, sq, h, d]; k/v: [b, skv, kvh, d].  ``q_offset`` is the absolute
    position of q[0].  ``window``: sliding-window size (0 = full).

    The q axis is swept in ``cfg.attn_q_block`` chunks under lax.scan with a
    rematerialized body, so live score buffers stay O(bq·skv) — this is the
    XLA stand-in for the Pallas flash kernel (kernels/flash_attention.py),
    with the same asymptotic memory behaviour on the dry-run roofline.
    """
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    if cfg.attn_repeat_kv and group > 1:
        # shard attention over ALL q-heads: repeat K/V per head (each model
        # shard materializes only its heads' copies) and merge the group
        # axis — otherwise kvh < TP replicates score compute TP-fold.
        k = cstr_heads(jnp.repeat(k, group, axis=2), 2)
        v = cstr_heads(jnp.repeat(v, group, axis=2), 2)
        kvh, group = h, 1
    qg = q.reshape(b, sq, kvh, group, d)
    bq = cfg.attn_q_block
    if sq <= bq or sq % bq != 0:
        out = _sdpa_chunk(cfg, qg, k, v, jnp.arange(sq) + q_offset, causal=causal, window=window)
        return out.reshape(b, sq, h * d)

    nq = sq // bq
    # layout pin: chunk axis UNSHARDED, batch over DP, kv-heads over TP when
    # divisible — without this the residual stream's seq-sharding lands on
    # the chunk axis and SPMD falls back to "involuntary full remat"
    # (observed: per-chunk full replication on nemotron-4-340b).
    qc = qg.reshape(b, nq, bq, kvh, group, d).transpose(1, 0, 2, 3, 4, 5)
    qc = cstr_custom(qc, batch_axis=1, tp_axis_at=3)

    def body(i, q_chunk):
        q_pos = i * bq + jnp.arange(bq) + q_offset
        out = _sdpa_chunk(cfg, q_chunk, k, v, q_pos, causal=causal, window=window)
        return i + 1, cstr_custom(out, batch_axis=1, tp_axis_at=3)

    _, out = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False), jnp.zeros((), jnp.int32), qc,
        unroll=cfg.scan_unroll,
    )
    out = cstr_custom(out, batch_axis=1, tp_axis_at=3)
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h * d)


def attention(
    cfg: LMConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    return_kv: bool = False,
):
    """Full-sequence (train / prefill) attention sublayer with residual.

    ``return_kv=True`` additionally returns the rotated K and V panels —
    prefill writes them straight into the decode cache.
    """
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(cfg, p, h, positions)
    o = _sdpa(cfg, q, k, v, causal=causal, window=window)
    y = x + o @ p["wo"]
    if return_kv:
        return y, k, v
    return y


def attention_decode(
    cfg: LMConfig,
    p: dict,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    cache_pos: jax.Array,
    index: jax.Array,
    *,
    window: int = 0,
):
    """One-token decode against a ring-buffer KV cache.

    cache_[kv]: [b, W, kvh, hd] where W = min(max_len, window or max_len);
    cache_pos: [W] absolute positions stored per slot (-1 = empty).
    With full attention W = max_len and the ring degenerates to the usual
    append cache; with a sliding window (zamba2 long-context) it is a true
    ring — this is how ``long_500k`` decodes with a 4096-slot cache.
    Returns (y, cache_k', cache_v', cache_pos').
    """
    b = x.shape[0]
    W = cache_k.shape[1]
    pos = jnp.full((b, 1), index, jnp.int32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(cfg, p, h, pos)
    slot = jnp.asarray(index % W, jnp.int32)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))
    cache_pos = jax.lax.dynamic_update_slice(cache_pos, pos[:1, 0], (slot,))
    seen = (cache_pos >= 0) & (cache_pos <= index)
    if window:
        seen &= cache_pos > index - window
    d = cfg.hd
    group = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, 1, cfg.n_kv_heads, group, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, cache_k.astype(q.dtype)).astype(jnp.float32)
    scores = scores / math.sqrt(d)
    scores = jnp.where(seen[None, None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", probs, cache_v.astype(q.dtype)).reshape(b, 1, cfg.q_dim)
    return x + o @ p["wo"], cache_k, cache_v, cache_pos


def cross_attention(cfg: LMConfig, p: dict, x: jax.Array, enc_out: jax.Array) -> jax.Array:
    """Encoder-decoder cross attention (whisper). No RoPE on cross-KV."""
    b, s, _ = x.shape
    se = enc_out.shape[1]
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
    k = (enc_out @ p["wk"]).reshape(b, se, cfg.n_kv_heads, cfg.hd)
    v = (enc_out @ p["wv"]).reshape(b, se, cfg.n_kv_heads, cfg.hd)
    o = _sdpa(cfg, q, k, v, causal=False)
    return x + o @ p["wo"]


# ---------------------------------------------------------------------------
# FFN (dense + MoE)
# ---------------------------------------------------------------------------


def dense_ffn(cfg: LMConfig, p: dict, x: jax.Array) -> jax.Array:
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.ffn_kind == "relu2":
        u = jax.nn.relu(h @ p["w_in"])
        return x + (u * u) @ p["w_out"]  # squared-ReLU (nemotron)
    g = jax.nn.silu(h @ p["w_gate"])
    u = h @ p["w_up"]
    return x + (g * u) @ p["w_down"]


def moe_capacity(cfg: LMConfig, tokens_local: int) -> int:
    cap = math.ceil(tokens_local * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-cap // 8) * 8)  # round up to a multiple of 8


def moe_ffn_local(cfg: LMConfig, p: dict, x: jax.Array, capacity: int) -> tuple[jax.Array, jax.Array]:
    """Token-choice top-k MoE with per-shard capacity, sort-free dispatch.

    x: [b_local, s, d] — tokens of ONE data shard.  Expert weights carry the
    full expert axis; their d_ff axis may be TP-sharded by the caller (the
    psum then happens outside).  Returns (y_partial, aux_loss).
    """
    b, s, dm = x.shape
    E, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, dm)
    logits = xf.astype(jnp.float32) @ p["router"]  # [t, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, k)  # [t, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(0)
    ce = jnp.zeros((E,)).at[expert.reshape(-1)].add(1.0) / (t * k)
    aux = E * jnp.sum(me * ce)

    flat_e = expert.reshape(-1)  # [t*k], grouped by token
    flat_tok = jnp.repeat(jnp.arange(t), k)
    flat_gate = gate.reshape(-1)
    # position of each (token, expert) pair within its expert's queue
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)[jnp.arange(t * k), flat_e]
    keep = pos < capacity
    slot = jnp.where(keep, flat_e * capacity + pos, E * capacity)  # overflow -> scratch row
    scale = keep.astype(x.dtype)[:, None]
    buf = (
        jnp.zeros((E * capacity + 1, dm), x.dtype)
        .at[slot]
        .add(xf[flat_tok] * scale, mode="drop")
    )
    xe = buf[:-1].reshape(E, capacity, dm)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["we_gate"]))
    u = jnp.einsum("ecd,edf->ecf", xe, p["we_up"])
    ye = jnp.einsum("ecf,efd->ecd", g * u, p["we_down"]).reshape(E * capacity, dm)
    contrib = ye[jnp.where(keep, slot, 0)] * (flat_gate.astype(x.dtype)[:, None] * scale)
    y = jnp.zeros((t, dm), x.dtype).at[flat_tok].add(contrib)
    if cfg.n_shared_experts:
        h = xf
        gs = jax.nn.silu(h @ p["ws_gate"])
        us = h @ p["ws_up"]
        y = y + (gs * us) @ p["ws_down"]
    return y.reshape(b, s, dm), aux


def moe_ffn(cfg: LMConfig, p: dict, x: jax.Array, mesh=None, dp_axes=("data",), tp_axis="model"):
    """MoE sublayer with residual.  With a mesh: shard_map local dispatch +
    TP psum; without: plain local computation (single-device smoke tests)."""
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if mesh is None:
        y, aux = moe_ffn_local(cfg, p, h, moe_capacity(cfg, h.shape[0] * h.shape[1]))
        return x + y, aux

    from jax.sharding import PartitionSpec as P

    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    tokens_local = (x.shape[0] // dp) * x.shape[1]
    capacity = moe_capacity(cfg, tokens_local)

    w_specs = {
        "router": P(None, None),
        "we_gate": P(None, None, tp_axis),
        "we_up": P(None, None, tp_axis),
        "we_down": P(None, tp_axis, None),
        "ln2": P(None),
    }
    if cfg.n_shared_experts:
        w_specs.update(ws_gate=P(None, tp_axis), ws_up=P(None, tp_axis), ws_down=P(tp_axis, None))
    used = {k: p[k] for k in w_specs}

    def local_fn(h_loc, w):
        y, aux = moe_ffn_local(cfg, w, h_loc, capacity)
        y = jax.lax.psum(y, tp_axis)
        aux = jax.lax.pmean(aux, dp_axes)
        return y, aux

    y, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(dp_axes, None, None), w_specs),
        out_specs=(P(dp_axes, None, None), P()),
        check_vma=False,
    )(h, used)
    return x + y, aux


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality, arXiv:2405.21060 minimal formulation)
# ---------------------------------------------------------------------------


def _segsum(a: jax.Array) -> jax.Array:
    """a: [..., cl] log-decays -> [..., cl, cl] lower-tri cumulative sums."""
    cl = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((cl, cl), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, return_state: bool = False, unroll: bool = False):
    """Chunked SSD scan.

    x: [b, l, h, p]   dt: [b, l, h]   A: [h] (negative)
    B, C: [b, l, n]   (n_groups = 1: B/C shared across heads)
    Returns y: [b, l, h, p] (+ final state [b, h, p, n] if requested).
    l must be a multiple of ``chunk``.
    """
    b, l, h, pdim = x.shape
    n = B.shape[-1]
    c = l // chunk
    a = (dt * A).astype(jnp.float32)  # [b, l, h] log decay
    xdt = x * dt[..., None].astype(x.dtype)

    a_c = a.reshape(b, c, chunk, h).transpose(0, 1, 3, 2)  # [b,c,h,cl]
    x_c = xdt.reshape(b, c, chunk, h, pdim)
    B_c = B.reshape(b, c, chunk, n)
    C_c = C.reshape(b, c, chunk, n)

    # intra-chunk (quadratic within chunk)
    Lmat = jnp.exp(_segsum(a_c)).astype(x.dtype)  # [b,c,h,cl,cl]
    G = jnp.einsum("bcln,bcsn->bcls", C_c, B_c)  # [b,c,cl,cl]
    y_diag = jnp.einsum("bcls,bchls,bcshp->bclhp", G, Lmat, x_c)

    # chunk states
    a_cum = jnp.cumsum(a_c, axis=-1)  # [b,c,h,cl]
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum).astype(x.dtype)
    S_c = jnp.einsum("bcln,bchl,bclhp->bchpn", B_c, decay_states, x_c)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[..., -1])  # [b,c,h] fp32
    def step(h_prev, inp):
        S, dec = inp
        return h_prev * dec[..., None, None].astype(h_prev.dtype) + S, h_prev

    S_swap = jnp.moveaxis(S_c, 1, 0)  # [c,b,h,p,n]
    dec_swap = jnp.moveaxis(chunk_decay, 1, 0)  # [c,b,h]
    final_state, H_in = jax.lax.scan(step, jnp.zeros_like(S_swap[0]), (S_swap, dec_swap), unroll=unroll)
    H_in = jnp.moveaxis(H_in, 0, 1)  # [b,c,h,p,n] state entering each chunk

    in_decay = jnp.exp(a_cum).astype(x.dtype)  # [b,c,h,cl]
    y_off = jnp.einsum("bcln,bchl,bchpn->bclhp", C_c, in_decay, H_in)
    y = (y_diag + y_off).reshape(b, l, h, pdim)
    if return_state:
        return y, final_state
    return y


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, x: [b, l, ch], w: [K, ch]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    segs = [xp[:, i : i + x.shape[1], :] * w[i] for i in range(K)]
    return sum(segs)


def ssd_block(cfg: LMConfig, p: dict, x: jax.Array, return_state: bool = False):
    """Mamba2 block (full sequence) with residual.

    ``return_state=True`` also returns (ssm_state [b,h,p,n],
    conv_tail [b,3,di+2n]) for prefill -> decode handoff.
    """
    b, s, d = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hin = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = hin @ p["in_proj"]
    z, xbc_raw, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc_raw, p["conv_w"]))
    xs, B, C = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [b,s,h]
    A = -jnp.exp(p["A_log"])  # [h]
    xh = cstr_heads(xs.reshape(b, s, h, cfg.ssm_head_dim), 2)
    res = ssd_chunked(xh, dt, A, B, C, cfg.ssm_chunk, return_state=return_state)
    y, state = res if return_state else (res, None)
    y = y + xh * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, di) * jax.nn.silu(z)
    y = rms_norm(y, p["gate_ln"], cfg.norm_eps)
    out = x + y @ p["out_proj"]
    if return_state:
        return out, state.astype(x.dtype), xbc_raw[:, -3:, :]
    return out


def ssd_decode(cfg: LMConfig, p: dict, x: jax.Array, ssm_state: jax.Array, conv_state: jax.Array):
    """One-token SSD decode.

    x: [b, 1, d]; ssm_state: [b, h, p, n]; conv_state: [b, K-1, di+2n].
    Returns (y, ssm_state', conv_state').
    """
    b = x.shape[0]
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hin = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = hin @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    # conv over [conv_state ; xbc]
    full = jnp.concatenate([conv_state, xbc], axis=1)  # [b, K, ch]
    w = p["conv_w"]  # [K, ch]
    xbc_t = jax.nn.silu(jnp.einsum("bkc,kc->bc", full, w))[:, None, :]
    conv_state = full[:, 1:, :]
    xs, B, C = jnp.split(xbc_t, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # [b,h]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)  # [b,h]
    xh = xs.reshape(b, h, cfg.ssm_head_dim)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt.astype(x.dtype), B[:, 0], xh)
    ssm_state = ssm_state * dA[..., None, None].astype(x.dtype) + dBx
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, C[:, 0])
    y = y + xh * p["D"][None, :, None].astype(x.dtype)
    y = y.reshape(b, 1, di) * jax.nn.silu(z)
    y = rms_norm(y, p["gate_ln"], cfg.norm_eps)
    return x + y @ p["out_proj"], ssm_state, conv_state
