"""Power/thermal co-simulation: DVFS ladders, package caps, RC thermals.

Zero-dependency (stdlib-only, no internal imports) so every layer can
consume an attached :class:`PowerModel` duck-typed via ``Platform.power``
without an import edge.  See :mod:`repro.power.model` for the attachment
contract (off by default, degenerate model is bit-for-bit identity).
"""

from .model import (
    DVFSLevel,
    EPPowerSpec,
    PowerModel,
    degenerate_power,
    dvfs_ladder,
    uniform_power,
)
from .thermal import ThermalModel, uniform_thermal

__all__ = [
    "DVFSLevel",
    "EPPowerSpec",
    "PowerModel",
    "ThermalModel",
    "degenerate_power",
    "dvfs_ladder",
    "uniform_power",
    "uniform_thermal",
]
