"""Package power model: per-EP DVFS ladders under a package power cap.

The lumos MPSoC models (SNIPPETS.md §1–2) build heterogeneous systems from
explicit per-core power budgets; CHIPSIM couples power and thermal to
chiplet DL performance.  This module gives ``Platform`` that axis with zero
dependencies:

  * :class:`DVFSLevel` — one frequency/voltage operating point: a ``scale``
    factor applied to the EP's compute rate *and* memory bandwidth (the
    evaluators divide nominal stage times by it), plus the dynamic watts
    drawn while serving and the static leakage watts drawn always.
  * :class:`EPPowerSpec` — one EP's DVFS ladder, fastest level first.
  * :class:`PowerModel` — the package: one spec per EP, the *current* level
    per EP as mutable state (like :class:`~repro.interconnect.Fabric`, it is
    attached to a frozen ``Platform`` via a compare-excluded field), and a
    package-level power cap.  Peak package power is pure model-side
    arithmetic — ``Σ static + Σ dynamic(in-use)`` — so cap feasibility is
    checked *before* paying an online trial, exactly like the elastic
    partitioner's pricing.

Attachment follows the fabric playbook: off by default (``Platform.power``
is ``None`` and every consumer guards with one ``is not None`` check), and
a :func:`degenerate_power` model — a single nominal level of ``scale=1.0``
under an infinite cap — reproduces the power-free results bit-for-bit
(dividing a float by exactly ``1.0`` is an identity in IEEE 754).

Determinism: the model owns no randomness and never reads the wall clock;
the only state is the per-EP level vector, mutated explicitly by the tuner
(:func:`repro.core.tuner.tune` with ``dvfs=True``) and the serving layer's
throttle response.
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Iterable, Sequence

if TYPE_CHECKING:
    from .thermal import ThermalModel


@dataclasses.dataclass(frozen=True)
class DVFSLevel:
    """One operating point of an EP's frequency/voltage ladder."""

    name: str
    #: relative clock: compute rate and memory bandwidth multiply by this
    #: (1.0 = nominal); stage times divide by it
    scale: float
    #: power drawn while the EP is serving a batch, watts
    dynamic_w: float
    #: leakage drawn always (busy or idle), watts
    static_w: float

    def __post_init__(self):
        if not 0.0 < self.scale <= 1.0:
            raise ValueError(f"level scale must be in (0, 1], got {self.scale}")
        if self.dynamic_w < 0 or self.static_w < 0:
            raise ValueError("level watts must be non-negative")


@dataclasses.dataclass(frozen=True)
class EPPowerSpec:
    """One EP's DVFS ladder, fastest (largest ``scale``) level first."""

    levels: tuple[DVFSLevel, ...]
    #: index of the launch-time level
    nominal: int = 0

    def __post_init__(self):
        if not self.levels:
            raise ValueError("EP power spec needs at least one DVFS level")
        scales = [l.scale for l in self.levels]
        if scales != sorted(scales, reverse=True):
            raise ValueError(f"DVFS levels must be fastest-first, got scales {scales}")
        if not 0 <= self.nominal < len(self.levels):
            raise ValueError(f"nominal level {self.nominal} out of range")


@dataclasses.dataclass(eq=False)
class PowerModel:
    """The package: per-EP DVFS state under a shared power cap.

    Mutable by design (current levels are tuned state), so it is attached
    to the frozen ``Platform`` via a compare-excluded field and excluded
    from equality itself, mirroring ``Fabric``.
    """

    specs: tuple[EPPowerSpec, ...]
    #: package-level power cap, watts (``inf`` = unconstrained)
    cap_w: float = math.inf
    #: optional thermal RC model per chiplet (:mod:`repro.power.thermal`)
    thermal: "ThermalModel | None" = None

    def __post_init__(self):
        if not self.specs:
            raise ValueError("power model needs at least one EP spec")
        if self.thermal is not None and self.thermal.n_eps != len(self.specs):
            raise ValueError(
                f"thermal model covers {self.thermal.n_eps} chiplets but the "
                f"power model has {len(self.specs)} EPs"
            )
        #: current DVFS level index per EP (mutable tuned state)
        self._levels: list[int] = [spec.nominal for spec in self.specs]

    # -- current state -------------------------------------------------------

    @property
    def n_eps(self) -> int:
        return len(self.specs)

    @property
    def tunable(self) -> bool:
        """True when at least one EP has more than one level to explore."""
        return any(len(spec.levels) > 1 for spec in self.specs)

    def level(self, ep: int) -> int:
        return self._levels[ep]

    def set_level(self, ep: int, idx: int) -> None:
        if not 0 <= idx < len(self.specs[ep].levels):
            raise ValueError(
                f"EP {ep} has {len(self.specs[ep].levels)} DVFS levels; "
                f"level {idx} does not exist"
            )
        self._levels[ep] = idx

    def can_step_up(self, ep: int) -> bool:
        """A faster level exists (levels are fastest-first)."""
        return self._levels[ep] > 0

    def can_step_down(self, ep: int) -> bool:
        return self._levels[ep] < len(self.specs[ep].levels) - 1

    def snapshot(self) -> tuple[int, ...]:
        """The current per-EP level vector (restorable)."""
        return tuple(self._levels)

    def restore(self, levels: Sequence[int]) -> None:
        if len(levels) != len(self.specs):
            raise ValueError(
                f"level vector covers {len(levels)} EPs, model has {len(self.specs)}"
            )
        for ep, idx in enumerate(levels):
            self.set_level(ep, idx)

    # -- per-EP physics at the current level ---------------------------------

    def current(self, ep: int) -> DVFSLevel:
        return self.specs[ep].levels[self._levels[ep]]

    def scale(self, ep: int) -> float:
        return self.current(ep).scale

    def dynamic_w(self, ep: int) -> float:
        return self.current(ep).dynamic_w

    def static_w(self, ep: int) -> float:
        return self.current(ep).static_w

    # -- package arithmetic (model-side: costs no simulated time) ------------

    @property
    def static_package_w(self) -> float:
        """Leakage of the whole package at the current levels, watts."""
        return sum(self.static_w(ep) for ep in range(len(self.specs)))

    def package_w(self, in_use: Iterable[int]) -> float:
        """Peak package draw: all leakage + dynamic watts of ``in_use`` EPs."""
        return self.static_package_w + sum(
            self.dynamic_w(ep) for ep in sorted(set(in_use))
        )

    def cap_feasible(self, in_use: Iterable[int]) -> bool:
        return self.package_w(in_use) <= self.cap_w

    # -- restriction (sub-platforms / elastic rescale) ------------------------

    def restrict(self, keep: Sequence[int]) -> "PowerModel":
        """Sub-model over the kept EPs, carrying their current levels.

        The package cap is inherited as-is — a deliberate simplification:
        each tenant's view enforces the whole-package budget rather than a
        per-partition share, so a restricted model can never admit a level
        vector the full package would reject.
        """
        sub = PowerModel(
            specs=tuple(self.specs[i] for i in keep),
            cap_w=self.cap_w,
            thermal=self.thermal.restrict(keep) if self.thermal is not None else None,
        )
        sub.restore(tuple(self._levels[i] for i in keep))
        return sub


# ---------------------------------------------------------------------------
# factories
# ---------------------------------------------------------------------------

#: nominal dynamic watts per GFLOP/s of EP compute (sets the power scale of
#: the gem5-style platforms: a 4-core big EP lands around 16 W)
WATTS_PER_GFLOPS = 0.25

#: leakage as a fraction of nominal dynamic draw
STATIC_FRACTION = 0.15


def dvfs_ladder(
    nominal_dynamic_w: float,
    nominal_static_w: float,
    *,
    n_levels: int = 4,
    min_scale: float = 0.4,
) -> tuple[DVFSLevel, ...]:
    """Evenly spaced scale ladder with the classic cubic dynamic-power law.

    Dynamic power follows ``P ∝ f·V²`` with voltage tracking frequency, so
    a level at ``scale`` draws ``nominal · scale³``; leakage falls only
    mildly with the voltage (``0.5 + 0.5·scale``).
    """
    if n_levels < 1:
        raise ValueError("need at least one DVFS level")
    if not 0.0 < min_scale <= 1.0:
        raise ValueError(f"min_scale must be in (0, 1], got {min_scale}")
    levels = []
    for i in range(n_levels):
        scale = (
            1.0
            if n_levels == 1
            else 1.0 - (1.0 - min_scale) * i / (n_levels - 1)
        )
        levels.append(
            DVFSLevel(
                name=f"L{i}",
                scale=scale,
                dynamic_w=nominal_dynamic_w * scale**3,
                static_w=nominal_static_w * (0.5 + 0.5 * scale),
            )
        )
    return tuple(levels)


def uniform_power(
    platform,
    *,
    cap_w: float = math.inf,
    n_levels: int = 4,
    min_scale: float = 0.4,
    watts_per_gflops: float = WATTS_PER_GFLOPS,
    static_fraction: float = STATIC_FRACTION,
    thermal: "ThermalModel | None" = None,
) -> PowerModel:
    """A plausible package model sized from the platform's EP compute rates.

    Each EP's nominal dynamic draw is proportional to its aggregate FLOP
    rate (faster chiplets burn more), with a ``n_levels``-step DVFS ladder
    down to ``min_scale``.  Attach with ``platform.with_power(...)``.
    """
    specs = []
    for ep in platform.eps:
        dyn = watts_per_gflops * ep.flops / 1e9
        specs.append(
            EPPowerSpec(
                levels=dvfs_ladder(
                    dyn,
                    dyn * static_fraction,
                    n_levels=n_levels,
                    min_scale=min_scale,
                )
            )
        )
    return PowerModel(specs=tuple(specs), cap_w=cap_w, thermal=thermal)


def degenerate_power(platform, **kw) -> PowerModel:
    """The identity model: one nominal level per EP, no cap, no thermal.

    Attaching it reproduces the power-free platform bit-for-bit (the
    evaluators divide by a scale of exactly ``1.0``), which is the
    regression pin keeping every pre-power result standing — the power
    analogue of ``scalar_fabric``.
    """
    return uniform_power(platform, n_levels=1, **kw)
