"""Thermal RC node model per chiplet, integrated on the simulated clock.

Each chiplet is one lumped RC node: junction temperature relaxes toward
``T_ambient + P·R`` with time constant ``τ = R·C``.  The serving simulator
steps the integrator once per monitor window using the *average* electrical
power it accounted over that window — no events are pushed, no wall clock
is read, and the only randomness is a hashed per-chiplet parameter jitter
(:func:`uniform_thermal`), so two runs of the same scenario produce
bit-identical temperature trajectories.

Throttling is hysteretic: a chiplet that crosses ``t_hot_c`` derates its
effective stage times by ``throttle_derate`` (and its electrical draw by
``electrical_derate`` — the forced frequency dip burns superlinearly less)
until it cools below ``t_cool_c``.  Under a steady load just past the hot
threshold this produces the slow *oscillating* derate that
:class:`repro.serve.autotuner.DriftDetector` classifies as ``"throttle"``
drift, distinguishing it from a step ``"slowdown"``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Sequence


def _jitter(key: str, sigma: float) -> float:
    """Deterministic multiplicative jitter in ``[1 - sigma, 1 + sigma]``.

    Same construction as ``repro.core.evaluator._noise``: a sha256 of the
    key mapped to the unit interval, so parameter variation is stable
    across runs and platforms without touching any RNG state.
    """
    h = hashlib.sha256(key.encode()).digest()
    u = int.from_bytes(h[:8], "big") / 2**64
    return 1.0 + sigma * (2.0 * u - 1.0)


@dataclasses.dataclass(eq=False)
class ThermalModel:
    """Per-chiplet lumped RC thermal nodes with hysteretic throttling.

    Mutable simulation state (temperatures, throttle latches) lives on the
    instance, so like :class:`~repro.power.model.PowerModel` it is excluded
    from equality and attached to frozen platforms by reference.
    """

    #: junction-to-ambient thermal resistance per chiplet, K/W
    r_k_per_w: tuple[float, ...]
    #: thermal capacitance per chiplet, J/K
    c_j_per_k: tuple[float, ...]
    t_ambient_c: float = 45.0
    #: throttle engages at or above this junction temperature
    t_hot_c: float = 85.0
    #: throttle releases at or below this (hysteresis band)
    t_cool_c: float = 75.0
    #: stage-time multiplier while throttled (> 1 = slower)
    throttle_derate: float = 1.6

    def __post_init__(self):
        if len(self.r_k_per_w) != len(self.c_j_per_k):
            raise ValueError(
                f"R covers {len(self.r_k_per_w)} chiplets, C covers "
                f"{len(self.c_j_per_k)}"
            )
        if not self.r_k_per_w:
            raise ValueError("thermal model needs at least one chiplet")
        if self.t_cool_c >= self.t_hot_c:
            raise ValueError(
                f"hysteresis band inverted: t_cool {self.t_cool_c} >= "
                f"t_hot {self.t_hot_c}"
            )
        if self.throttle_derate < 1.0:
            raise ValueError("throttle_derate must be >= 1")
        #: current junction temperature per chiplet, °C
        self.temps: list[float] = [self.t_ambient_c] * len(self.r_k_per_w)
        #: throttle latch per chiplet
        self.throttled: list[bool] = [False] * len(self.r_k_per_w)
        #: total throttle engagements since construction
        self.throttle_events: int = 0

    @property
    def n_eps(self) -> int:
        return len(self.r_k_per_w)

    @property
    def electrical_derate(self) -> float:
        """Power reduction factor while throttled.

        The forced clock dip slows compute by ``throttle_derate`` but cuts
        electrical draw quadratically (``f·V²`` with V tracking f would be
        cubic; quadratic is the conservative choice), which is what lets a
        throttled chiplet actually cool and produces the release/re-engage
        oscillation.
        """
        return self.throttle_derate * self.throttle_derate

    def step(self, ep: int, avg_w: float, dt: float) -> float:
        """Advance one chiplet by ``dt`` seconds of ``avg_w`` average draw.

        Exact exponential update of ``dT/dt = (P·R + T_amb − T) / (R·C)``,
        so the trajectory is independent of how the simulator slices the
        window.  Returns the stage-time derate now in force (1.0 or
        ``throttle_derate``).
        """
        r = self.r_k_per_w[ep]
        c = self.c_j_per_k[ep]
        target = avg_w * r + self.t_ambient_c
        alpha = 1.0 - math.exp(-dt / (r * c))
        self.temps[ep] += (target - self.temps[ep]) * alpha
        if self.throttled[ep]:
            if self.temps[ep] <= self.t_cool_c:
                self.throttled[ep] = False
        elif self.temps[ep] >= self.t_hot_c:
            self.throttled[ep] = True
            self.throttle_events += 1
        return self.throttle_derate if self.throttled[ep] else 1.0

    def factor(self, ep: int) -> float:
        return self.throttle_derate if self.throttled[ep] else 1.0

    def restrict(self, keep: Sequence[int]) -> "ThermalModel":
        """Sub-model over the kept chiplets, carrying their current state."""
        sub = ThermalModel(
            r_k_per_w=tuple(self.r_k_per_w[i] for i in keep),
            c_j_per_k=tuple(self.c_j_per_k[i] for i in keep),
            t_ambient_c=self.t_ambient_c,
            t_hot_c=self.t_hot_c,
            t_cool_c=self.t_cool_c,
            throttle_derate=self.throttle_derate,
        )
        sub.temps = [self.temps[i] for i in keep]
        sub.throttled = [self.throttled[i] for i in keep]
        return sub


def uniform_thermal(
    n_eps: int,
    *,
    seed: int = 0,
    r_k_per_w: float = 2.0,
    c_j_per_k: float = 20.0,
    sigma: float = 0.1,
    t_ambient_c: float = 45.0,
    t_hot_c: float = 85.0,
    t_cool_c: float = 75.0,
    throttle_derate: float = 1.6,
) -> ThermalModel:
    """Thermal model with hashed per-chiplet parameter variation.

    Each chiplet's R and C get an independent jitter in ``[1±sigma]`` keyed
    on ``(seed, index)`` — process variation without RNG state.  The
    defaults give ``τ = R·C = 40 s``: slow against a monitor window, fast
    enough to oscillate within a serving horizon.
    """
    if n_eps < 1:
        raise ValueError("need at least one chiplet")
    return ThermalModel(
        r_k_per_w=tuple(
            r_k_per_w * _jitter(f"{seed}|r|{i}", sigma) for i in range(n_eps)
        ),
        c_j_per_k=tuple(
            c_j_per_k * _jitter(f"{seed}|c|{i}", sigma) for i in range(n_eps)
        ),
        t_ambient_c=t_ambient_c,
        t_hot_c=t_hot_c,
        t_cool_c=t_cool_c,
        throttle_derate=throttle_derate,
    )
