"""Execution-Place (EP) and platform model.

The paper (Shisha, §2/§6) targets chiplet platforms built from clusters of
cores attached to memory modules of different bandwidths:

  * FEP — Fast Execution Place: high-perf cores + high-bandwidth memory.
  * SEP — Slow Execution Place: slower cores + low-bandwidth memory.

An EP is the unit Shisha maps a pipeline stage onto.  We model an EP by its
aggregate compute rate, memory bandwidth and the link bandwidth/latency of
its connection to neighbouring EPs.  Two families of platform presets are
provided:

  1. ``gem5-like`` ARM big/LITTLE configs reproducing the paper's Table 1
     and Table 3 (C1..C5) systems, for the faithful reproduction benchmarks.
  2. TPU-pod presets (v5e-like FEPs, slower slices as SEPs) used when Shisha
     drives the JAX pipeline runtime (DESIGN.md §2: chiplet -> mesh slice).

Nothing in the scheduling algorithms depends on which preset is used: they
only ever see ``Platform`` / ``EP`` objects.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Sequence

if TYPE_CHECKING:
    from ..faults import FaultModel
    from ..interconnect import Fabric
    from ..power import PowerModel

# ---------------------------------------------------------------------------
# EP / Platform
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EP:
    """One Execution Place (paper: a chiplet = cores + attached memory)."""

    name: str
    cores: int
    #: per-core sustained compute rate, FLOP/s
    flops_per_core: float
    #: memory bandwidth of the attached module, bytes/s
    mem_bw: float
    #: link bandwidth to neighbouring EPs, bytes/s
    link_bw: float = 25e9
    #: one-way link latency to neighbouring EPs, seconds (Fig. 9 knob)
    link_latency: float = 100e-9
    #: bigger is faster; used by Algorithm 1 to rank EPs (FEP rank 1, ...)
    perf_class: int = 1

    @property
    def flops(self) -> float:
        """Aggregate compute rate of the EP, FLOP/s."""
        return self.cores * self.flops_per_core

    @property
    def is_fep(self) -> bool:
        return self.perf_class == 1


@dataclasses.dataclass(frozen=True)
class Platform:
    """A fixed set of EPs (the machine Shisha schedules onto).

    ``fabric`` (optional) attaches a routed, contention-priced interconnect
    (:class:`~repro.interconnect.Fabric`); without one, every consumer falls
    back to the scalar per-EP ``link_bw``/``link_latency`` model, which a
    fully-connected fabric reproduces bit-for-bit.  The field is excluded
    from comparison/hash so platform equality keeps its pre-fabric meaning.

    ``power`` (optional) attaches per-EP DVFS state tables and a package
    power cap (:class:`~repro.power.PowerModel`), following the same
    playbook: compare-excluded, off by default, and a degenerate model
    (single nominal level, no cap) reproduces the power-free results
    bit-for-bit.
    """

    name: str
    eps: tuple[EP, ...]
    fabric: "Fabric | None" = dataclasses.field(default=None, compare=False)
    power: "PowerModel | None" = dataclasses.field(default=None, compare=False)
    #: optional chaos spec (:class:`~repro.faults.FaultModel`), same
    #: playbook: compare-excluded, off by default, and the degenerate
    #: ``no_faults`` model reproduces fault-free results bit-for-bit
    faults: "FaultModel | None" = dataclasses.field(default=None, compare=False)

    def __post_init__(self):
        if not self.eps:
            raise ValueError("platform needs at least one EP")
        if self.fabric is not None and self.fabric.n_eps != len(self.eps):
            raise ValueError(
                f"fabric binds {self.fabric.n_eps} EPs but platform has {len(self.eps)}"
            )
        if self.power is not None and self.power.n_eps != len(self.eps):
            raise ValueError(
                f"power model covers {self.power.n_eps} EPs but platform has "
                f"{len(self.eps)}"
            )

    @property
    def n_eps(self) -> int:
        return len(self.eps)

    @property
    def feps(self) -> tuple[int, ...]:
        """Indices of fast EPs (best perf_class present on the platform)."""
        best = min(ep.perf_class for ep in self.eps)
        return tuple(i for i, ep in enumerate(self.eps) if ep.perf_class == best)

    @property
    def seps(self) -> tuple[int, ...]:
        best = min(ep.perf_class for ep in self.eps)
        return tuple(i for i, ep in enumerate(self.eps) if ep.perf_class != best)

    def ranked(self) -> list[int]:
        """EP indices sorted in descending order of performance.

        This is the paper's H_e list (§5.1): FEPs first.  Ties broken by
        aggregate FLOP rate, then memory bandwidth, then index (stable).
        """
        return sorted(
            range(self.n_eps),
            key=lambda i: (
                self.eps[i].perf_class,
                -self.eps[i].flops,
                -self.eps[i].mem_bw,
                i,
            ),
        )

    def with_fabric(self, fabric: "Fabric") -> "Platform":
        """Copy of the platform with an interconnect fabric attached.

        A fabric whose ``mc_bw`` is the sentinel ``"auto"`` gets its
        memory-controller hotspot caps resolved here, from the machine the
        fabric is being attached to: each EP's node is capped at that EP's
        ``mem_bw`` (the paper's Table 1 memory-module bandwidth), so fan-in
        onto one chiplet saturates its memory controller by default on the
        gem5-style platforms.  Nodes hosting several EPs take the smallest;
        pure router nodes (no EP) stay uncapped.
        """
        if isinstance(fabric.mc_bw, str) and fabric.n_eps == len(self.eps):
            # "auto" (validated by Fabric); a binding-size mismatch falls
            # through to __post_init__'s clean error below
            caps: dict[int, float] = {}
            for i, ep in enumerate(self.eps):
                node = fabric.ep_nodes[i]
                caps[node] = min(caps.get(node, ep.mem_bw), ep.mem_bw)
            fabric = dataclasses.replace(fabric, mc_bw=caps)
        return dataclasses.replace(self, fabric=fabric)

    def with_power(self, power: "PowerModel") -> "Platform":
        """Copy of the platform with a power/thermal model attached.

        The model is shared by reference (its per-EP DVFS levels are live
        tuned state), so two platform copies made with ``dataclasses.replace``
        see the same frequencies — deliberately, like ``fabric``.
        """
        return dataclasses.replace(self, power=power)

    def with_faults(self, faults: "FaultModel") -> "Platform":
        """Copy of the platform with a chaos fault model attached.

        Nothing breaks at attach time — the spec only becomes live when a
        serving layer expands it through a
        :class:`~repro.faults.FaultInjector` at prime time.  Fault domains
        are validated here, where the EP count is known.
        """
        for d in faults.domains:
            for ep in d:
                if not (0 <= ep < len(self.eps)):
                    raise ValueError(
                        f"failure domain EP {ep} outside platform with {len(self.eps)} EPs"
                    )
        return dataclasses.replace(self, faults=faults)

    def with_latency(self, latency_s: float) -> "Platform":
        """Copy of the platform with every inter-EP link latency replaced.

        Used by the Fig. 9 experiment (inter-chiplet latency sweep).  When a
        fabric is attached, its per-link latencies are replaced too, so the
        knob stays meaningful in both the scalar and the routed path (a
        routed transfer then pays ``hops * latency_s``).
        """
        eps = tuple(dataclasses.replace(ep, link_latency=latency_s) for ep in self.eps)
        fabric = self.fabric.with_link_latency(latency_s) if self.fabric is not None else None
        return dataclasses.replace(
            self, name=f"{self.name}@lat{latency_s:g}", eps=eps, fabric=fabric
        )

    def without(self, dead: Sequence[int]) -> "Platform":
        """Copy of the platform with EPs ``dead`` removed (elastic rescale).

        An attached fabric is restricted to the survivors: the dead chiplet's
        router keeps forwarding (routes are physically unchanged), only the
        EP binding shrinks.  An attached power model is restricted the same
        way (a copy carrying the survivors' current DVFS levels).
        """
        dead_set = set(dead)
        keep = [i for i in range(len(self.eps)) if i not in dead_set]
        eps = tuple(self.eps[i] for i in keep)
        fabric = self.fabric.restrict(keep) if self.fabric is not None else None
        power = self.power.restrict(keep) if self.power is not None else None
        # the chaos spec is NOT carried over: its EP/domain indices are in
        # the original space, and a sub-platform's faults are injected by
        # whoever owns the full platform (the co-serving layer)
        return dataclasses.replace(
            self,
            name=f"{self.name}-minus{sorted(dead_set)}",
            eps=eps,
            fabric=fabric,
            power=power,
            faults=None,
        )


# ---------------------------------------------------------------------------
# gem5-style presets (paper Table 1 + Table 3)
# ---------------------------------------------------------------------------

# ARM big (out-of-order, ~2 GHz, 8 FLOP/cycle fp32 NEON-ish) vs LITTLE
# (in-order, ~1.4 GHz, 4 FLOP/cycle).  Absolute values only set the time
# scale; the algorithms respond to the *ratios*, as in the paper's gem5 DB.
_BIG_FLOPS = 2.0e9 * 8
_LITTLE_FLOPS = 1.4e9 * 4

#: paper Table 1 memory bandwidths
_HBM_BW = 40e9
_DDR_BW = 20e9


def _big(name: str, cores: int, link_latency: float = 100e-9) -> EP:
    return EP(
        name=name,
        cores=cores,
        flops_per_core=_BIG_FLOPS,
        mem_bw=_HBM_BW,
        link_bw=25e9,
        link_latency=link_latency,
        perf_class=1,
    )


def _little(name: str, cores: int, link_latency: float = 100e-9) -> EP:
    return EP(
        name=name,
        cores=cores,
        flops_per_core=_LITTLE_FLOPS,
        mem_bw=_DDR_BW,
        link_bw=25e9,
        link_latency=link_latency,
        perf_class=2,
    )


def table3_platform(conf: str) -> Platform:
    """Paper Table 3 EP configurations C1..C5."""
    specs = {
        # (FEPs as list of core counts, SEPs as list of core counts)
        "C1": ([8], [8]),
        "C2": ([8, 8], [8, 8]),
        "C3": ([4, 4, 4, 4], [8, 8]),
        "C4": ([8, 8], [4, 4, 4, 4]),
        "C5": ([4, 4, 4, 4], [4, 4, 4, 4]),
    }
    if conf not in specs:
        raise KeyError(f"unknown Table-3 config {conf!r}; have {sorted(specs)}")
    fep_cores, sep_cores = specs[conf]
    eps = [_big(f"FEP{i}", c) for i, c in enumerate(fep_cores)]
    eps += [_little(f"SEP{i}", c) for i, c in enumerate(sep_cores)]
    return Platform(name=conf, eps=tuple(eps))


def paper_platform(n_eps: int = 8, fep_fraction: float = 0.5) -> Platform:
    """Generic big/LITTLE platform with ``n_eps`` EPs (Fig. 4 uses 8 EPs)."""
    n_fep = max(1, round(n_eps * fep_fraction))
    eps = [_big(f"FEP{i}", 4) for i in range(n_fep)]
    eps += [_little(f"SEP{i}", 4) for i in range(n_eps - n_fep)]
    return Platform(name=f"bigLITTLE{n_eps}", eps=tuple(eps))


# ---------------------------------------------------------------------------
# TPU presets (hardware adaptation, DESIGN.md §2)
# ---------------------------------------------------------------------------

#: per-chip peak numbers used across the framework (also in benchmarks/roofline.py)
TPU_PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
TPU_HBM_BW = 819e9  # bytes/s per chip
TPU_ICI_BW = 50e9  # bytes/s per link
TPU_DCI_BW = 12.5e9  # bytes/s inter-pod (modelled)


def tpu_slice_ep(name: str, chips: int, *, fast: bool = True, link_latency: float = 1e-6) -> EP:
    """A slice of a TPU pod treated as one EP (chiplet analogue).

    ``fast=False`` models an older/downclocked slice (or one sharing DCI
    bandwidth), giving the FEP/SEP heterogeneity the paper requires.
    """
    derate = 1.0 if fast else 0.45
    return EP(
        name=name,
        cores=chips,
        flops_per_core=TPU_PEAK_FLOPS * derate,
        mem_bw=chips * TPU_HBM_BW * derate,
        link_bw=TPU_ICI_BW if fast else TPU_DCI_BW,
        link_latency=link_latency,
        perf_class=1 if fast else 2,
    )


def tpu_platform(n_fast: int = 4, n_slow: int = 4, chips_per_slice: int = 8) -> Platform:
    eps = [tpu_slice_ep(f"v5e[{i}]", chips_per_slice, fast=True) for i in range(n_fast)]
    eps += [tpu_slice_ep(f"v5e-slow[{i}]", chips_per_slice, fast=False) for i in range(n_slow)]
    return Platform(name=f"tpu{n_fast}f{n_slow}s", eps=tuple(eps))
