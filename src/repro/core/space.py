"""Design-space accounting and enumeration.

A point is (composition of L layers into N contiguous stages) × (injective
assignment of the N stages to the platform's EPs).  Sizes:

    |space| = sum_{N=1..min(L,E)}  C(L-1, N-1) * P(E, N)

where P(E,N) = E!/(E-N)! — each stage owns its EP exclusively.  This is the
denominator behind the paper's "Shisha explores ~0.1% of the design space".
"""

from __future__ import annotations

import itertools
import math
from typing import Iterator

from .config import PipelineConfig


def n_compositions(n_layers: int, depth: int) -> int:
    return math.comb(n_layers - 1, depth - 1)


def n_assignments(n_eps: int, depth: int) -> int:
    return math.perm(n_eps, depth)


def space_size(n_layers: int, n_eps: int, max_depth: int | None = None) -> int:
    top = min(n_layers, n_eps, max_depth or n_eps)
    return sum(n_compositions(n_layers, d) * n_assignments(n_eps, d) for d in range(1, top + 1))


def compositions(n_layers: int, depth: int) -> Iterator[tuple[int, ...]]:
    """All ways to split n_layers into `depth` positive contiguous parts."""
    for cuts in itertools.combinations(range(1, n_layers), depth - 1):
        prev, parts = 0, []
        for c in cuts:
            parts.append(c - prev)
            prev = c
        parts.append(n_layers - prev)
        yield tuple(parts)


def enumerate_configs(
    n_layers: int, n_eps: int, depth: int | None = None, max_depth: int | None = None
) -> Iterator[PipelineConfig]:
    depths = [depth] if depth else range(1, min(n_layers, n_eps, max_depth or n_eps) + 1)
    for d in depths:
        for stages in compositions(n_layers, d):
            for eps in itertools.permutations(range(n_eps), d):
                yield PipelineConfig(stages=stages, eps=eps)
