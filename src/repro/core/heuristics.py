"""Shisha heuristics H1–H6 (paper Table 2): assignment × balancing."""

from __future__ import annotations

import dataclasses
import random as _random
from typing import Sequence

from .evaluator import Trace
from .seed import Assignment, generate_seed
from .tuner import Balancing, TuneResult, tune

HEURISTICS: dict[str, tuple[Assignment, Balancing]] = {
    "H1": ("rank_l", "nlfep"),
    "H2": ("rank_l", "nfep"),
    "H3": ("rank_w", "nlfep"),  # recommended by the paper (§7.5)
    "H4": ("rank_w", "nfep"),
    "H5": ("random", "nlfep"),
    "H6": ("random", "nfep"),
}


@dataclasses.dataclass
class ShishaResult:
    heuristic: str
    result: TuneResult
    trace: Trace


def run_shisha(
    weights: Sequence[float],
    trace: Trace,
    heuristic: str = "H3",
    n_stages: int | None = None,
    alpha: int = 10,
    rng: _random.Random | None = None,
    placement: bool = False,
) -> ShishaResult:
    """Seed (Alg. 1) + tune (Alg. 2) under one of H1..H6.

    ``placement=True`` enables the fabric-aware EP-relocation moves of
    :func:`~repro.core.tuner.tune` (extra trials, charged to ``trace``).
    """
    assignment, balancing = HEURISTICS[heuristic]
    seed = generate_seed(weights, trace.evaluator.platform, n_stages, assignment, rng)
    result = tune(seed, trace, alpha=alpha, balancing=balancing, placement=placement)
    return ShishaResult(heuristic=heuristic, result=result, trace=trace)
