"""Algorithm 2 — Shisha online tuning.

Starting from the seed, repeatedly:
  1. find the slowest pipeline stage (the throughput bottleneck),
  2. pick a *target* stage on a fast EP — nearest (``nFEP``) or nearest
     lightest (``nlFEP``, recommended: H3) —
  3. move one boundary layer from the slowest stage one hop toward the
     target (contiguity: layers travel between adjacent stages),
  4. re-measure; after α consecutive non-improving configurations, stop.

The tuner never enumerates the space — each step visits exactly one new
configuration, which is what makes it *online-viable* (every trial costs
real pipeline time, accounted by ``Trace``).

Deviation noted in DESIGN.md: when the slowest stage is down to one layer,
the directional move would empty it; we collapse the stage instead (depth
shrinks by one, its EP is freed), mirroring what the paper's layer drain
implies.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

from .config import PipelineConfig
from .evaluator import Trace
from .seed import Seed

Balancing = Literal["nfep", "nlfep"]


def _move_toward(conf: PipelineConfig, src: int, direction: int) -> PipelineConfig | None:
    """Move one boundary layer of stage ``src`` one hop in ``direction``.

    Collapses ``src`` (dropping its EP) if it would become empty.  Returns
    None when the move is impossible (src at pipeline edge).
    """
    dst = src + direction
    if dst < 0 or dst >= conf.depth:
        return None
    stages = list(conf.stages)
    eps = list(conf.eps)
    stages[src] -= 1
    stages[dst] += 1
    if stages[src] == 0:
        del stages[src], eps[src]
    return PipelineConfig(stages=tuple(stages), eps=tuple(eps))


def pick_target(
    conf: PipelineConfig,
    stage_times: list[float],
    slowest: int,
    platform,
    balancing: Balancing,
) -> int | None:
    """Choose the target stage (line 6 of Alg. 2).

    Candidates: stages other than the slowest whose EP class is at least as
    fast as the slowest stage's and whose current beat is lower — preferring
    FEPs.  ``nfep``: minimal pipeline distance;  ``nlfep``: lightest load.

    Ties are broken deterministically: ``nfep`` by (distance, beat, stage
    index), ``nlfep`` by (beat, distance, stage index) — so equal-distance
    equal-load candidates always resolve to the lowest stage index,
    independent of candidate enumeration order.
    """
    fep_set = set(platform.feps)
    cands = [
        s
        for s in range(conf.depth)
        if s != slowest and stage_times[s] < stage_times[slowest]
    ]
    if not cands:
        return None
    fast_cands = [s for s in cands if conf.eps[s] in fep_set]
    pool = fast_cands or cands
    if balancing == "nfep":
        return min(pool, key=lambda s: (abs(s - slowest), stage_times[s], s))
    if balancing == "nlfep":
        return min(pool, key=lambda s: (stage_times[s], abs(s - slowest), s))
    raise ValueError(f"unknown balancing {balancing!r}")


@dataclasses.dataclass
class TuneResult:
    best_conf: PipelineConfig
    best_throughput: float
    n_explored: int
    final_conf: PipelineConfig


def tune(
    seed: Seed | PipelineConfig,
    trace: Trace,
    alpha: int = 10,
    balancing: Balancing = "nlfep",
    max_steps: int = 10_000,
) -> TuneResult:
    """Algorithm 2.  ``trace`` wraps the evaluator and accounts cost."""
    conf = seed.conf if isinstance(seed, Seed) else seed
    platform = trace.evaluator.platform
    throughput = trace.execute(conf)
    best_conf, best_tp = conf, throughput
    gamma = 0
    steps = 0
    while gamma < alpha and steps < max_steps:
        steps += 1
        stage_times = trace.evaluator.stage_times(conf)
        slowest = max(range(conf.depth), key=stage_times.__getitem__)
        target = pick_target(conf, stage_times, slowest, platform, balancing)
        if target is None:
            break  # perfectly balanced or single stage: nothing to move
        direction = 1 if target > slowest else -1
        nxt = _move_toward(conf, slowest, direction)
        if nxt is None or nxt == conf:
            break
        conf = nxt
        tp = trace.execute(conf)
        if tp <= throughput:
            gamma += 1
        else:
            gamma = 0
            throughput = tp
        if tp > best_tp:
            best_conf, best_tp = conf, tp
    return TuneResult(best_conf=best_conf, best_throughput=best_tp, n_explored=trace.n_trials, final_conf=conf)
