"""Algorithm 2 — Shisha online tuning.

Starting from the seed, repeatedly:
  1. find the slowest pipeline stage (the throughput bottleneck),
  2. pick a *target* stage on a fast EP — nearest (``nFEP``) or nearest
     lightest (``nlFEP``, recommended: H3) —
  3. move one boundary layer from the slowest stage one hop toward the
     target (contiguity: layers travel between adjacent stages),
  4. re-measure; after α consecutive non-improving configurations, stop.

The tuner never enumerates the space — each step visits exactly one new
configuration, which is what makes it *online-viable* (every trial costs
real pipeline time, accounted by ``Trace``).

Deviation noted in DESIGN.md: when the slowest stage is down to one layer,
the directional move would empty it; we collapse the stage instead (depth
shrinks by one, its EP is freed), mirroring what the paper's layer drain
implies.

With ``placement=True`` each step additionally proposes *which EP hosts the
slowest stage*: the stage is trial-relocated onto the best free EP (fastest
class first, then lowest fabric-routed latency to its pipeline neighbours,
then FLOPs, then index).  On a platform with an interconnect fabric this is
what lets the tuner route around congested links — placement on the chiplet
fabric becomes a first-class decision, not just stage sizing.  The extra
candidate is charged to the trace like any online trial — at its *routed*
price: relocating a stage ships its resident weights over the fabric, so
the trial pays ``reconfig_overhead`` plus a store-and-forward ship of the
stage's weight bytes across every routed hop beyond the first
(:func:`placement_reconfig_cost`; a distant EP is expensive to even *try*,
exactly the online-cost asymmetry Shisha exploits).  With
``placement=False`` the loop is exactly the paper's Algorithm 2, trial for
trial.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

from .config import PipelineConfig
from .evaluator import Trace
from .seed import Seed

Balancing = Literal["nfep", "nlfep"]


def _move_toward(conf: PipelineConfig, src: int, direction: int) -> PipelineConfig | None:
    """Move one boundary layer of stage ``src`` one hop in ``direction``.

    Collapses ``src`` (dropping its EP) if it would become empty.  Returns
    None when the move is impossible (src at pipeline edge).
    """
    dst = src + direction
    if dst < 0 or dst >= conf.depth:
        return None
    stages = list(conf.stages)
    eps = list(conf.eps)
    stages[src] -= 1
    stages[dst] += 1
    if stages[src] == 0:
        del stages[src], eps[src]
    return PipelineConfig(stages=tuple(stages), eps=tuple(eps))


def pick_target(
    conf: PipelineConfig,
    stage_times: list[float],
    slowest: int,
    platform,
    balancing: Balancing,
) -> int | None:
    """Choose the target stage (line 6 of Alg. 2).

    Candidates: stages other than the slowest whose EP class is at least as
    fast as the slowest stage's and whose current beat is lower — preferring
    FEPs.  ``nfep``: minimal pipeline distance;  ``nlfep``: lightest load.

    Ties are broken deterministically: ``nfep`` by (distance, beat, stage
    index), ``nlfep`` by (beat, distance, stage index) — so equal-distance
    equal-load candidates always resolve to the lowest stage index,
    independent of candidate enumeration order.
    """
    fep_set = set(platform.feps)
    cands = [
        s
        for s in range(conf.depth)
        if s != slowest and stage_times[s] < stage_times[slowest]
    ]
    if not cands:
        return None
    fast_cands = [s for s in cands if conf.eps[s] in fep_set]
    pool = fast_cands or cands
    if balancing == "nfep":
        return min(pool, key=lambda s: (abs(s - slowest), stage_times[s], s))
    if balancing == "nlfep":
        return min(pool, key=lambda s: (stage_times[s], abs(s - slowest), s))
    raise ValueError(f"unknown balancing {balancing!r}")


def _relocate(conf: PipelineConfig, stage: int, new_ep: int) -> PipelineConfig:
    eps = list(conf.eps)
    eps[stage] = new_ep
    return PipelineConfig(stages=conf.stages, eps=tuple(eps))


def placement_reconfig_cost(
    trace: Trace, conf: PipelineConfig, stage: int, new_ep: int
) -> float:
    """Wall-clock price of trial-relocating ``stage`` onto ``new_ep``.

    A boundary move ships one layer's weights to an adjacent EP — the flat
    ``reconfig_overhead`` has always modelled that single-link transfer.  A
    *relocation* ships the whole stage's resident weights across the fabric,
    so it pays the flat overhead **plus** a store-and-forward ship of the
    stage's ``weight_bytes`` over every routed hop beyond the first:

        ``overhead + sum_{hops 2..H} (stage_weight_bytes / bw_hop + lat_hop)``

    Weights ship once, as a bulk transfer outside the steady-state flow set,
    so the *static* route prices it (deterministic, congestion-free).  On a
    fully-connected fabric every route is one hop and the extra term
    vanishes — relocation trials cost exactly the old flat overhead, which
    is the regression pin keeping all pre-fabric placement results
    bit-for-bit.  Without a fabric there is nothing to route: flat cost.
    """
    fabric = trace.evaluator.platform.fabric
    flat = trace.reconfig_overhead
    if fabric is None:
        return flat
    if not math.isfinite(fabric.latency_ep(conf.eps[stage], new_ep)):
        # link faults severed the shipping route: the relocation cannot be
        # performed at all (the caller must skip the candidate)
        return math.inf
    route = fabric.route_ep(conf.eps[stage], new_ep)
    if len(route) <= 1:
        return flat
    a, b = conf.boundaries()[stage]
    wbytes = sum(trace.evaluator.layers[i].weight_bytes for i in range(a, b))
    links = fabric.effective_topology().links
    extra = sum(wbytes / links[k].bw + links[k].latency for k in route[1:])
    return flat + extra


def placement_candidate(
    conf: PipelineConfig,
    slowest: int,
    platform,
    exclude: frozenset = frozenset(),
) -> int | None:
    """Best free EP to rehost the slowest stage on, or None.

    Deterministic preference: fastest perf class, then smallest
    fabric-routed latency to the stage's pipeline neighbours (0 without a
    fabric), then highest aggregate FLOPs, then lowest index.  Only unused
    EPs are proposed (the EP assignment is injective), so when the pipeline
    occupies every EP there is nothing to propose.  ``exclude`` removes EPs
    that must never host a stage (e.g. dead EPs in a drifted model, whose
    near-zero sentinel specs would make the relocation trial absurdly
    expensive).
    """
    used = set(conf.eps) | set(exclude)
    free = [e for e in range(platform.n_eps) if e not in used]
    if not free:
        return None
    fabric = platform.fabric

    def neighbour_latency(e: int) -> float:
        if fabric is None:
            return 0.0
        tot = 0.0
        if slowest > 0:
            tot += fabric.latency_ep(conf.eps[slowest - 1], e)
        if slowest < conf.depth - 1:
            tot += fabric.latency_ep(e, conf.eps[slowest + 1])
        return tot

    return min(
        free,
        key=lambda e: (
            platform.eps[e].perf_class,
            neighbour_latency(e),
            -platform.eps[e].flops,
            e,
        ),
    )


@dataclasses.dataclass
class TuneResult:
    best_conf: PipelineConfig
    best_throughput: float
    n_explored: int
    final_conf: PipelineConfig
    #: per-EP DVFS level vector adopted with ``best_conf`` when the tuner
    #: ran with ``dvfs=True`` on a powered platform; None otherwise
    dvfs_levels: tuple[int, ...] | None = None


def _dvfs_candidate(pm, conf: PipelineConfig, slowest: int):
    """One DVFS knob to try this step: ``(ep, new_level, kind)`` or None.

    Preference order mirrors the boundary heuristic's bottleneck focus:
    step the slowest stage's EP *up* a level when the package cap still
    admits it; otherwise free headroom by stepping *down* the hungriest
    other in-use EP.  Deterministic — ties on watts resolve to the lowest
    EP index.
    """
    slow_ep = conf.eps[slowest]
    if pm.can_step_up(slow_ep):
        prev = pm.level(slow_ep)
        pm.set_level(slow_ep, prev - 1)
        feasible = pm.cap_feasible(conf.eps)
        pm.set_level(slow_ep, prev)
        if feasible:
            return (slow_ep, prev - 1, "dvfs_up")
    others = [e for e in sorted(set(conf.eps)) if e != slow_ep and pm.can_step_down(e)]
    if others:
        victim = max(others, key=lambda e: (pm.dynamic_w(e), -e))
        return (victim, pm.level(victim) + 1, "dvfs_down")
    return None


def tune(
    seed: Seed | PipelineConfig,
    trace: Trace,
    alpha: int = 10,
    balancing: Balancing = "nlfep",
    max_steps: int = 10_000,
    placement: bool = False,
    placement_exclude: frozenset = frozenset(),
    dvfs: bool = False,
) -> TuneResult:
    """Algorithm 2.  ``trace`` wraps the evaluator and accounts cost.

    ``placement=True`` adds one extra trial per step — relocating the
    slowest stage onto the best free EP (never one in
    ``placement_exclude``) — and adopts whichever measured candidate
    (boundary move or relocation) is fastest.  Off by default: the paper's
    loop is reproduced move for move.

    ``dvfs=True`` (requires a :class:`~repro.power.PowerModel` attached to
    the platform) makes per-EP frequency levels tuned state alongside the
    boundary/placement moves: before the loop, in-use EPs are stepped down
    until the package power cap is satisfied (each enforced level is a paid
    trial — the runtime must re-measure at the new clocks); each step then
    adds one DVFS candidate (up-shift the bottleneck EP if the cap admits
    it, else down-shift the hungriest non-bottleneck EP), applied only for
    its own trial and re-applied if adopted.  Candidates whose EP set would
    break the cap are rejected before being paid.  The best level vector is
    left applied on the power model and returned in ``dvfs_levels``.
    """
    conf = seed.conf if isinstance(seed, Seed) else seed
    platform = trace.evaluator.platform
    #: live telemetry session of the trace, or None (duck-typed; the move
    #: kind and beat delta of every adopted candidate are the tuner-side
    #: facts Trace.execute cannot see)
    tl = getattr(trace, "telemetry", None)
    if tl is not None and not tl.enabled:
        tl = None
    pm = platform.power if dvfs else None
    if pm is not None and not pm.tunable and pm.cap_feasible(conf.eps):
        pm = None  # single-level ladders under a satisfied cap: nothing to tune
    if pm is not None:
        # cap enforcement: walk the hungriest in-use EPs down until the
        # package fits (or every ladder bottoms out); each enforced level
        # is a paid measurement at the new clocks
        while not pm.cap_feasible(conf.eps):
            cands = [e for e in sorted(set(conf.eps)) if pm.can_step_down(e)]
            if not cands:
                break
            victim = max(cands, key=lambda e: (pm.dynamic_w(e), -e))
            pm.set_level(victim, pm.level(victim) + 1)
            trace.execute(conf)
            if tl is not None:
                tl.counter("tune.moves.dvfs_cap").inc()
    throughput = trace.execute(conf)
    best_conf, best_tp = conf, throughput
    best_levels = pm.snapshot() if pm is not None else None
    gamma = 0
    steps = 0
    while gamma < alpha and steps < max_steps:
        steps += 1
        stage_times = trace.evaluator.stage_times(conf)
        slowest = max(range(conf.depth), key=stage_times.__getitem__)
        #: (candidate, per-trial reconfig cost — None = flat overhead,
        #:  DVFS change (ep, new_level) or None, move kind)
        candidates: list[
            tuple[PipelineConfig, float | None, tuple[int, int] | None, str]
        ] = []
        target = pick_target(conf, stage_times, slowest, platform, balancing)
        if target is not None:
            direction = 1 if target > slowest else -1
            nxt = _move_toward(conf, slowest, direction)
            if nxt is not None and nxt != conf:
                candidates.append((nxt, None, None, "boundary"))
        if placement:
            new_ep = placement_candidate(conf, slowest, platform, placement_exclude)
            if new_ep is not None:
                # relocation ships the stage's weights across the fabric:
                # the trial is charged its routed weight-shipping cost, not
                # the flat boundary-move overhead.  An infinite cost means
                # link faults severed the shipping route — unperformable
                rc = placement_reconfig_cost(trace, conf, slowest, new_ep)
                if math.isfinite(rc):
                    candidates.append(
                        (_relocate(conf, slowest, new_ep), rc, None, "relocation")
                    )
        if pm is not None:
            # reject cap-infeasible boundary/placement candidates before
            # they are paid (a move onto a hungrier EP set may break the
            # cap at the current levels)
            candidates = [
                c for c in candidates if pm.cap_feasible(c[0].eps)
            ]
            dv = _dvfs_candidate(pm, conf, slowest)
            if dv is not None:
                candidates.append((conf, None, (dv[0], dv[1]), dv[2]))
        if not candidates:
            break  # perfectly balanced, single stage, or nowhere to move
        # every candidate is a paid online trial; ties resolve to the first
        # (boundary move before relocation before DVFS), keeping the
        # no-placement, no-DVFS path identical to the paper's loop
        measured = []
        for c, rc, change, _kind in candidates:
            if change is not None:
                prev_level = pm.level(change[0])
                pm.set_level(change[0], change[1])
            measured.append((trace.execute(c, reconfig_cost=rc), c))
            if change is not None:
                pm.set_level(change[0], prev_level)
        chosen = max(range(len(measured)), key=lambda i: (measured[i][0], -i))
        tp, conf = measured[chosen]
        change = candidates[chosen][2]
        if change is not None:
            pm.set_level(change[0], change[1])
        if tl is not None:
            tl.counter(f"tune.moves.{candidates[chosen][3]}").inc()
            if tp > 0.0:  # a severed pipeline has no beat to compare
                tl.histogram("tune.beat_delta_s").observe(
                    1.0 / tp - stage_times[slowest]
                )
        if tp <= throughput:
            gamma += 1
        else:
            gamma = 0
            throughput = tp
        if tp > best_tp:
            best_conf, best_tp = conf, tp
            if pm is not None:
                best_levels = pm.snapshot()
    if pm is not None:
        pm.restore(best_levels)
    return TuneResult(
        best_conf=best_conf,
        best_throughput=best_tp,
        n_explored=trace.n_trials,
        final_conf=conf,
        dvfs_levels=best_levels,
    )
