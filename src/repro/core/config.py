"""Pipeline configuration: the point in Shisha's design space.

A configuration is (paper §5):
  1. ``stages`` — how many consecutive layers each pipeline stage owns
     (a composition of L into N positive parts; contiguity respects the
     chain DAG of the CNN / transformer).
  2. ``eps``    — which EP each stage is mapped to (injective: each stage
     owns its EP exclusively, as in the paper's chiplet setting).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    stages: tuple[int, ...]  # layers per stage, sum == L
    eps: tuple[int, ...]  # EP index per stage, len == len(stages)

    def __post_init__(self):
        if len(self.stages) != len(self.eps):
            raise ValueError(f"{len(self.stages)} stages but {len(self.eps)} EP slots")
        if any(s <= 0 for s in self.stages):
            raise ValueError(f"empty stage in {self.stages}")
        if len(set(self.eps)) != len(self.eps):
            raise ValueError(f"EP assigned to two stages: {self.eps}")

    @property
    def depth(self) -> int:
        return len(self.stages)

    @property
    def n_layers(self) -> int:
        return sum(self.stages)

    def boundaries(self) -> list[tuple[int, int]]:
        """[start, end) layer range per stage."""
        out, start = [], 0
        for s in self.stages:
            out.append((start, start + s))
            start += s
        return out

    def stage_of_layer(self, layer: int) -> int:
        for i, (a, b) in enumerate(self.boundaries()):
            if a <= layer < b:
                return i
        raise IndexError(layer)

    def move_layer(self, src: int, dst: int) -> "PipelineConfig":
        """Move one boundary layer from stage ``src`` to adjacent stage ``dst``.

        Contiguity allows moves only between neighbouring stages; the layer
        moved is the one at the shared boundary.  If src would become empty
        the move is rejected (returns self).
        """
        if abs(src - dst) != 1:
            raise ValueError(f"stages {src} and {dst} are not adjacent")
        if self.stages[src] <= 1:
            return self  # cannot empty a stage
        stages = list(self.stages)
        stages[src] -= 1
        stages[dst] += 1
        return dataclasses.replace(self, stages=tuple(stages))

    def swap_eps(self, i: int, j: int) -> "PipelineConfig":
        eps = list(self.eps)
        eps[i], eps[j] = eps[j], eps[i]
        return dataclasses.replace(self, eps=tuple(eps))

    def neighbours(self) -> Iterator["PipelineConfig"]:
        """Local-move neighbourhood used by Hill Climbing / SA baselines."""
        for i in range(self.depth - 1):
            if self.stages[i] > 1:
                yield self.move_layer(i, i + 1)
            if self.stages[i + 1] > 1:
                yield self.move_layer(i + 1, i)
        for i in range(self.depth):
            for j in range(i + 1, self.depth):
                yield self.swap_eps(i, j)

    def pretty(self, ep_names: Sequence[str] | None = None) -> str:
        cells = []
        for s, e in zip(self.stages, self.eps):
            en = ep_names[e] if ep_names else f"EP{e}"
            cells.append(f"{s}L@{en}")
        return " | ".join(cells)
