"""Static per-layer cost model.

Equation 1 of the paper assigns each conv layer a *weight*

    W = H * W_in * C * R * S * K            (MACs of the convolution)

which Algorithm 1 uses as the static load estimate when grouping layers into
pipeline stages.  For the LM-family architectures the same quantity — the
per-layer forward MAC count — is computed from the block structure
(attention + FFN / active experts / SSD).  The generalization is deliberate:
the paper uses Eq. 1 purely as a static load proxy, so each layer *kind*
contributes its own FLOP formula (DESIGN.md §4).

Every layer also carries a byte estimate (weights + activations touched),
used by the roofline evaluator (`core/evaluator.py`) to model bandwidth-bound
layers on low-bandwidth EPs — which is exactly the heterogeneity Shisha's
platform hints are about.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

# ---------------------------------------------------------------------------
# Layer descriptors
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Layer:
    """One schedulable unit of the network chain.

    ``flops``        — forward FLOPs for one inference unit (image/microbatch).
    ``bytes_mem``    — bytes moved from the EP's memory (weights + act streams).
    ``act_bytes``    — output-activation bytes shipped to the next stage
                       (inter-EP traffic when a stage boundary falls here).
    ``weight_bytes`` — resident parameter bytes; what a placement move must
                       ship over the fabric when the layer's stage is
                       relocated to another EP (hop-priced reconfiguration).
    """

    name: str
    flops: float
    bytes_mem: float
    act_bytes: float
    kind: str = "conv"
    weight_bytes: float = 0.0

    @property
    def weight(self) -> float:
        """Eq. 1 weight (static load estimate). MACs => flops/2 for convs,
        but a constant factor is irrelevant to ranking/merging, so we use
        flops directly."""
        return self.flops


def conv_layer(
    name: str,
    h: int,
    w: int,
    c: int,
    r: int,
    s: int,
    k: int,
    *,
    stride: int = 1,
    dtype_bytes: int = 4,
) -> Layer:
    """Build a Layer from conv dims, Eq. 1 of the paper.

    H, W are *output* spatial dims of the conv (the paper indexes the input
    tensor; for stride-1 same-pad convs these coincide — we follow the
    output-centred convention used by the Im2Col+GEMM operator it simulates).
    """
    ho, wo = h // stride, w // stride
    macs = ho * wo * c * r * s * k
    weight_bytes = c * r * s * k * dtype_bytes
    in_bytes = h * w * c * dtype_bytes
    out_bytes = ho * wo * k * dtype_bytes
    # Im2Col materializes the patch matrix: dominant memory stream.
    im2col_bytes = ho * wo * c * r * s * dtype_bytes
    return Layer(
        name=name,
        flops=2.0 * macs,
        bytes_mem=weight_bytes + in_bytes + out_bytes + im2col_bytes,
        act_bytes=out_bytes,
        kind="conv",
        weight_bytes=weight_bytes,
    )


# ---------------------------------------------------------------------------
# Transformer-family layer costs (generalized Eq. 1)
# ---------------------------------------------------------------------------


def attention_layer(
    name: str,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    seq: int,
    *,
    batch: int = 1,
    window: int | None = None,
    dtype_bytes: int = 2,
) -> Layer:
    head_dim = d_model // n_heads
    kv_dim = n_kv_heads * head_dim
    t = batch * seq
    proj = 2.0 * t * (d_model * d_model + 2 * d_model * kv_dim + d_model * d_model)
    ctx = min(seq, window) if window else seq
    attn = 2.0 * batch * n_heads * seq * ctx * head_dim * 2  # QK^T + PV
    w_bytes = (2 * d_model * d_model + 2 * d_model * kv_dim) * dtype_bytes
    act = t * d_model * dtype_bytes
    return Layer(
        name=name,
        flops=proj + attn,
        bytes_mem=w_bytes + 4 * act,
        act_bytes=act,
        kind="attn",
        weight_bytes=w_bytes,
    )


def ffn_layer(
    name: str,
    d_model: int,
    d_ff: int,
    *,
    seq: int,
    batch: int = 1,
    gated: bool = True,
    n_experts: int = 0,
    top_k: int = 0,
    dtype_bytes: int = 2,
) -> Layer:
    t = batch * seq
    mats = 3 if gated else 2
    dense_flops = 2.0 * t * mats * d_model * d_ff
    if n_experts:
        flops = dense_flops * top_k  # active experts only (MoE, DESIGN.md §4)
        w_bytes = n_experts * mats * d_model * d_ff * dtype_bytes
        kind = "moe"
    else:
        flops = dense_flops
        w_bytes = mats * d_model * d_ff * dtype_bytes
        kind = "ffn"
    act = t * d_model * dtype_bytes
    return Layer(name=name, flops=flops, bytes_mem=w_bytes + 4 * act, act_bytes=act, kind=kind, weight_bytes=w_bytes)


def ssd_layer(
    name: str,
    d_model: int,
    ssm_state: int,
    *,
    seq: int,
    batch: int = 1,
    expand: int = 2,
    dtype_bytes: int = 2,
) -> Layer:
    """Mamba2 SSD block: in/out projections + chunked state-space scan."""
    d_inner = expand * d_model
    t = batch * seq
    proj = 2.0 * t * (d_model * 2 * d_inner + d_inner * d_model)
    scan = 2.0 * t * d_inner * ssm_state * 3  # B-expand, state update, C-contract
    w_bytes = (3 * d_model * d_inner + d_inner * ssm_state * 2) * dtype_bytes
    act = t * d_model * dtype_bytes
    return Layer(name=name, flops=proj + scan, bytes_mem=w_bytes + 4 * act, act_bytes=act, kind="ssd", weight_bytes=w_bytes)


def fuse(name: str, layers: Sequence[Layer], kind: str = "block") -> Layer:
    """Fuse sub-layers into one schedulable block (attn+ffn => one layer)."""
    return Layer(
        name=name,
        flops=sum(l.flops for l in layers),
        bytes_mem=sum(l.bytes_mem for l in layers),
        act_bytes=layers[-1].act_bytes,
        kind=kind,
        weight_bytes=sum(l.weight_bytes for l in layers),
    )


# ---------------------------------------------------------------------------
# Chain-level helpers used by Algorithm 1
# ---------------------------------------------------------------------------


def weights(layers: Sequence[Layer]) -> list[float]:
    """The paper's W_l list."""
    return [l.weight for l in layers]


def total_flops(layers: Sequence[Layer]) -> float:
    return sum(l.flops for l in layers)
