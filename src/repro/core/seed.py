"""Algorithm 1 — seed generation.

Input:  W_l (per-layer weights, Eq. 1), H_e (EPs ranked fast-to-slow),
        N (target pipeline depth), L (layer count), C (assignment choice).
Output: seed = layers-per-stage composition, E = EP per stage.

Phase 1 (lines 3–8): repeat L-N times — find the lightest group, merge it
with its *lighter* adjacent neighbour (chain DAG => only consecutive merges
are legal).

Phase 2 (lines 9–11): rank stages (by layer count ``Rank_l``, by aggregate
weight ``Rank_w``, or ``random`` for the H5/H6 ablation) and assign them to
the ranked EP list.  Under ``Rank_w`` heavy stages go to fast EPs (load
balance); under ``Rank_l`` many-layer stages go to *slow* EPs — per §5.1 the
highest Rank_l rank is assigned to SEPs so that online tuning can later
greedily drain layers from them toward fast EPs.
"""

from __future__ import annotations

import dataclasses
import random as _random
from typing import Literal, Sequence

from .config import PipelineConfig
from .platform import Platform

Assignment = Literal["rank_l", "rank_w", "random"]


@dataclasses.dataclass(frozen=True)
class Seed:
    conf: PipelineConfig
    #: group -> constituent layer indices (diagnostics)
    groups: tuple[tuple[int, ...], ...]


def merge_layers(weights: Sequence[float], n_stages: int) -> list[list[int]]:
    """Phase 1: merge lightest group with its lighter adjacent neighbour."""
    if n_stages < 1:
        raise ValueError("need at least one stage")
    if n_stages > len(weights):
        raise ValueError(f"cannot make {n_stages} stages out of {len(weights)} layers")
    groups = [[i] for i in range(len(weights))]
    w = list(map(float, weights))
    for _ in range(len(weights) - n_stages):
        i = min(range(len(w)), key=w.__getitem__)  # lightest group (line 4)
        # lighter adjacent neighbour (line 5): min(w[i-1], w[i+1])
        if i == 0:
            j = 1
        elif i == len(w) - 1:
            j = i - 1
        else:
            j = i - 1 if w[i - 1] <= w[i + 1] else i + 1
        a, b = min(i, j), max(i, j)
        groups[a] = groups[a] + groups[b]
        w[a] = w[a] + w[b]
        del groups[b], w[b]
    return groups


def assign_eps(
    group_weights: Sequence[float],
    group_sizes: Sequence[int],
    platform: Platform,
    choice: Assignment,
    rng: _random.Random | None = None,
) -> list[int]:
    """Phase 2: rank stages, walk the ranked-EP list H_e."""
    n = len(group_weights)
    ranked_eps = platform.ranked()[:n]
    if choice == "rank_w":
        # heaviest stage -> fastest EP
        order = sorted(range(n), key=lambda i: -group_weights[i])
    elif choice == "rank_l":
        # most-layers stage -> ranked *last* (slow EPs), per §5.1
        order = sorted(range(n), key=lambda i: group_sizes[i])
    elif choice == "random":
        order = list(range(n))
        (rng or _random.Random(0)).shuffle(order)
    else:
        raise ValueError(f"unknown assignment choice {choice!r}")
    eps = [0] * n
    for rank, stage in enumerate(order):
        eps[stage] = ranked_eps[rank]
    return eps


def generate_seed(
    weights: Sequence[float],
    platform: Platform,
    n_stages: int | None = None,
    choice: Assignment = "rank_w",
    rng: _random.Random | None = None,
) -> Seed:
    """Algorithm 1 end-to-end.  Default depth = one stage per EP."""
    n = n_stages if n_stages is not None else min(platform.n_eps, len(weights))
    groups = merge_layers(weights, n)
    gw = [sum(weights[i] for i in g) for g in groups]
    gs = [len(g) for g in groups]
    eps = assign_eps(gw, gs, platform, choice, rng)
    conf = PipelineConfig(stages=tuple(gs), eps=tuple(eps))
    return Seed(conf=conf, groups=tuple(tuple(g) for g in groups))
