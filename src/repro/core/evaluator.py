"""The ``execute(conf)`` oracle of Algorithm 2.

The paper measures throughput of a candidate pipeline by actually running it
(in their setup: querying a gem5-derived database of per-layer times).  The
oracle is therefore pluggable here:

  * :class:`AnalyticEvaluator` — roofline model per (layer, EP):
        t_layer = max(flops / EP.flops, bytes / EP.mem_bw)
    plus inter-stage transfer time over the EP link (bandwidth + latency,
    the Fig. 9 knob).  Throughput = 1 / max_stage_time (steady-state
    pipeline, one inference unit per beat).  When the platform carries an
    interconnect fabric (:class:`~repro.interconnect.Fabric`), each
    stage-boundary transfer is *routed* and priced under the steady-state
    flow set — all of the schedule's boundary transfers plus any
    ``background_flows`` a serving layer injects — so shared links fair-share
    their bandwidth (the graph form of the paper's §6 shared-memory-
    controller effect, and the Fig. 9 latency knob becomes per-hop).

  * :class:`DatabaseEvaluator` — mimics the paper's gem5 database: per
    (layer, EP-type) times are precomputed once with deterministic
    measurement noise, then only *queried* during exploration.  This is the
    faithful-reproduction oracle used by benchmarks/fig*.py.

  * :class:`MeasuringEvaluator` (in ``pipeline/runtime.py``) — times the
    real JAX pipeline; the true "online" mode.

Every evaluator is wrapped in :class:`Trace` by the exploration drivers to
account configurations tried and *simulated wall-clock cost* of trying them
(a trial costs ``measure_batches`` pipeline beats plus a reconfiguration
penalty — this is what makes "trying bad configurations" expensive, the
effect Shisha exploits).
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Protocol, Sequence

from .config import PipelineConfig
from .cost_model import Layer
from .platform import Platform


class Evaluator(Protocol):
    platform: Platform
    layers: Sequence[Layer]

    def stage_times(self, conf: PipelineConfig) -> list[float]: ...

    def throughput(self, conf: PipelineConfig) -> float: ...


# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AnalyticEvaluator:
    """Roofline-model oracle (layer time = max(compute, memory) + link)."""

    platform: Platform
    layers: Sequence[Layer]
    #: per-layer fixed overhead on the EP (kernel-launch / queue pop), s
    layer_overhead: float = 2e-6
    #: co-tenant flows priced into every transfer when the platform has a
    #: fabric (node-space :class:`~repro.interconnect.Flow`s injected by the
    #: serving layer); ignored on scalar-link platforms
    background_flows: tuple = ()

    def nominal_layer_time(self, layer: Layer, ep_idx: int) -> float:
        """Layer time at the EP's nominal clock (DVFS-independent)."""
        ep = self.platform.eps[ep_idx]
        return max(layer.flops / ep.flops, layer.bytes_mem / ep.mem_bw) + self.layer_overhead

    def layer_time(self, layer: Layer, ep_idx: int) -> float:
        t = self.nominal_layer_time(layer, ep_idx)
        pm = self.platform.power
        if pm is not None:
            # DVFS scales the EP's compute rate and memory bandwidth
            # together, so the whole on-EP time divides by the level's
            # scale (exactly 1.0 at nominal: the no-power path is
            # reproduced bit-for-bit).  Link transfers are unscaled — the
            # interconnect runs on its own clock.
            t = t / pm.scale(ep_idx)
        return t

    def transfer_times(self, conf: PipelineConfig) -> list[float]:
        """Inter-stage transfer time per stage boundary (s -> s+1).

        Scalar path: the output activations of the stage's last layer cross
        one link priced by the two EPs' specs.  Fabric path: every boundary
        transfer of the steady-state pipeline (plus ``background_flows``) is
        routed and priced under shared-link contention.
        """
        n_links = conf.depth - 1
        if n_links <= 0:
            return []
        bounds = conf.boundaries()
        fabric = self.platform.fabric
        if fabric is None:
            out = []
            for s in range(n_links):
                ep = self.platform.eps[conf.eps[s]]
                nxt = self.platform.eps[conf.eps[s + 1]]
                bw = min(ep.link_bw, nxt.link_bw)
                lat = max(ep.link_latency, nxt.link_latency)
                out.append(self.layers[bounds[s][1] - 1].act_bytes / bw + lat)
            return out
        from ..interconnect import Flow

        flows = [
            Flow(conf.eps[s], conf.eps[s + 1], self.layers[bounds[s][1] - 1].act_bytes)
            for s in range(n_links)
        ]
        return fabric.flow_times(flows + list(self.background_flows))[:n_links]

    def stage_times(self, conf: PipelineConfig) -> list[float]:
        times = []
        link = self.transfer_times(conf)
        for s, (a, b) in enumerate(conf.boundaries()):
            ep_idx = conf.eps[s]
            t = sum(self.layer_time(self.layers[i], ep_idx) for i in range(a, b))
            if s < conf.depth - 1:
                t += link[s]
            times.append(t)
        return times

    def throughput(self, conf: PipelineConfig) -> float:
        """Steady-state inferences/second = 1 / slowest stage beat."""
        return 1.0 / max(self.stage_times(conf))

    def pipeline_latency(self, conf: PipelineConfig) -> float:
        return sum(self.stage_times(conf))


# ---------------------------------------------------------------------------


def _noise(key: str, sigma: float) -> float:
    """Deterministic pseudo-measurement noise in [1-sigma, 1+sigma]."""
    h = int.from_bytes(hashlib.sha256(key.encode()).digest()[:8], "big")
    u = h / float(1 << 64)  # [0,1)
    return 1.0 + sigma * (2.0 * u - 1.0)


@dataclasses.dataclass
class DatabaseEvaluator(AnalyticEvaluator):
    """gem5-style database: times precomputed once, then only queried.

    Deterministic multiplicative noise models gem5-vs-model discrepancy; it
    is keyed on (layer, EP) so repeated queries return identical values, as
    a database would.
    """

    noise_sigma: float = 0.08

    def __post_init__(self):
        self._db: dict[tuple[int, int], float] = {}
        for li, layer in enumerate(self.layers):
            for ei in range(self.platform.n_eps):
                # DB entries are nominal-clock times: the database is
                # measured once, while DVFS levels move during tuning, so
                # the scale is applied at query time (see stage_times)
                base = AnalyticEvaluator.nominal_layer_time(self, layer, ei)
                self._db[(li, ei)] = base * _noise(f"{layer.name}|{self.platform.eps[ei].name}", self.noise_sigma)

    def layer_time_by_index(self, layer_idx: int, ep_idx: int) -> float:
        t = self._db[(layer_idx, ep_idx)]
        pm = self.platform.power
        if pm is not None:
            t = t / pm.scale(ep_idx)
        return t

    def stage_times(self, conf: PipelineConfig) -> list[float]:
        times = []
        link = self.transfer_times(conf)
        pm = self.platform.power
        for s, (a, b) in enumerate(conf.boundaries()):
            ep_idx = conf.eps[s]
            t = sum(self._db[(i, ep_idx)] for i in range(a, b))
            if pm is not None:
                t = t / pm.scale(ep_idx)
            if s < conf.depth - 1:
                t += link[s]
            times.append(t)
        return times


# ---------------------------------------------------------------------------
# Exploration accounting
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Trial:
    conf: PipelineConfig
    throughput: float
    #: cumulative simulated wall-clock when this trial finished, seconds
    t_wall: float


@dataclasses.dataclass
class Trace:
    """Wraps an evaluator; accounts every execute() like the real runtime.

    Trying a configuration online costs real time: the pipeline must be
    reconfigured (weights shipped to the new EPs) and run for a few batches
    to measure steady-state throughput.  All exploration algorithms pay this
    identically, so convergence-time comparisons (Fig. 4) are fair.
    """

    evaluator: AnalyticEvaluator
    measure_batches: int = 8
    reconfig_overhead: float = 0.05  # seconds per reconfiguration
    #: one-off setup cost (e.g. Pipe-Search / ES database generation)
    setup_cost: float = 0.0
    #: when True, re-visiting a configuration returns the remembered
    #: throughput for free (no wall-clock charge, no new trial).  Off by
    #: default: the Fig. 4 cost accounting assumes every visit is paid,
    #: as on real hardware where a revisit still costs pipeline time.
    use_cache: bool = False
    #: optional :class:`repro.telemetry.Telemetry` session; every *paid*
    #: trial records its charged wall cost and measured beat (duck-typed so
    #: ``repro.core`` stays import-free of the telemetry package)
    telemetry: "object | None" = None

    def __post_init__(self):
        self.trials: list[Trial] = []
        self._wall = float(self.setup_cost)
        self._cache: dict[PipelineConfig, float] = {}

    @property
    def wall(self) -> float:
        return self._wall

    @property
    def n_trials(self) -> int:
        return len(self.trials)

    def execute(self, conf: PipelineConfig, reconfig_cost: float | None = None) -> float:
        """Measure throughput of ``conf``, paying the simulated cost.

        ``reconfig_cost`` overrides the flat ``reconfig_overhead`` for this
        one trial — how placement-aware tuning charges an EP-relocation its
        routed weight-shipping cost (hops x stage weight bytes over the
        fabric) instead of the flat boundary-move price.  ``None`` keeps the
        flat charge, so every pre-placement exploration path is bit-for-bit
        unchanged.  A ``use_cache`` hit stays entirely free by its existing
        contract (no wall charge, no trial) — the override, like the flat
        overhead it replaces, is only paid when the trial actually runs.
        """
        if self.use_cache and conf in self._cache:
            return self._cache[conf]
        beat = max(self.evaluator.stage_times(conf))
        fill = self.evaluator.pipeline_latency(conf)
        if reconfig_cost is None:
            reconfig_cost = self.reconfig_overhead
        if math.isfinite(beat):
            charged = reconfig_cost + fill + self.measure_batches * beat
        else:
            # a severed stage boundary (link fault) makes the pipeline
            # unable to flow: the runtime reconfigures, sees nothing come
            # out, and abandons the trial — only the reconfiguration is paid
            charged = reconfig_cost
        self._wall += charged
        tl = self.telemetry
        if tl is not None and tl.enabled:
            tl.counter("tune.trials").inc()
            tl.histogram("tune.trial_cost_s").observe(charged)
            tl.histogram("tune.trial_beat_s").observe(beat)
        tp = self.evaluator.throughput(conf)
        if self.use_cache:
            self._cache[conf] = tp
        self.trials.append(Trial(conf, tp, self._wall))
        return tp

    def best(self) -> Trial:
        if not self.trials:
            raise RuntimeError("no trials executed")
        return max(self.trials, key=lambda t: t.throughput)

    def convergence_curve(self) -> list[tuple[float, float]]:
        """(wall time, best-so-far throughput) staircase, for Fig. 4."""
        out, best = [], 0.0
        for t in self.trials:
            best = max(best, t.throughput)
            out.append((t.t_wall, best))
        return out
