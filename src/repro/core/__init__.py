"""Shisha core: the paper's contribution (seed generation + online tuning)."""

from .baselines import (
    SearchResult,
    database_generation_cost,
    exhaustive_search,
    hill_climbing,
    pipe_search,
    random_config,
    random_walk,
    simulated_annealing,
)
from .config import PipelineConfig
from .cost_model import (
    Layer,
    attention_layer,
    conv_layer,
    ffn_layer,
    fuse,
    ssd_layer,
    total_flops,
    weights,
)
from .evaluator import AnalyticEvaluator, DatabaseEvaluator, Trace, Trial
from .heuristics import HEURISTICS, ShishaResult, run_shisha
from .platform import (
    EP,
    Platform,
    paper_platform,
    table3_platform,
    tpu_platform,
    tpu_slice_ep,
    TPU_PEAK_FLOPS,
    TPU_HBM_BW,
    TPU_ICI_BW,
)
from .seed import Seed, assign_eps, generate_seed, merge_layers
from .space import compositions, enumerate_configs, space_size
from .tuner import TuneResult, pick_target, tune

__all__ = [
    "AnalyticEvaluator",
    "DatabaseEvaluator",
    "EP",
    "HEURISTICS",
    "Layer",
    "PipelineConfig",
    "Platform",
    "SearchResult",
    "Seed",
    "ShishaResult",
    "Trace",
    "Trial",
    "TuneResult",
    "attention_layer",
    "assign_eps",
    "compositions",
    "conv_layer",
    "database_generation_cost",
    "enumerate_configs",
    "exhaustive_search",
    "ffn_layer",
    "fuse",
    "generate_seed",
    "hill_climbing",
    "merge_layers",
    "paper_platform",
    "pick_target",
    "pipe_search",
    "random_config",
    "random_walk",
    "run_shisha",
    "simulated_annealing",
    "space_size",
    "ssd_layer",
    "table3_platform",
    "total_flops",
    "tpu_platform",
    "tpu_slice_ep",
    "tune",
    "weights",
    "TPU_PEAK_FLOPS",
    "TPU_HBM_BW",
    "TPU_ICI_BW",
]
