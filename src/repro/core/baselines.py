"""Baseline exploration algorithms Shisha is compared against (paper §7).

All baselines consume the same :class:`Trace` accounting as Shisha — every
``execute`` costs simulated pipeline time — so Fig.-4-style convergence
curves are directly comparable.  Each stops when its simulated wall clock
exceeds ``budget_s`` (the online time budget) or its own termination rule
fires.

* Hill Climbing (HC) — first-improvement over the local-move neighbourhood
  (boundary-layer moves + EP swaps); restarts from a random config when
  stuck.
* Simulated Annealing (SA) — random neighbour, Metropolis acceptance on
  relative throughput, geometric cooling (the schedule TVM/Ansor-style
  tuners use).
* Random Walk (RW) — independent uniform configurations, keep the best.
* Exhaustive Search (ES) — enumerate everything; pays an up-front
  database-generation cost like the paper's ES/Pipe-Search setup.
* Pipe-Search (PS) — generates the full configuration database, *sorts* it
  by workload-balance variance (its "sorted w.r.t. distribution of workload"
  ordering), then tests configurations in that order until the time limit.
"""

from __future__ import annotations

import dataclasses
import math
import random as _random
from typing import Sequence

from .config import PipelineConfig
from .evaluator import Trace
from .space import compositions, enumerate_configs, space_size


@dataclasses.dataclass
class SearchResult:
    name: str
    best_conf: PipelineConfig
    best_throughput: float
    n_explored: int


def random_config(rng: _random.Random, n_layers: int, n_eps: int, depth: int | None = None) -> PipelineConfig:
    d = depth or rng.randint(1, min(n_layers, n_eps))
    cuts = sorted(rng.sample(range(1, n_layers), d - 1))
    stages, prev = [], 0
    for c in cuts:
        stages.append(c - prev)
        prev = c
    stages.append(n_layers - prev)
    eps = rng.sample(range(n_eps), d)
    return PipelineConfig(stages=tuple(stages), eps=tuple(eps))


# ---------------------------------------------------------------------------


def hill_climbing(
    trace: Trace,
    n_layers: int,
    budget_s: float,
    start: PipelineConfig | None = None,
    seed: int = 0,
    max_stall_restarts: int = 50,
) -> SearchResult:
    rng = _random.Random(seed)
    n_eps = trace.evaluator.platform.n_eps
    conf = start or random_config(rng, n_layers, n_eps)
    best_tp = trace.execute(conf)
    best_conf = conf
    restarts = 0
    while trace.wall < budget_s and restarts <= max_stall_restarts:
        cur_tp = trace.evaluator.throughput(conf)
        improved = False
        neigh = list(conf.neighbours())
        rng.shuffle(neigh)
        for cand in neigh:
            if trace.wall >= budget_s:
                break
            tp = trace.execute(cand)
            if tp > best_tp:
                best_tp, best_conf = tp, cand
            if tp > cur_tp:  # first improvement
                conf, improved = cand, True
                break
        if not improved:
            restarts += 1
            conf = random_config(rng, n_layers, n_eps)
            if trace.wall < budget_s:
                tp = trace.execute(conf)
                if tp > best_tp:
                    best_tp, best_conf = tp, conf
    return SearchResult("HC", best_conf, best_tp, trace.n_trials)


def simulated_annealing(
    trace: Trace,
    n_layers: int,
    budget_s: float,
    start: PipelineConfig | None = None,
    seed: int = 0,
    t0: float = 0.30,
    cooling: float = 0.97,
) -> SearchResult:
    rng = _random.Random(seed)
    n_eps = trace.evaluator.platform.n_eps
    conf = start or random_config(rng, n_layers, n_eps)
    cur_tp = trace.execute(conf)
    best_conf, best_tp = conf, cur_tp
    temp = t0
    while trace.wall < budget_s and temp > 1e-4:
        neigh = list(conf.neighbours())
        if not neigh:
            break
        cand = rng.choice(neigh)
        tp = trace.execute(cand)
        if tp > best_tp:
            best_conf, best_tp = cand, tp
        # relative throughput delta drives acceptance
        delta = (tp - cur_tp) / max(cur_tp, 1e-30)
        if delta >= 0 or rng.random() < math.exp(delta / temp):
            conf, cur_tp = cand, tp
        temp *= cooling
    return SearchResult("SA", best_conf, best_tp, trace.n_trials)


def random_walk(
    trace: Trace, n_layers: int, budget_s: float, seed: int = 0
) -> SearchResult:
    rng = _random.Random(seed)
    n_eps = trace.evaluator.platform.n_eps
    best_conf, best_tp = None, -1.0
    while trace.wall < budget_s:
        conf = random_config(rng, n_layers, n_eps)
        tp = trace.execute(conf)
        if tp > best_tp:
            best_conf, best_tp = conf, tp
    if best_conf is None:
        best_conf = random_config(rng, n_layers, n_eps)
        best_tp = trace.execute(best_conf)
    return SearchResult("RW", best_conf, best_tp, trace.n_trials)


def exhaustive_search(
    trace: Trace,
    n_layers: int,
    budget_s: float = math.inf,
    max_depth: int | None = None,
) -> SearchResult:
    n_eps = trace.evaluator.platform.n_eps
    best_conf, best_tp = None, -1.0
    for conf in enumerate_configs(n_layers, n_eps, max_depth=max_depth):
        if trace.wall >= budget_s:
            break
        tp = trace.execute(conf)
        if tp > best_tp:
            best_conf, best_tp = conf, tp
    assert best_conf is not None
    return SearchResult("ES", best_conf, best_tp, trace.n_trials)


# ---------------------------------------------------------------------------
# Pipe-Search (Soomro et al., CF'21) re-implementation
# ---------------------------------------------------------------------------


def database_generation_cost(n_layers: int, n_eps: int, max_depth: int | None = None, per_entry_s: float = 2e-4) -> float:
    """Up-front cost of building the sorted configuration database.

    Pipe-Search (and ES, which shares the enumeration) must materialize and
    sort the whole space before exploring — ~1200 s in the paper's Fig. 4.
    We charge a per-entry generation cost; the default reproduces that order
    of magnitude for the SynthNet/8-EP space.
    """
    return space_size(n_layers, n_eps, max_depth) * per_entry_s


def pipe_search(
    trace: Trace,
    weights: Sequence[float],
    budget_s: float,
    max_depth: int | None = None,
    max_db: int = 200_000,
) -> SearchResult:
    """Database of configurations ordered by workload-balance variance.

    Pipe-Search is heterogeneity-blind (paper §7.1): its ordering considers
    only the workload split across stages, not which EP a stage lands on —
    so it converges before trying high-variance splits that heterogeneous
    platforms actually want.
    """
    n_eps = trace.evaluator.platform.n_eps
    n_layers = len(weights)
    total = sum(weights)

    def imbalance(stages: tuple[int, ...]) -> float:
        bounds, start = [], 0
        means = total / len(stages)
        var = 0.0
        for s in stages:
            w = sum(weights[start : start + s])
            var += (w - means) ** 2
            start += s
        return var

    db: list[PipelineConfig] = []
    for d in range(1, min(n_layers, n_eps, max_depth or n_eps) + 1):
        for stages in compositions(n_layers, d):
            if len(db) >= max_db:
                break
            # heterogeneity-blind: EPs assigned in fixed platform order
            db.append(PipelineConfig(stages=stages, eps=tuple(range(d))))
        if len(db) >= max_db:
            break
    db.sort(key=lambda c: imbalance(c.stages))

    best_conf, best_tp = None, -1.0
    for conf in db:
        if trace.wall >= budget_s:
            break
        tp = trace.execute(conf)
        if tp > best_tp:
            best_conf, best_tp = conf, tp
    if best_conf is None:
        best_conf = db[0]
        best_tp = trace.execute(best_conf)
    return SearchResult("PS", best_conf, best_tp, trace.n_trials)
