"""``repro.interconnect`` — the chiplet fabric as a first-class subsystem.

Shisha's premise is heterogeneity "at the level of cores, memory subsystem
and the interconnect" (§2); the pre-fabric evaluator collapsed the third
axis to one scalar link (the Fig. 9 latency knob).  This package models the
interconnect as a graph instead:

  * :mod:`.topology` — router nodes + per-link bandwidth/latency, preset
    fabrics (2D mesh, ring, crossbar, hierarchical package-of-chiplets,
    fully-connected) and deterministic routing (XY on meshes, tie-broken
    Dijkstra elsewhere).
  * :mod:`.fabric`   — the EP -> node binding plus contention pricing:
    fair-share slowdown on shared links and memory-controller hotspots,
    evaluated over the steady-state flow set of a pipelined schedule.

Attach a fabric with ``Platform.with_fabric`` and every consumer — the
evaluators, Algorithm 2 (including its placement-aware moves), the serving
simulator and the multi-tenant co-simulator — prices transfers over routed,
contended paths; leave it off (or use :func:`~.fabric.scalar_fabric`) and
all pre-fabric results reproduce bit-for-bit.
"""

from .fabric import Fabric, Flow, scalar_fabric, uniform_fabric
from .topology import (
    Link,
    LinkKey,
    Topology,
    crossbar,
    fully_connected,
    hierarchical,
    mesh2d,
    ring,
)

__all__ = [
    "Fabric",
    "Flow",
    "Link",
    "LinkKey",
    "Topology",
    "crossbar",
    "fully_connected",
    "hierarchical",
    "mesh2d",
    "ring",
    "scalar_fabric",
    "uniform_fabric",
]
