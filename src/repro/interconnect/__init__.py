"""``repro.interconnect`` — the chiplet fabric as a first-class subsystem.

Shisha's premise is heterogeneity "at the level of cores, memory subsystem
and the interconnect" (§2); the pre-fabric evaluator collapsed the third
axis to one scalar link (the Fig. 9 latency knob).  This package models the
interconnect as a graph instead:

  * :mod:`.topology` — router nodes + per-link bandwidth/latency, preset
    fabrics (2D mesh with optional row express channels, ring with
    per-segment bandwidths, crossbar with per-port uplink bandwidths,
    hierarchical package-of-chiplets with intra-/inter-package asymmetry,
    fully-connected) with heterogeneous links, deterministic routing (XY on
    meshes, tie-broken Dijkstra elsewhere) and deterministic k-shortest-path
    enumeration (Yen's algorithm).
  * :mod:`.fabric`   — the EP -> node binding plus contention pricing:
    fair-share slowdown on shared links and memory-controller hotspots
    (per-node caps derived from EP ``mem_bw`` by default at attach time),
    evaluated over the steady-state flow set of a pipelined schedule — and
    the routing *decision* itself: ``routing="adaptive"`` assigns each flow
    a path among its k shortest candidates by congestion-priced iterated
    best response.

Attach a fabric with ``Platform.with_fabric`` and every consumer — the
evaluators, Algorithm 2 (including its placement-aware moves, each
relocation trial charged its routed hop-priced weight-shipping cost), the
serving simulator and the multi-tenant co-simulator (which re-routes every
lane's flows each monitor window as co-tenant traffic shifts) — prices
transfers over routed, contended paths; leave it off (or use
:func:`~.fabric.scalar_fabric`) and all pre-fabric results reproduce
bit-for-bit.

**Determinism contract of the seeded fixed-point router.**  The adaptive
assignment is a *pure function* of (topology, flow multiset, ``seed``):

  1. candidate paths come from :meth:`.Topology.k_shortest_paths`, whose
     Yen enumeration orders by (total latency, hop count, lexicographically
     smallest node sequence) — no dict/heap iteration-order dependence;
  2. best-response sweeps visit flows in the canonical order of their
     identity (sorted by endpoints then size; exact duplicates are
     interchangeable), starting from the all-static assignment, for at most
     ``max_sweeps`` rounds or until a fixed point — so reordering a flow
     list never changes the assignment;
  3. exact-cost ties between candidate paths resolve by (fewest hops, then
     a SHA-256 hash keyed on (``seed``, flow endpoints + size, path)) —
     stable across processes and platforms, unlike Python's salted
     ``hash``;
  4. the final assignment is kept only if it prices strictly better *in
     total* than all-static; ties return the static assignment itself.

Consequences: repeated calls, freshly rebuilt identical topologies, and
replayed serving scenarios all see identical routes and prices (pinned by
``tests/test_fabric_properties.py``), and an adaptive fabric can never
price a flow set worse than the static one it replaces.
"""

from .fabric import Fabric, Flow, scalar_fabric, uniform_fabric
from .topology import (
    Link,
    LinkKey,
    Topology,
    crossbar,
    fully_connected,
    hierarchical,
    mesh2d,
    path_links,
    ring,
)

__all__ = [
    "Fabric",
    "Flow",
    "Link",
    "LinkKey",
    "Topology",
    "crossbar",
    "fully_connected",
    "hierarchical",
    "mesh2d",
    "path_links",
    "ring",
    "scalar_fabric",
    "uniform_fabric",
]
