"""Chiplet interconnect topology: nodes, links and deterministic routing.

The paper (Shisha §2/§6) defines heterogeneity "at the level of cores,
memory subsystem *and the interconnect*", and its Fig. 9 sensitivity study
sweeps a single inter-chiplet latency scalar.  This module upgrades that
scalar into a graph: a :class:`Topology` is a set of router nodes joined by
:class:`Link`\\ s with individual bandwidth/latency, plus a deterministic
routing function.  Presets cover the fabrics real chiplet packages use —
2D mesh (XY dimension-ordered routing), ring, crossbar (a star through a
central switch) and a hierarchical "package of chiplets" — alongside the
fully-connected degenerate that reproduces the old scalar-link model
bit-for-bit (see :func:`repro.interconnect.fabric.scalar_fabric`).

Routing is a pure function of the topology: the same (src, dst) pair always
returns the identical link sequence, which is what keeps the evaluator and
every tuner built on it deterministic.  Mesh topologies use XY
dimension-ordered routing (the standard deadlock-free NoC choice); every
other topology routes by Dijkstra over (total latency, hop count, lexico-
graphically smallest node sequence), so ties can never depend on dict or
heap iteration order.

Links are heterogeneous: every preset can mix fast and slow links in one
fabric — meshes grow row *express channels* (long-range links skipping
intermediate routers, as in express-cube NoCs), crossbars take per-port
uplink bandwidths (a slow port models a chiplet hanging off a previous-gen
PHY), rings take per-segment bandwidths, and the hierarchical preset keeps
its intra-/inter-package asymmetry.  Static XY/Dijkstra routing ignores
bandwidth entirely (it is latency/hop-ordered), so heterogeneous bandwidths
only matter to the contention pricing — and to the *adaptive* router
(:class:`~repro.interconnect.fabric.Fabric` with ``routing="adaptive"``),
which chooses among :meth:`Topology.k_shortest_paths` by congested cost.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Mapping, Sequence

#: normalized undirected link key: (u, v) with u < v
LinkKey = tuple[int, int]


@dataclasses.dataclass(frozen=True)
class Link:
    """One physical inter-router link."""

    #: bandwidth, bytes/s
    bw: float
    #: one-way traversal latency, seconds (per-hop share of the Fig. 9 knob)
    latency: float

    def __post_init__(self):
        if self.bw <= 0 or self.latency < 0:
            raise ValueError(f"bad link spec bw={self.bw} latency={self.latency}")


def _key(u: int, v: int) -> LinkKey:
    if u == v:
        raise ValueError(f"self-link at node {u}")
    return (u, v) if u < v else (v, u)


def path_links(path: Sequence[int]) -> tuple[LinkKey, ...]:
    """The normalized link sequence of a node path (adjacent hops)."""
    return tuple(_key(a, b) for a, b in zip(path, path[1:]))


@dataclasses.dataclass(eq=False)
class Topology:
    """An undirected interconnect graph with per-link bandwidth/latency.

    ``coords`` (optional) places nodes on a 2D grid and switches routing to
    XY dimension-ordered; without coordinates routes come from deterministic
    Dijkstra.  Instances compare by identity — two separately built
    topologies are distinct objects even if structurally equal, which keeps
    them safely usable inside frozen :class:`~repro.core.platform.Platform`
    dataclasses (the ``fabric`` field is excluded from comparison).
    """

    name: str
    n_nodes: int
    links: Mapping[LinkKey, Link]
    #: node -> (x, y) grid position; enables XY routing on meshes
    coords: Mapping[int, tuple[int, int]] | None = None

    def __post_init__(self):
        if self.n_nodes < 1:
            raise ValueError("topology needs at least one node")
        self.links = {_key(*k): l for k, l in self.links.items()}
        adj: dict[int, list[int]] = {n: [] for n in range(self.n_nodes)}
        for (u, v) in self.links:
            if not (0 <= u < self.n_nodes and 0 <= v < self.n_nodes):
                raise ValueError(f"link ({u},{v}) outside 0..{self.n_nodes - 1}")
            adj[u].append(v)
            adj[v].append(u)
        #: node -> sorted neighbour list (sorted: no dict-order dependence)
        self._adj = {n: tuple(sorted(ns)) for n, ns in adj.items()}
        self._routes: dict[tuple[int, int], tuple[LinkKey, ...]] = {}
        self._kpaths: dict[tuple[int, int, int], tuple[tuple[int, ...], ...]] = {}

    def link(self, u: int, v: int) -> Link:
        return self.links[_key(u, v)]

    def neighbors(self, node: int) -> tuple[int, ...]:
        return self._adj[node]

    # -- routing ------------------------------------------------------------

    def route(self, src: int, dst: int) -> tuple[LinkKey, ...]:
        """Deterministic link sequence from ``src`` to ``dst``.

        XY dimension-ordered on grids with coordinates (when every grid hop
        exists), shortest-path otherwise.  Cached: repeated queries are O(1)
        and — by construction — identical.
        """
        if src == dst:
            return ()
        key = (src, dst)
        if key not in self._routes:
            path = None
            if self.coords is not None:
                path = self._xy_path(src, dst)
            if path is None:
                path = self._dijkstra_path(src, dst)
            self._routes[key] = tuple(
                _key(a, b) for a, b in zip(path, path[1:])
            )
        return self._routes[key]

    def path_latency(self, src: int, dst: int) -> float:
        """Total routed latency (sum of per-hop link latencies)."""
        return sum(self.links[k].latency for k in self.route(src, dst))

    def hops(self, src: int, dst: int) -> int:
        return len(self.route(src, dst))

    def _xy_path(self, src: int, dst: int) -> list[int] | None:
        """X-then-Y dimension-ordered walk; None if a grid hop is missing."""
        by_pos = {pos: n for n, pos in self.coords.items()}
        x, y = self.coords[src]
        dx, dy = self.coords[dst]
        path = [src]
        while x != dx:
            x += 1 if dx > x else -1
            nxt = by_pos.get((x, y))
            if nxt is None or _key(path[-1], nxt) not in self.links:
                return None
            path.append(nxt)
        while y != dy:
            y += 1 if dy > y else -1
            nxt = by_pos.get((x, y))
            if nxt is None or _key(path[-1], nxt) not in self.links:
                return None
            path.append(nxt)
        return path

    def _dijkstra_path(self, src: int, dst: int) -> list[int]:
        """Min (latency, hops, lexicographic node sequence) path."""
        found = self._constrained_path(src, dst, frozenset(), frozenset())
        if found is None:
            raise ValueError(f"no route {src} -> {dst} in topology {self.name!r}")
        return list(found)

    def _constrained_path(
        self,
        src: int,
        dst: int,
        banned_edges: frozenset[LinkKey],
        banned_nodes: frozenset[int],
    ) -> tuple[int, ...] | None:
        """Deterministic Dijkstra avoiding the given edges/nodes (Yen spur).

        Heap entries are fully ordered (latency, hops, path) tuples, so pop
        order — and thereby the chosen path — is independent of insertion
        order.
        """
        heap: list[tuple[float, int, tuple[int, ...]]] = [(0.0, 0, (src,))]
        done: set[int] = set()
        while heap:
            lat, hops, path = heapq.heappop(heap)
            node = path[-1]
            if node == dst:
                return path
            if node in done:
                continue
            done.add(node)
            for nxt in self._adj[node]:
                if nxt in done or nxt in banned_nodes:
                    continue
                k = _key(node, nxt)
                if k in banned_edges:
                    continue
                l = self.links[k]
                heapq.heappush(heap, (lat + l.latency, hops + 1, path + (nxt,)))
        return None

    def _path_cost(self, path: Sequence[int]) -> tuple[float, int, tuple[int, ...]]:
        lat = sum(self.links[_key(a, b)].latency for a, b in zip(path, path[1:]))
        return (lat, len(path) - 1, tuple(path))

    def k_shortest_paths(self, src: int, dst: int, k: int) -> tuple[tuple[int, ...], ...]:
        """Up to ``k`` loopless paths ``src`` -> ``dst``, cheapest first.

        Yen's algorithm over the same deterministic (latency, hops,
        lexicographic node sequence) order as :meth:`route`'s Dijkstra, so
        the enumeration is a pure function of the topology: identical
        topologies yield identical path lists in identical order — the
        foundation of the adaptive router's determinism contract.  Paths
        include express/shortcut links XY routing never takes.  Cached.
        """
        if src == dst:
            return ((src,),)
        if k < 1:
            raise ValueError(f"need k >= 1 paths, got {k}")
        key = (src, dst, k)
        if key not in self._kpaths:
            first = self._constrained_path(src, dst, frozenset(), frozenset())
            if first is None:
                raise ValueError(f"no route {src} -> {dst} in topology {self.name!r}")
            paths: list[tuple[int, ...]] = [first]
            # candidate heap of (cost, path); costs are fully ordered tuples
            cands: list[tuple[tuple[float, int, tuple[int, ...]], tuple[int, ...]]] = []
            seen = {first}
            while len(paths) < k:
                prev = paths[-1]
                for i in range(len(prev) - 1):
                    spur, root = prev[i], prev[: i + 1]
                    banned_edges = frozenset(
                        _key(p[i], p[i + 1])
                        for p in paths
                        if len(p) > i + 1 and p[: i + 1] == root
                    )
                    banned_nodes = frozenset(root[:-1])
                    tail = self._constrained_path(spur, dst, banned_edges, banned_nodes)
                    if tail is None:
                        continue
                    cand = root[:-1] + tail
                    if cand not in seen:
                        seen.add(cand)
                        heapq.heappush(cands, (self._path_cost(cand), cand))
                if not cands:
                    break
                paths.append(heapq.heappop(cands)[1])
            self._kpaths[key] = tuple(paths)
        return self._kpaths[key]

    # -- derived topologies ---------------------------------------------------

    def with_link_latency(self, latency_s: float) -> "Topology":
        """Copy with every link's latency replaced (the Fig. 9 sweep knob)."""
        return Topology(
            name=f"{self.name}@lat{latency_s:g}",
            n_nodes=self.n_nodes,
            links={k: dataclasses.replace(l, latency=latency_s) for k, l in self.links.items()},
            coords=self.coords,
        )

    def with_scaled_bw(self, factor: float) -> "Topology":
        """Copy with every link's bandwidth multiplied by ``factor``.

        Preserves heterogeneity (a 2x-faster fabric is still the same mix of
        fast and slow links); the metamorphic contract is that scaling every
        bandwidth up can never *increase* any contention-priced transfer.
        """
        if factor <= 0:
            raise ValueError(f"bandwidth scale factor must be positive, got {factor}")
        return Topology(
            name=f"{self.name}@bwx{factor:g}",
            n_nodes=self.n_nodes,
            links={k: dataclasses.replace(l, bw=l.bw * factor) for k, l in self.links.items()},
            coords=self.coords,
        )

    def without_link(self, *keys: LinkKey) -> "Topology":
        """Copy with the given links removed — a hard link failure.

        The derived instance rebuilds its adjacency and route/k-path caches
        from scratch, so dead links vanish from :meth:`route` *and* from
        every :meth:`k_shortest_paths` candidate list.  Removal may
        disconnect the graph: routes between severed components then raise,
        and :meth:`connected` / :meth:`components` let callers detect the
        partition instead of tripping over it.
        """
        dead = {_key(*k) for k in keys}
        missing = sorted(dead - set(self.links))
        if missing:
            raise KeyError(f"no such links {missing} in topology {self.name!r}")
        return Topology(
            name=f"{self.name}-{len(dead)}link",
            n_nodes=self.n_nodes,
            links={k: l for k, l in self.links.items() if k not in dead},
            coords=self.coords,
        )

    def with_degraded_links(self, factors: Mapping[LinkKey, float]) -> "Topology":
        """Copy with per-link bandwidth multipliers; factor 0 removes a link.

        The chaos layer's combined view of a faulted fabric: hard-failed
        links (factor 0) disappear from routing entirely, degraded links
        (0 < factor < 1) keep routing but price at the reduced bandwidth.
        """
        state = {_key(*k): f for k, f in factors.items()}
        missing = sorted(set(state) - set(self.links))
        if missing:
            raise KeyError(f"no such links {missing} in topology {self.name!r}")
        for k in sorted(state):
            if not (0.0 <= state[k] <= 1.0):
                raise ValueError(f"link factor must be in [0, 1], got {state[k]} for {k}")
        links: dict[LinkKey, Link] = {}
        for k, l in self.links.items():
            f = state.get(k, 1.0)
            if f <= 0.0:
                continue
            links[k] = l if f >= 1.0 else dataclasses.replace(l, bw=l.bw * f)
        return Topology(
            name=f"{self.name}!faults{len(state)}",
            n_nodes=self.n_nodes,
            links=links,
            coords=self.coords,
        )

    # -- connectivity ---------------------------------------------------------

    def components(self) -> tuple[tuple[int, ...], ...]:
        """Connected components as sorted node tuples, ordered by least node."""
        seen: set[int] = set()
        comps: list[tuple[int, ...]] = []
        for start in range(self.n_nodes):
            if start in seen:
                continue
            comp = [start]
            seen.add(start)
            frontier = [start]
            while frontier:
                node = frontier.pop()
                for nxt in self._adj[node]:
                    if nxt not in seen:
                        seen.add(nxt)
                        comp.append(nxt)
                        frontier.append(nxt)
            comps.append(tuple(sorted(comp)))
        return tuple(comps)

    def connected(self, src: int, dst: int) -> bool:
        """Is there any path ``src`` -> ``dst``?  (Cheap; no route built.)"""
        if src == dst:
            return True
        return self._constrained_path(src, dst, frozenset(), frozenset()) is not None


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------


def fully_connected(
    n: int, bw: float = 25e9, latency: float = 100e-9, name: str = "full"
) -> Topology:
    """Every node pair joined directly — the degenerate scalar-link fabric."""
    links = {(i, j): Link(bw, latency) for i in range(n) for j in range(i + 1, n)}
    return Topology(name=name, n_nodes=n, links=links)


def mesh2d(
    rows: int,
    cols: int,
    bw: float = 25e9,
    latency: float = 100e-9,
    *,
    express_bw: float | None = None,
    express_latency: float | None = None,
    express_stride: int = 2,
) -> Topology:
    """``rows x cols`` 2D mesh with XY routing (node = r * cols + c).

    ``express_bw`` adds *express channels* along every row: extra links
    joining nodes ``express_stride`` columns apart (express-cube NoC style),
    with their own bandwidth/latency — per-link heterogeneity inside one
    mesh.  XY dimension-ordered routing walks unit grid steps only, so the
    static route never uses an express link and stays bit-for-bit what it
    was without them; only the adaptive router (and explicit
    :meth:`Topology.k_shortest_paths` callers) can exploit them.
    """
    links: dict[LinkKey, Link] = {}
    coords: dict[int, tuple[int, int]] = {}
    for r in range(rows):
        for c in range(cols):
            n = r * cols + c
            coords[n] = (c, r)
            if c + 1 < cols:
                links[(n, n + 1)] = Link(bw, latency)
            if r + 1 < rows:
                links[(n, n + cols)] = Link(bw, latency)
    name = f"mesh{rows}x{cols}"
    if express_bw is not None:
        if express_stride < 2:
            raise ValueError(f"express stride must be >= 2, got {express_stride}")
        e_lat = express_latency if express_latency is not None else latency
        for r in range(rows):
            for c in range(cols - express_stride):
                n = r * cols + c
                links[(n, n + express_stride)] = Link(express_bw, e_lat)
        name += f"+x{express_stride}"
    return Topology(name=name, n_nodes=rows * cols, links=links, coords=coords)


def ring(
    n: int,
    bw: float = 25e9,
    latency: float = 100e-9,
    *,
    segment_bws: Sequence[float] | None = None,
) -> Topology:
    """Bidirectional ring; routes take the shorter arc (ties: smaller ids).

    ``segment_bws[i]`` overrides the bandwidth of the segment joining node
    ``i`` to node ``(i + 1) % n`` — a ring with one slow segment is the
    smallest fabric where congestion-aware routing pays (the long arc around
    the slow segment can be the cheaper one under load).
    """
    if segment_bws is not None:
        if n < 3:
            raise ValueError(
                f"a {n}-node ring collapses to a single link; "
                "per-segment bandwidths are ambiguous there"
            )
        if len(segment_bws) != n:
            raise ValueError(f"need {n} segment bandwidths, got {len(segment_bws)}")
    links = {
        _key(i, (i + 1) % n): Link(segment_bws[i] if segment_bws is not None else bw, latency)
        for i in range(n)
    }
    return Topology(name=f"ring{n}", n_nodes=n, links=links)


def crossbar(
    n: int,
    bw: float = 25e9,
    latency: float = 100e-9,
    *,
    port_bws: Sequence[float] | None = None,
) -> Topology:
    """A central switch: n ports star-wired to hub node ``n``.

    Every port-to-port route is two hops through the hub (each hub link
    carries half the end-to-end latency), and port links are the contention
    points — concurrent flows into one port fair-share its link, which is
    how a real crossbar's output-port conflicts behave.  ``port_bws[i]``
    overrides port ``i``'s uplink bandwidth: a slow uplink models a chiplet
    hanging off a previous-generation PHY, the heterogeneity §2 of the paper
    puts in the interconnect itself.
    """
    if port_bws is not None and len(port_bws) != n:
        raise ValueError(f"need {n} port bandwidths, got {len(port_bws)}")
    links = {
        (i, n): Link(port_bws[i] if port_bws is not None else bw, latency / 2.0)
        for i in range(n)
    }
    return Topology(name=f"xbar{n}", n_nodes=n + 1, links=links)


def hierarchical(
    n_packages: int,
    chiplets_per_package: int,
    intra_bw: float = 50e9,
    intra_latency: float = 50e-9,
    inter_bw: float = 12.5e9,
    inter_latency: float = 500e-9,
) -> Topology:
    """Packages of chiplets: dense fast links inside a package, one slow
    gateway link between each package pair (chiplet 0 is the gateway)."""
    links: dict[LinkKey, Link] = {}
    cpp = chiplets_per_package
    for p in range(n_packages):
        base = p * cpp
        for i in range(cpp):
            for j in range(i + 1, cpp):
                links[(base + i, base + j)] = Link(intra_bw, intra_latency)
    for p in range(n_packages):
        for q in range(p + 1, n_packages):
            links[(p * cpp, q * cpp)] = Link(inter_bw, inter_latency)
    return Topology(
        name=f"hier{n_packages}x{cpp}",
        n_nodes=n_packages * cpp,
        links=links,
    )
