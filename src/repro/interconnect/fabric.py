"""Contention-priced communication over a chiplet :class:`Topology`.

A :class:`Fabric` binds EP indices to topology nodes and prices transfers
under a *steady-state flow set*: in a pipelined execution every stage
boundary ships activations once per beat, so all boundary transfers (plus
any co-tenant traffic) are concurrently in flight.  Two contention effects
are modeled, both deliberately simple and monotone:

  * **fair-share links** — ``k`` flows routed through one link each get
    ``bw / k`` of it (round-robin arbitration at the router); a flow's
    effective bandwidth is the minimum fair share along its route.  This is
    the graph version of the paper's "shared memory controller" effect
    (§6): co-located traffic slows everyone on the shared resource.
  * **memory-controller hotspots** — when ``mc_bw`` is set, every flow also
    queues at its endpoint nodes' memory controllers: ``k`` flows sourcing
    or sinking at one node share ``mc_bw`` there, so fan-in to a single
    chiplet saturates even over disjoint links.

Transfer time of a flow carrying ``nbytes`` is then

    ``nbytes / eff_bw + sum(link latencies along the route)``

which degenerates to the scalar model (``nbytes / bw + latency``) on a
fully-connected single-hop fabric with no concurrent flows — bit-for-bit,
which is what keeps all pre-fabric results unchanged (see
:func:`scalar_fabric` and the regression tests in
``tests/test_interconnect.py``).  Adding a flow can only increase link and
node loads, so contention is monotone under static routing: no existing
flow ever speeds up.

Routing itself is a decision, not just a price.  With ``routing="static"``
(the default) every flow takes the topology's fixed XY/Dijkstra route and
everything above holds unchanged.  With ``routing="adaptive"`` the fabric
assigns each flow a path from its :meth:`Topology.k_shortest_paths`
candidates to minimize that flow's *contention-priced* cost given where
every other flow currently runs — iterated best response over the whole
flow set, swept in deterministic order with seeded tie-breaks and a bounded
number of sweeps, so the assignment is a pure function of (topology, flow
multiset, seed).  The final assignment is kept only if its total priced
cost is no worse than the all-static assignment (ties keep static), so
adaptive routing can never lose to static on the same flow set — the
invariant the property suite pins.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Mapping, Sequence

from .topology import Link, LinkKey, Topology, fully_connected


@dataclasses.dataclass(frozen=True)
class Flow:
    """One steady-state transfer: ``nbytes`` shipped ``src`` -> ``dst``.

    ``src``/``dst`` are EP indices of the pricing platform by default;
    ``nodes=True`` marks them as raw topology node ids — the form
    cross-tenant background flows take, since a tenant's restricted fabric
    keeps the *global* topology and co-tenant traffic lives outside the
    tenant's own EP index space.
    """

    src: int
    dst: int
    nbytes: float
    nodes: bool = False


@dataclasses.dataclass(eq=False)
class Fabric:
    """A topology plus the EP -> node binding and the contention model.

    ``ep_nodes[i]`` is the router node EP ``i`` sits on.  Restricting a
    fabric to a subset of EPs (:meth:`restrict`) keeps the full topology —
    a dead or foreign chiplet's router still forwards traffic — and only
    narrows the binding, so sub-platform routes are physically identical to
    global ones.
    """

    topology: Topology
    #: EP index -> topology node
    ep_nodes: tuple[int, ...]
    #: per-node memory-controller bandwidth shared by flows that source or
    #: sink at the node.  A float caps every node uniformly; a mapping
    #: (node -> bytes/s) caps per chiplet; the string ``"auto"`` asks
    #: :meth:`~repro.core.platform.Platform.with_fabric` to derive the
    #: per-node caps from each EP's ``mem_bw`` at attach time (until then it
    #: behaves as disabled); ``None`` disables the hotspot model.
    mc_bw: "float | Mapping[int, float] | str | None" = None
    #: ``"static"`` — every flow takes the topology's fixed XY/Dijkstra
    #: route (pre-adaptive behaviour, bit-for-bit).  ``"adaptive"`` — flows
    #: are assigned paths by congestion-priced iterated best response.
    routing: str = "static"
    #: candidate paths per flow the adaptive router chooses among
    k_paths: int = 4
    #: bound on best-response sweeps (reproducibility: the fixed point —
    #: or the sweep bound — is reached in deterministic order)
    max_sweeps: int = 8
    #: tie-break seed: exact cost ties between candidate paths resolve by a
    #: keyed hash of (seed, flow endpoints + size, path), so distinct seeds
    #: explore distinct-but-deterministic equilibria
    seed: int = 0
    #: optional :class:`repro.telemetry.Telemetry` session (duck-typed; the
    #: serving layers attach theirs).  When live, every routing pass records
    #: link loads, the fair-share contention factor, memory-controller
    #: hotspot saturation and — in adaptive mode — the priced
    #: static-vs-adaptive delta.  ``None`` (the default) records nothing
    #: and prices bit-for-bit as before.
    telemetry: "object | None" = dataclasses.field(default=None, repr=False)
    #: live link-fault state: LinkKey -> bandwidth factor (0.0 = link dead,
    #: 0 < f < 1 = degraded).  Healthy links are absent.  The dict is shared
    #: *by reference* across :meth:`restrict` copies, so a fault applied to
    #: the global fabric is instantly visible to every tenant's restricted
    #: view — exactly how a physical link failure behaves.  Empty (the
    #: default) prices bit-for-bit as before faults existed.
    link_state: dict = dataclasses.field(default_factory=dict, repr=False)

    def __post_init__(self):
        self.ep_nodes = tuple(self.ep_nodes)
        for n in self.ep_nodes:
            if not (0 <= n < self.topology.n_nodes):
                raise ValueError(f"EP node {n} outside topology {self.topology.name!r}")
        if self.routing not in ("static", "adaptive"):
            raise ValueError(f"unknown routing mode {self.routing!r}")
        if isinstance(self.mc_bw, str) and self.mc_bw != "auto":
            raise ValueError(f"mc_bw must be a number, mapping, 'auto' or None, got {self.mc_bw!r}")
        if self.k_paths < 1 or self.max_sweeps < 1:
            raise ValueError("need k_paths >= 1 and max_sweeps >= 1")
        #: (fault fingerprint, derived topology) — rebuilt when state changes
        self._eff_cache: tuple[tuple, Topology] = ((), self.topology)

    @property
    def n_eps(self) -> int:
        return len(self.ep_nodes)

    def node(self, ep_idx: int) -> int:
        return self.ep_nodes[ep_idx]

    def restrict(self, kept: Sequence[int]) -> "Fabric":
        """The fabric as seen by a sub-platform holding EPs ``kept``."""
        return dataclasses.replace(
            self, ep_nodes=tuple(self.ep_nodes[i] for i in kept)
        )

    def with_link_latency(self, latency_s: float) -> "Fabric":
        """Every link latency replaced — the Fig. 9 knob on a real fabric."""
        return dataclasses.replace(
            self, topology=self.topology.with_link_latency(latency_s)
        )

    def with_routing(
        self,
        routing: str,
        *,
        k_paths: int | None = None,
        max_sweeps: int | None = None,
        seed: int | None = None,
    ) -> "Fabric":
        """Copy with the routing policy replaced (knobs keep current values)."""
        return dataclasses.replace(
            self,
            routing=routing,
            k_paths=self.k_paths if k_paths is None else k_paths,
            max_sweeps=self.max_sweeps if max_sweeps is None else max_sweeps,
            seed=self.seed if seed is None else seed,
        )

    # -- link faults ----------------------------------------------------------

    def set_link_state(self, u: int, v: int, factor: float) -> None:
        """Set link ``(u, v)``'s bandwidth factor; ``>= 1`` restores it."""
        key = (u, v) if u < v else (v, u)
        if key not in self.topology.links:
            raise KeyError(f"no such link {key} in topology {self.topology.name!r}")
        if factor < 0.0:
            raise ValueError(f"link factor must be >= 0, got {factor}")
        if factor >= 1.0:
            self.link_state.pop(key, None)
        else:
            self.link_state[key] = factor

    def fail_link(self, u: int, v: int) -> None:
        self.set_link_state(u, v, 0.0)

    def degrade_link(self, u: int, v: int, factor: float) -> None:
        if not (0.0 < factor < 1.0):
            raise ValueError(f"degrade factor must be in (0, 1), got {factor}")
        self.set_link_state(u, v, factor)

    def restore_link(self, u: int, v: int) -> None:
        self.set_link_state(u, v, 1.0)

    def fault_fingerprint(self) -> tuple:
        """Canonical view of the current link faults (``()`` when healthy).

        A pure function of the fault *state*, independent of the order the
        faults were applied in — the token drift fingerprints fold in so a
        link change is visible even when EP factors and the dead set are
        untouched.
        """
        return tuple(sorted(self.link_state.items()))

    def _topo(self) -> Topology:
        """The effective topology under the current link faults.

        Identity (``self.topology``, caches and all) while the fault state
        is empty — the degenerate contract.  Faulted states derive a fresh
        topology (dead links removed, degraded links' bandwidth scaled) and
        cache it against the fingerprint, so repeated pricing between fault
        transitions pays the rebuild once.
        """
        if not self.link_state:
            return self.topology
        fp = self.fault_fingerprint()
        cached_fp, cached = self._eff_cache
        if fp != cached_fp:
            cached = self.topology.with_degraded_links(self.link_state)
            self._eff_cache = (fp, cached)
        return cached

    def effective_topology(self) -> Topology:
        """Public view of :meth:`_topo` for pricing callers outside the package."""
        return self._topo()

    def marooned_eps(self) -> tuple[int, ...]:
        """EPs cut off from the main component by dead links.

        The *main* component is the one hosting the most EPs (ties: the one
        containing the smallest node id).  EPs bound to any other component
        cannot exchange activations with the majority of the platform, so
        placement rescues treat them like dead EPs until the link heals.
        """
        topo = self._topo()
        comps = topo.components()
        if len(comps) <= 1:
            return ()
        count = {c: sum(1 for n in self.ep_nodes if n in set(c)) for c in comps}
        main = max(comps, key=lambda c: (count[c], -c[0]))
        main_set = set(main)
        return tuple(
            ep for ep, n in enumerate(self.ep_nodes) if n not in main_set
        )

    # -- routing shortcuts ----------------------------------------------------

    def route_ep(self, src_ep: int, dst_ep: int) -> tuple[LinkKey, ...]:
        return self._topo().route(self.ep_nodes[src_ep], self.ep_nodes[dst_ep])

    def latency_ep(self, src_ep: int, dst_ep: int) -> float:
        """Routed latency between two EPs; ``inf`` when faults severed them."""
        topo = self._topo()
        src, dst = self.ep_nodes[src_ep], self.ep_nodes[dst_ep]
        if self.link_state and not topo.connected(src, dst):
            return float("inf")
        return topo.path_latency(src, dst)

    # -- contention pricing ---------------------------------------------------

    def _endpoints(self, flow: Flow) -> tuple[int, int]:
        if flow.nodes:
            return flow.src, flow.dst
        return self.ep_nodes[flow.src], self.ep_nodes[flow.dst]

    def _mc_cap(self, node: int) -> float | None:
        """Memory-controller bandwidth cap at ``node``, or None (uncapped).

        An unresolved ``"auto"`` (fabric never attached to a platform) is
        treated as disabled — there is no EP spec to derive the cap from.
        """
        if self.mc_bw is None or isinstance(self.mc_bw, str):
            return None
        if isinstance(self.mc_bw, Mapping):
            return self.mc_bw.get(node)
        return self.mc_bw

    @property
    def _mc_enabled(self) -> bool:
        return self.mc_bw is not None and not isinstance(self.mc_bw, str)

    def _loads(
        self,
        pairs: Sequence[tuple[int, int]],
        routes: Sequence[tuple[LinkKey, ...]],
    ) -> tuple[dict[LinkKey, int], dict[int, int]]:
        """(flows per link, flows per capped endpoint node) of a route set."""
        link_load: dict[LinkKey, int] = {}
        node_load: dict[int, int] = {}
        for (s, d), r in zip(pairs, routes):
            if r is None:
                continue  # severed flow: consumes no link or MC capacity
            for k in r:
                link_load[k] = link_load.get(k, 0) + 1
            if r and self._mc_enabled:
                node_load[s] = node_load.get(s, 0) + 1
                node_load[d] = node_load.get(d, 0) + 1
        return link_load, node_load

    def _price(
        self,
        flows: Sequence[Flow],
        pairs: Sequence[tuple[int, int]],
        routes: Sequence["tuple[LinkKey, ...] | None"],
    ) -> list[float]:
        """Fair-share + hotspot pricing of flows on an explicit route set.

        A ``None`` route means link faults severed the flow's endpoints:
        the transfer can never complete, so it prices ``inf`` (the serving
        layer surfaces that as a ``"link-loss"`` drift rather than an
        exception mid-simulation).
        """
        links = self._topo().links
        link_load, node_load = self._loads(pairs, routes)
        times = []
        for f, (s, d), r in zip(flows, pairs, routes):
            if r is None:
                times.append(float("inf"))
                continue
            if not r:
                times.append(0.0)
                continue
            eff = min(links[k].bw / link_load[k] for k in r)
            if self._mc_enabled:
                for node in (s, d):
                    cap = self._mc_cap(node)
                    if cap is not None:
                        eff = min(eff, cap / node_load[node])
            times.append(f.nbytes / eff + sum(links[k].latency for k in r))
        return times

    def flow_times(self, flows: Sequence[Flow]) -> list[float]:
        """Transfer time of each flow under the whole set's contention.

        Deterministic in the multiset of flows; a flow between co-located
        endpoints costs 0 (it never leaves the chiplet).  Under
        ``routing="adaptive"`` each flow is first assigned a path by
        :meth:`route_flows`; under ``"static"`` every flow takes the
        topology's fixed route, exactly as before adaptive routing existed.
        """
        pairs = [self._endpoints(f) for f in flows]
        routes = self.route_flows(flows)
        times = self._price(flows, pairs, routes)
        tl = self.telemetry
        if tl is not None and tl.enabled:
            self._record_pass(tl, flows, pairs, routes, times)
        return times

    def _record_pass(self, tl, flows, pairs, routes, times) -> None:
        """One routing pass into the telemetry registry (live sink only)."""
        tl.counter("fabric.routing_passes").inc()
        tl.counter("fabric.flows_priced").inc(len(flows))
        link_load, node_load = self._loads(pairs, routes)
        if link_load:
            link_bytes: dict[LinkKey, float] = {}
            for f, r in zip(flows, routes):
                for k in r or ():
                    link_bytes[k] = link_bytes.get(k, 0.0) + f.nbytes
            for k in sorted(link_load):
                tl.histogram("fabric.link_flows").observe(link_load[k])
                tl.histogram("fabric.link_bytes").observe(link_bytes[k])
            # fair-share contention factor: worst per-link flow count — 1.0
            # means every link is private, k means someone runs at bw/k
            # scalar max over flow counts: ties are value-identical, so
            # insertion order cannot leak into the observed factor
            tl.histogram("fabric.contention_factor").observe(
                max(link_load.values())  # shisha: allow(unkeyed-sort)
            )
        for node in sorted(node_load):
            cap = self._mc_cap(node)
            if cap is not None:
                # §6 hotspot saturation: flows queued at this node's memory
                # controller (each gets cap/k of it)
                tl.histogram("fabric.mc_node_flows").observe(node_load[node])
        if times:
            tl.histogram("fabric.flow_time_s").observe(max(times))

    def transfer_time(
        self,
        src_ep: int,
        dst_ep: int,
        nbytes: float,
        background: Sequence[Flow] = (),
    ) -> float:
        """Price one transfer given concurrent ``background`` flows."""
        flows = [Flow(src_ep, dst_ep, nbytes)] + list(background)
        return self.flow_times(flows)[0]

    # -- routing --------------------------------------------------------------

    def route_flows(self, flows: Sequence[Flow]) -> list[tuple[LinkKey, ...]]:
        """The per-flow link-sequence assignment the fabric prices under.

        Static mode: every flow takes the topology's fixed route — a pure
        function of (src, dst), independent of the rest of the flow set.
        Adaptive mode: iterated best response over the whole flow set (see
        :meth:`_adaptive_routes`); a pure function of (topology, flow
        multiset, seed), never worse than static in total priced cost.
        """
        pairs = [self._endpoints(f) for f in flows]
        topo = self._topo()
        static: list[tuple[LinkKey, ...] | None] = []
        for (s, d) in pairs:
            if s == d:
                static.append(())
            elif self.link_state and not topo.connected(s, d):
                static.append(None)  # severed by link faults: prices inf
            else:
                static.append(topo.route(s, d))
        if self.routing != "adaptive":
            return static
        return self._adaptive_routes(flows, pairs, static)

    def _tiebreak(
        self, endpoints: tuple[int, int], nbytes: float, route: tuple[LinkKey, ...]
    ) -> int:
        """Seeded, platform-independent tie-break between equal-cost paths.

        Keyed on the flow's *identity* (endpoints + size), not its list
        position, so the choice survives reordering of the flow set.
        """
        key = f"{self.seed}|{endpoints}|{nbytes!r}|{route}".encode()
        return int.from_bytes(hashlib.sha256(key).digest()[:8], "big")

    def _adaptive_routes(
        self,
        flows: Sequence[Flow],
        pairs: Sequence[tuple[int, int]],
        static: Sequence[tuple[LinkKey, ...]],
    ) -> list[tuple[LinkKey, ...]]:
        """Congestion-priced path assignment by iterated best response.

        Starting from the all-static assignment, flows are visited in the
        canonical order of their identity — sorted by (endpoints, nbytes),
        positions only disambiguating exact duplicates, which are mutually
        interchangeable — so the assignment is a function of the flow
        *multiset*, not of the order a caller happened to assemble the list
        in.  Each flow picks, among its candidate paths (the static route
        plus the topology's ``k_paths`` shortest loopless paths — which
        include express/shortcut links XY routing never takes), the path
        minimizing its own contention-priced transfer time given where
        every other flow currently runs.  Sweeps repeat until a fixed point
        or ``max_sweeps``, whichever first; exact cost ties resolve by
        (fewest hops, seeded hash of the flow identity and path), so the
        result is reproducible.  The
        best-response equilibrium of a congestion game need not improve the
        *sum* — so the all-static assignment is kept whenever it prices no
        worse in total, which is what makes adaptive routing safe to leave
        on: it can only ever lower the total priced cost of a flow set.
        """
        from .topology import path_links

        topo = self._topo()
        cands: list[list[tuple[LinkKey, ...]]] = []
        for (s, d), st_route in zip(pairs, static):
            if s == d or st_route is None:
                # co-located (route ()) or severed (route None): nothing for
                # best response to choose among
                cands.append([st_route])
                continue
            seen = {st_route}
            cl = [st_route]
            for path in topo.k_shortest_paths(s, d, self.k_paths):
                r = path_links(path)
                if r not in seen:
                    seen.add(r)
                    cl.append(r)
            cands.append(cl)

        assign = list(static)
        link_load: dict[LinkKey, int] = {}
        node_load: dict[int, int] = {}
        for (s, d), r in zip(pairs, assign):
            if r is None:
                continue
            for k in r:
                link_load[k] = link_load.get(k, 0) + 1
            if r and self._mc_enabled:
                node_load[s] = node_load.get(s, 0) + 1
                node_load[d] = node_load.get(d, 0) + 1

        links = topo.links
        order = sorted(
            range(len(flows)), key=lambda i: (pairs[i], flows[i].nbytes, i)
        )
        for _sweep in range(self.max_sweeps):
            changed = False
            for i in order:
                f = flows[i]
                if len(cands[i]) <= 1:
                    continue
                s, d = pairs[i]
                for k in assign[i]:  # price candidates against the others
                    link_load[k] -= 1
                # endpoint MC load is route-independent (every candidate
                # sources at s and sinks at d), so it is a constant floor
                # under the candidate comparison — but it must be in the
                # cost so "minimize its contention-priced cost" stays true
                mc_floor = None
                if self._mc_enabled:
                    for node in (s, d):
                        cap = self._mc_cap(node)
                        if cap is not None:
                            share = cap / node_load[node]
                            mc_floor = share if mc_floor is None else min(mc_floor, share)

                def priced(route: tuple[LinkKey, ...]) -> float:
                    eff = min(links[k].bw / (link_load.get(k, 0) + 1) for k in route)
                    if mc_floor is not None:
                        eff = min(eff, mc_floor)
                    return f.nbytes / eff + sum(links[k].latency for k in route)

                best = min(
                    cands[i],
                    key=lambda r: (
                        priced(r),
                        len(r),
                        self._tiebreak(pairs[i], f.nbytes, r),
                    ),
                )
                if best != assign[i]:
                    assign[i] = best
                    changed = True
                for k in assign[i]:
                    link_load[k] = link_load.get(k, 0) + 1
            if not changed:
                break

        # never-worse-than-static: a selfish equilibrium may price worse in
        # total than everyone staying on the default path; keep static then
        # (ties keep static, preserving the pre-adaptive assignment exactly)
        adaptive_total = sum(self._price(flows, pairs, assign))
        static_total = sum(self._price(flows, pairs, static))
        tl = self.telemetry
        if tl is not None and tl.enabled:
            # >= 0 by the keep-static rule: how much the adaptive router
            # actually saved over XY/Dijkstra on this flow set
            tl.histogram("fabric.adaptive_delta_s").observe(
                static_total - adaptive_total if adaptive_total < static_total else 0.0
            )
            kind = "improved" if adaptive_total < static_total else "kept_static"
            tl.counter(f"fabric.adaptive.{kind}").inc()
        if adaptive_total < static_total:
            return assign
        return list(static)


# ---------------------------------------------------------------------------
# platform-derived preset
# ---------------------------------------------------------------------------


def scalar_fabric(platform) -> Fabric:
    """The degenerate fabric that reproduces the scalar-link model exactly.

    Every EP pair gets a direct link with ``bw = min`` / ``latency = max``
    of the two EPs' scalar link specs — precisely the expression
    ``core.evaluator`` used before fabrics existed, so a platform with this
    fabric attached prices every transfer bit-for-bit identically to the
    same platform without one (single-hop route, load 1, no hotspot model).
    ``platform`` is duck-typed (anything with ``.eps[i].link_bw`` /
    ``.link_latency``) to keep this package import-free of ``repro.core``.

    ``mc_bw`` stays ``None`` (not ``"auto"``) and routing stays static by
    construction: the degenerate fabric's whole contract is reproducing the
    pre-fabric arithmetic exactly, and both the hotspot cap and adaptive
    path choice would add terms the scalar model never had.
    """
    eps = platform.eps
    links: dict[LinkKey, Link] = {}
    for i in range(len(eps)):
        for j in range(i + 1, len(eps)):
            links[(i, j)] = Link(
                bw=min(eps[i].link_bw, eps[j].link_bw),
                latency=max(eps[i].link_latency, eps[j].link_latency),
            )
    topo = Topology(name=f"{platform.name}-scalar", n_nodes=len(eps), links=links)
    return Fabric(topology=topo, ep_nodes=tuple(range(len(eps))))


def uniform_fabric(
    topology: Topology,
    n_eps: int | None = None,
    mc_bw: "float | Mapping[int, float] | str | None" = "auto",
    *,
    routing: str = "static",
    k_paths: int = 4,
    max_sweeps: int = 8,
    seed: int = 0,
) -> Fabric:
    """Bind EPs 0..n-1 to topology nodes 0..n-1 (the common identity case).

    ``mc_bw`` defaults to ``"auto"``: once the fabric is attached with
    :meth:`~repro.core.platform.Platform.with_fabric`, every node's
    memory-controller cap is derived from its EP's ``mem_bw`` — the hotspot
    model is *on by default* for the gem5-style preset platforms (pass
    ``None`` to disable it explicitly).  Standalone fabrics (never attached)
    have no EP specs to derive from and price as uncapped.
    """
    n = n_eps if n_eps is not None else topology.n_nodes
    if n > topology.n_nodes:
        raise ValueError(f"{n} EPs need at least {n} nodes, topology has {topology.n_nodes}")
    return Fabric(
        topology=topology,
        ep_nodes=tuple(range(n)),
        mc_bw=mc_bw,
        routing=routing,
        k_paths=k_paths,
        max_sweeps=max_sweeps,
        seed=seed,
    )
