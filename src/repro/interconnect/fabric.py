"""Contention-priced communication over a chiplet :class:`Topology`.

A :class:`Fabric` binds EP indices to topology nodes and prices transfers
under a *steady-state flow set*: in a pipelined execution every stage
boundary ships activations once per beat, so all boundary transfers (plus
any co-tenant traffic) are concurrently in flight.  Two contention effects
are modeled, both deliberately simple and monotone:

  * **fair-share links** — ``k`` flows routed through one link each get
    ``bw / k`` of it (round-robin arbitration at the router); a flow's
    effective bandwidth is the minimum fair share along its route.  This is
    the graph version of the paper's "shared memory controller" effect
    (§6): co-located traffic slows everyone on the shared resource.
  * **memory-controller hotspots** — when ``mc_bw`` is set, every flow also
    queues at its endpoint nodes' memory controllers: ``k`` flows sourcing
    or sinking at one node share ``mc_bw`` there, so fan-in to a single
    chiplet saturates even over disjoint links.

Transfer time of a flow carrying ``nbytes`` is then

    ``nbytes / eff_bw + sum(link latencies along the route)``

which degenerates to the scalar model (``nbytes / bw + latency``) on a
fully-connected single-hop fabric with no concurrent flows — bit-for-bit,
which is what keeps all pre-fabric results unchanged (see
:func:`scalar_fabric` and the regression tests in
``tests/test_interconnect.py``).  Adding a flow can only increase link and
node loads, so contention is monotone: no existing flow ever speeds up.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .topology import Link, LinkKey, Topology, fully_connected


@dataclasses.dataclass(frozen=True)
class Flow:
    """One steady-state transfer: ``nbytes`` shipped ``src`` -> ``dst``.

    ``src``/``dst`` are EP indices of the pricing platform by default;
    ``nodes=True`` marks them as raw topology node ids — the form
    cross-tenant background flows take, since a tenant's restricted fabric
    keeps the *global* topology and co-tenant traffic lives outside the
    tenant's own EP index space.
    """

    src: int
    dst: int
    nbytes: float
    nodes: bool = False


@dataclasses.dataclass(eq=False)
class Fabric:
    """A topology plus the EP -> node binding and the contention model.

    ``ep_nodes[i]`` is the router node EP ``i`` sits on.  Restricting a
    fabric to a subset of EPs (:meth:`restrict`) keeps the full topology —
    a dead or foreign chiplet's router still forwards traffic — and only
    narrows the binding, so sub-platform routes are physically identical to
    global ones.
    """

    topology: Topology
    #: EP index -> topology node
    ep_nodes: tuple[int, ...]
    #: per-node memory-controller bandwidth shared by flows that source or
    #: sink at the node; None disables the hotspot model
    mc_bw: float | None = None

    def __post_init__(self):
        self.ep_nodes = tuple(self.ep_nodes)
        for n in self.ep_nodes:
            if not (0 <= n < self.topology.n_nodes):
                raise ValueError(f"EP node {n} outside topology {self.topology.name!r}")

    @property
    def n_eps(self) -> int:
        return len(self.ep_nodes)

    def node(self, ep_idx: int) -> int:
        return self.ep_nodes[ep_idx]

    def restrict(self, kept: Sequence[int]) -> "Fabric":
        """The fabric as seen by a sub-platform holding EPs ``kept``."""
        return Fabric(
            topology=self.topology,
            ep_nodes=tuple(self.ep_nodes[i] for i in kept),
            mc_bw=self.mc_bw,
        )

    def with_link_latency(self, latency_s: float) -> "Fabric":
        """Every link latency replaced — the Fig. 9 knob on a real fabric."""
        return Fabric(
            topology=self.topology.with_link_latency(latency_s),
            ep_nodes=self.ep_nodes,
            mc_bw=self.mc_bw,
        )

    # -- routing shortcuts ----------------------------------------------------

    def route_ep(self, src_ep: int, dst_ep: int) -> tuple[LinkKey, ...]:
        return self.topology.route(self.ep_nodes[src_ep], self.ep_nodes[dst_ep])

    def latency_ep(self, src_ep: int, dst_ep: int) -> float:
        return self.topology.path_latency(self.ep_nodes[src_ep], self.ep_nodes[dst_ep])

    # -- contention pricing ---------------------------------------------------

    def _endpoints(self, flow: Flow) -> tuple[int, int]:
        if flow.nodes:
            return flow.src, flow.dst
        return self.ep_nodes[flow.src], self.ep_nodes[flow.dst]

    def flow_times(self, flows: Sequence[Flow]) -> list[float]:
        """Transfer time of each flow under the whole set's contention.

        Deterministic in the multiset of flows; a flow between co-located
        endpoints costs 0 (it never leaves the chiplet).
        """
        pairs = [self._endpoints(f) for f in flows]
        routes = [
            self.topology.route(s, d) if s != d else () for (s, d) in pairs
        ]
        link_load: dict[LinkKey, int] = {}
        node_load: dict[int, int] = {}
        for (s, d), r in zip(pairs, routes):
            for k in r:
                link_load[k] = link_load.get(k, 0) + 1
            if r and self.mc_bw is not None:
                node_load[s] = node_load.get(s, 0) + 1
                node_load[d] = node_load.get(d, 0) + 1
        times = []
        for f, (s, d), r in zip(flows, pairs, routes):
            if not r:
                times.append(0.0)
                continue
            eff = min(self.topology.links[k].bw / link_load[k] for k in r)
            if self.mc_bw is not None:
                eff = min(eff, self.mc_bw / node_load[s], self.mc_bw / node_load[d])
            times.append(f.nbytes / eff + sum(self.topology.links[k].latency for k in r))
        return times

    def transfer_time(
        self,
        src_ep: int,
        dst_ep: int,
        nbytes: float,
        background: Sequence[Flow] = (),
    ) -> float:
        """Price one transfer given concurrent ``background`` flows."""
        flows = [Flow(src_ep, dst_ep, nbytes)] + list(background)
        return self.flow_times(flows)[0]


# ---------------------------------------------------------------------------
# platform-derived preset
# ---------------------------------------------------------------------------


def scalar_fabric(platform) -> Fabric:
    """The degenerate fabric that reproduces the scalar-link model exactly.

    Every EP pair gets a direct link with ``bw = min`` / ``latency = max``
    of the two EPs' scalar link specs — precisely the expression
    ``core.evaluator`` used before fabrics existed, so a platform with this
    fabric attached prices every transfer bit-for-bit identically to the
    same platform without one (single-hop route, load 1, no hotspot model).
    ``platform`` is duck-typed (anything with ``.eps[i].link_bw`` /
    ``.link_latency``) to keep this package import-free of ``repro.core``.
    """
    eps = platform.eps
    links: dict[LinkKey, Link] = {}
    for i in range(len(eps)):
        for j in range(i + 1, len(eps)):
            links[(i, j)] = Link(
                bw=min(eps[i].link_bw, eps[j].link_bw),
                latency=max(eps[i].link_latency, eps[j].link_latency),
            )
    topo = Topology(name=f"{platform.name}-scalar", n_nodes=len(eps), links=links)
    return Fabric(topology=topo, ep_nodes=tuple(range(len(eps))))


def uniform_fabric(
    topology: Topology, n_eps: int | None = None, mc_bw: float | None = None
) -> Fabric:
    """Bind EPs 0..n-1 to topology nodes 0..n-1 (the common identity case)."""
    n = n_eps if n_eps is not None else topology.n_nodes
    if n > topology.n_nodes:
        raise ValueError(f"{n} EPs need at least {n} nodes, topology has {topology.n_nodes}")
    return Fabric(topology=topology, ep_nodes=tuple(range(n)), mc_bw=mc_bw)
