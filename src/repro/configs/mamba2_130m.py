"""mamba2-130m [arXiv:2405.21060] — pure SSD (state-space duality) stack.

24L d_model=768 (attention-free) vocab=50280, ssm_state=128.
expand=2 -> d_inner=1536, 24 SSD heads of dim 64.
"""

from ..models.lm_common import LMConfig

CONFIG = LMConfig(
    name="mamba2-130m",
    n_layers=24,
    d_model=768,
    n_heads=12,  # unused (attention-free); kept for cost-model symmetry
    n_kv_heads=12,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_chunk=64,  # impl knob: keeps [.., cl, cl] decay panels VMEM/HBM-friendly
    block_kind="ssd",
)

SMOKE = LMConfig(
    name="mamba2-smoke",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=128,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=8,
    block_kind="ssd",
    remat="none",
)
