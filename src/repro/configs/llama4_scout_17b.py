"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1 +
one shared expert (llama4 routing).  Early-fusion multimodality is out of
scope — text backbone only (DESIGN.md §4).
"""

from ..models.lm_common import LMConfig

CONFIG = LMConfig(
    name="llama4-scout-17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
)

SMOKE = LMConfig(
    name="llama4-scout-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab=128,
    n_experts=4,
    top_k=1,
    n_shared_experts=1,
    remat="none",
)
