"""whisper-small [arXiv:2212.04356] — encoder-decoder, conv frontend stubbed.

12L encoder + 12L decoder, d_model=768 12H (MHA kv=12) d_ff=3072
vocab=51865.  The conv/mel frontend is a STUB per the assignment:
``input_specs()`` provides 1500 precomputed frame embeddings.  Decoder
self-attention context is capped at 448 positions (Whisper spec), so
decode cells run a 448-slot ring cache with cross-attention over the
1500-frame encoder output.
"""

from ..models.lm_common import LMConfig

CONFIG = LMConfig(
    name="whisper-small",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    enc_layers=12,
    enc_frames=1500,
    max_decoder_len=448,
)

SMOKE = LMConfig(
    name="whisper-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=128,
    enc_layers=2,
    enc_frames=16,
    max_decoder_len=32,
    remat="none",
)
