"""zamba2-2.7b [arXiv:2411.15242] — hybrid Mamba2 backbone + shared attention.

54L d_model=2560 32H (MHA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
The shared attention+MLP block (one parameter set) is applied after every
6th Mamba2 layer.  At long context (``long_500k``) the shared block runs a
4096-token sliding window (documented deviation, DESIGN.md §4), which is
what makes the 500k decode cell sub-quadratic end-to-end.
"""

from ..models.lm_common import LMConfig

CONFIG = LMConfig(
    name="zamba2-2.7b",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    block_kind="hybrid",
    shared_attn_every=6,
)

SMOKE = LMConfig(
    name="zamba2-smoke",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=128,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=8,
    block_kind="hybrid",
    shared_attn_every=2,
    remat="none",
)
