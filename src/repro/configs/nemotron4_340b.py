"""nemotron-4-340b [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000, squared-ReLU FFN.
"""

from ..models.lm_common import LMConfig

CONFIG = LMConfig(
    name="nemotron-4-340b",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256000,
    ffn_kind="relu2",
)

SMOKE = LMConfig(
    name="nemotron-4-smoke",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab=128,
    ffn_kind="relu2",
    remat="none",
)
