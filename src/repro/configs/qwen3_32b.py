"""qwen3-32b [hf:Qwen/Qwen3-32B family].

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, qk_norm,
explicit head_dim=128 (q_dim 8192 != d_model, per the HF config).
"""

from ..models.lm_common import LMConfig

CONFIG = LMConfig(
    name="qwen3-32b",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
)

SMOKE = LMConfig(
    name="qwen3-smoke",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab=128,
    head_dim=32,
    qk_norm=True,
    remat="none",
)
