"""internvl2-76b [arXiv:2404.16821] — InternViT frontend + LLM backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  Per the
assignment the ViT frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (n_patches=256) that are projected and
prepended to the token sequence.
"""

from ..models.lm_common import LMConfig

CONFIG = LMConfig(
    name="internvl2-76b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    n_patches=256,
)

SMOKE = LMConfig(
    name="internvl2-smoke",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab=128,
    n_patches=8,
    remat="none",
)
