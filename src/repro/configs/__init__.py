"""Architecture & shape registry: ``--arch <id> --shape <cell>``.

10 assigned architectures × 4 input-shape cells = 40 dry-run cells.
``applicable()`` encodes the per-family skips mandated by the assignment
(``long_500k`` needs sub-quadratic attention; enc-dec decode runs against
its capped decoder context).  Skips are reported — never silently dropped.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any

from ..models.lm_common import LMConfig

_MODULES = {
    "phi3.5-moe-42b": "phi35_moe_42b",
    "llama4-scout-17b": "llama4_scout_17b",
    "nemotron-4-340b": "nemotron4_340b",
    "qwen2-0.5b": "qwen2_0_5b",
    "qwen3-32b": "qwen3_32b",
    "granite-3-2b": "granite3_2b",
    "zamba2-2.7b": "zamba2_2_7b",
    "internvl2-76b": "internvl2_76b",
    "mamba2-130m": "mamba2_130m",
    "whisper-small": "whisper_small",
}

ARCHS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    phase: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def get_config(arch: str) -> LMConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f".{_MODULES[arch]}", __package__).CONFIG


def get_smoke(arch: str) -> LMConfig:
    return importlib.import_module(f".{_MODULES[arch]}", __package__).SMOKE


def applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runs?, reason).  Encodes the assignment's skip rules."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    if shape == "long_500k":
        if cfg.block_kind in ("ssd", "hybrid"):
            return True, "sub-quadratic (SSM state / hybrid sliding-window)"
        return False, "pure full-attention arch: 500k decode is quadratic — skipped per assignment"
    if cell.phase == "decode" and cfg.is_encdec:
        # runs, but against the whisper-capped decoder context
        return True, f"decoder self-attn context capped at {cfg.max_decoder_len} (whisper spec); cross-KV over {cfg.enc_frames} frames"
    return True, ""


def for_shape(cfg: LMConfig, shape: str) -> LMConfig:
    """Shape-conditional config tweaks (documented deviations only)."""
    if shape == "long_500k" and cfg.block_kind == "hybrid":
        # zamba2's shared attention runs a sliding window at long context
        return dataclasses.replace(cfg, sliding_window=4_096)
    return cfg


def cells(include_skips: bool = False):
    """Iterate (arch, shape, runs, reason) over all 40 cells."""
    for arch in ARCHS:
        for shape in SHAPES:
            runs, reason = applicable(arch, shape)
            if runs or include_skips:
                yield arch, shape, runs, reason
