from .fault import ElasticScheduler, StragglerMitigator, TrainSupervisor

__all__ = ["ElasticScheduler", "StragglerMitigator", "TrainSupervisor"]
