"""Fault tolerance & elasticity — Shisha doubles as the runtime scheduler.

This is the "first-class feature" integration (DESIGN.md §5): the paper's
online tuner is not just an offline experiment, it is the mechanism the
runtime uses to respond to the two failure modes a 1000-node job actually
sees:

  * **Stragglers** — a stage's EP slows down (thermals, a sick host, a
    shared-link neighbour).  :class:`StragglerMitigator` watches measured
    stage times; when the max/median imbalance crosses a threshold it
    derates the offending EP in the platform model and warm-starts
    Algorithm 2 *from the current configuration* (no re-seed — the current
    conf is by construction near-optimal for the old derates, which is
    exactly the warm-start Alg. 2 wants).

  * **Node loss / elastic rescale** — an EP disappears (or arrives).
    :class:`ElasticScheduler` rebuilds the platform, re-runs Algorithm 1's
    seed on the surviving EPs, and tunes from there; together with the
    step-addressed checkpoint store and counter-based data pipeline this
    gives deterministic resume on the new topology.

  * **Step-level faults** — :class:`TrainSupervisor` wraps a train loop
    with checkpoint/restore (async saves every ``save_every``), NaN-loss
    quarantine (skip + re-restore), and restart bookkeeping.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from ..core.config import PipelineConfig
from ..core.evaluator import Trace
from ..core.platform import Platform
from ..core.seed import generate_seed
from ..core.tuner import TuneResult, tune
from ..checkpoint.store import CheckpointStore


# ---------------------------------------------------------------------------
# Straggler mitigation (paper Alg. 2 as the runtime rebalancer)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StragglerMitigator:
    platform: Platform
    conf: PipelineConfig
    make_trace: Callable[[Platform], Trace]
    imbalance_threshold: float = 1.5
    alpha: int = 10

    def check(self, measured_stage_times: Sequence[float]) -> tuple[bool, int | None]:
        """(should_rebalance, straggler_stage)."""
        t = np.asarray(measured_stage_times, float)
        med = float(np.median(t))
        worst = int(np.argmax(t))
        if med <= 0:
            return False, None
        return bool(t[worst] / med > self.imbalance_threshold), worst

    def derate_factor(self, measured_stage_times: Sequence[float], stage: int) -> float:
        t = np.asarray(measured_stage_times, float)
        med = float(np.median(t))
        return float(t[stage] / max(med, 1e-12))

    def rebalance(self, measured_stage_times: Sequence[float]) -> tuple[PipelineConfig, TuneResult] | None:
        """Detect a straggler, derate its EP, warm-start Alg. 2."""
        hit, stage = self.check(measured_stage_times)
        if not hit:
            return None
        ep_idx = self.conf.eps[stage]
        factor = self.derate_factor(measured_stage_times, stage)
        import dataclasses as dc

        eps = list(self.platform.eps)
        ep = eps[ep_idx]
        eps[ep_idx] = dc.replace(
            ep,
            flops_per_core=ep.flops_per_core / factor,
            mem_bw=ep.mem_bw / factor,
            perf_class=ep.perf_class + 1,  # demote: no longer a "fast" EP
        )
        derated = dc.replace(self.platform, name=f"{self.platform.name}*", eps=tuple(eps))
        trace = self.make_trace(derated)
        result = tune(self.conf, trace, alpha=self.alpha)  # warm start from current conf
        self.platform = derated
        self.conf = result.best_conf
        return result.best_conf, result


# ---------------------------------------------------------------------------
# Elastic rescale (node loss / arrival)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ElasticScheduler:
    platform: Platform
    weights: Sequence[float]
    make_trace: Callable[[Platform], Trace]
    alpha: int = 10

    def on_topology_change(self, dead_eps: Sequence[int] = (), n_stages: int | None = None):
        """Re-seed (Alg. 1) + tune (Alg. 2) on the surviving EPs."""
        if len(set(dead_eps)) >= self.platform.n_eps:
            raise RuntimeError("no EPs left")
        platform = self.platform.without(dead_eps) if dead_eps else self.platform
        trace = self.make_trace(platform)
        seed = generate_seed(self.weights, platform, n_stages=n_stages, choice="rank_w")
        result = tune(seed, trace, alpha=self.alpha)
        self.platform = platform
        return result.best_conf, result


# ---------------------------------------------------------------------------
# Step-level supervision
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainSupervisor:
    """Checkpointed train loop with NaN quarantine and crash resume."""

    store: CheckpointStore
    save_every: int = 50
    max_restores: int = 3

    def run(
        self,
        state: dict,
        step_fn: Callable[[dict, int], tuple[dict, float]],
        n_steps: int,
        start_step: int = 0,
    ) -> tuple[dict, list[float]]:
        losses: list[float] = []
        restores = 0
        step = start_step
        last_good = start_step
        while step < n_steps:
            state_new, loss = step_fn(state, step)
            if not math.isfinite(loss):
                if restores >= self.max_restores:
                    raise RuntimeError(f"NaN loss at step {step}, restores exhausted")
                restored = self.store.restore_latest(state)
                if restored is None:
                    raise RuntimeError(f"NaN loss at step {step}, no checkpoint to restore")
                last_good, state = restored
                step = last_good
                restores += 1
                continue
            state = state_new
            losses.append(float(loss))
            step += 1
            if step % self.save_every == 0 or step == n_steps:
                self.store.save(step, state, async_=True)
                last_good = step
        self.store.wait()
        return state, losses
