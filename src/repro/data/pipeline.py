"""Deterministic, restart-safe synthetic data pipeline.

Every batch is a pure function of (seed, step) — ``jax.random.fold_in`` of
the pipeline seed with the step counter — so a job restarted from a step-N
checkpoint regenerates exactly the batches N, N+1, ... it would have seen
(the determinism contract checkpoint/restore relies on; tested in
tests/test_data.py).  Batches are produced host-side in numpy and sharded
onto the mesh with ``jax.device_put`` against the batch sharding, which is
the same code path a real tokenized-shard loader would use.

The synthetic stream is a mixture of Zipf-distributed tokens with injected
copy spans, so the LM loss actually decreases during the end-to-end
training example (pure uniform noise would pin loss at log V).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

import jax

from ..models.lm_common import LMConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq: int
    vocab: int
    seed: int = 0
    zipf_a: float = 1.3
    copy_span: int = 8


@dataclasses.dataclass
class SyntheticLMData:
    cfg: DataConfig

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(np.uint32([c.seed, step]))
        # Zipf body, clipped into vocab
        toks = rng.zipf(c.zipf_a, size=(c.batch, c.seq + 1)).astype(np.int64)
        toks = (toks - 1) % c.vocab
        # copy spans: predictable structure for the loss to latch onto
        for b in range(c.batch):
            start = rng.integers(0, max(c.seq - 2 * c.copy_span, 1))
            src = toks[b, start : start + c.copy_span]
            toks[b, start + c.copy_span : start + 2 * c.copy_span] = src
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


def make_batch_iterator(
    model_cfg: LMConfig,
    data_cfg: DataConfig,
    start_step: int = 0,
    shardings=None,
) -> Iterator[dict]:
    """Yields device-put batches from ``start_step`` on (restart-safe)."""
    ds = SyntheticLMData(data_cfg)
    rng = np.random.default_rng(data_cfg.seed + 17)
    step = start_step
    while True:
        batch = dict(ds.batch_at(step))
        if model_cfg.is_encdec:
            r = np.random.default_rng(np.uint32([data_cfg.seed, step, 2]))
            batch["frames"] = r.standard_normal(
                (data_cfg.batch, model_cfg.enc_frames, model_cfg.d_model), dtype=np.float32
            )
        if model_cfg.n_patches:
            r = np.random.default_rng(np.uint32([data_cfg.seed, step, 3]))
            batch["patch_embeds"] = r.standard_normal(
                (data_cfg.batch, model_cfg.n_patches, model_cfg.d_model), dtype=np.float32
            )
        if shardings is not None:
            batch = {
                k: jax.device_put(v, shardings[k]) if k in shardings else jax.device_put(v)
                for k, v in batch.items()
            }
        yield batch
        step += 1
