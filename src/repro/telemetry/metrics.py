"""Metrics registry: counters, gauges and exact-quantile histograms.

Everything here is deliberately zero-dependency and deterministic: a metric
is plain Python state fed by the simulation layers, so two seeded runs that
dispatch the same events record byte-identical snapshots.  Histograms keep
*every* observation and answer quantile queries with the same nearest-rank
arithmetic the serving simulator's latency percentiles use
(:func:`repro.serve.simulator.percentile`) — exact, not sketched, because
the quantities observed live on the simulated clock where an approximation
would be an unforced loss of reproducibility.

Naming convention (informal, enforced only by the callers): dotted paths
namespaced by layer — ``serve.<lane>.latency_s``, ``fabric.contention_factor``,
``tune.trial_cost_s``, ``cosim.repartitions`` — with units suffixed where a
unit exists (``_s`` seconds, ``_rps`` requests/second, ``_bytes``).
"""

from __future__ import annotations

import math


class Counter:
    """Monotonically increasing count (events, trials, SLO misses)."""

    __slots__ = ("name", "value")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def snapshot(self) -> dict:
        return {"kind": self.kind, "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value (queue depth, active flows)."""

    __slots__ = ("name", "value")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = math.nan

    def set(self, v: float) -> None:
        self.value = v

    def snapshot(self) -> dict:
        return {"kind": self.kind, "value": self.value}


class Histogram:
    """Exact-quantile histogram over every recorded observation.

    Observations are kept verbatim (the simulation horizons here are
    bounded, and exactness is the point); quantiles are nearest-rank on the
    sorted multiset, matching the simulator's latency percentiles.
    """

    __slots__ = ("name", "_values", "_is_sorted", "_sum")
    kind = "histogram"

    def __init__(self, name: str):
        self.name = name
        self._values: list[float] = []
        self._is_sorted = True
        self._sum = 0.0

    def observe(self, v: float) -> None:
        if self._values and v < self._values[-1]:
            self._is_sorted = False
        self._values.append(v)
        self._sum += v

    @property
    def count(self) -> int:
        return len(self._values)

    @property
    def sum(self) -> float:
        return self._sum

    def _sorted(self) -> list[float]:
        if not self._is_sorted:
            self._values.sort()
            self._is_sorted = True
        return self._values

    def quantile(self, q: float) -> float:
        """Exact nearest-rank quantile of everything observed, q in [0, 1]."""
        vals = self._sorted()
        if not vals:
            return math.nan
        idx = max(0, math.ceil(q * len(vals)) - 1)
        return vals[idx]

    def snapshot(self) -> dict:
        vals = self._sorted()
        if not vals:
            return {"kind": self.kind, "count": 0}
        return {
            "kind": self.kind,
            "count": len(vals),
            "sum": self._sum,
            "min": vals[0],
            "max": vals[-1],
            "mean": self._sum / len(vals),
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Get-or-create home for every metric of one telemetry session.

    Asking for an existing name returns the same object; asking for it as a
    different kind is an error (a name means one thing per session).
    Snapshots iterate names in sorted order, so serialized registries are
    deterministic regardless of creation order.
    """

    def __init__(self):
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, not {cls.kind}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def __len__(self) -> int:
        return len(self._metrics)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """name -> metric snapshot, names sorted (deterministic)."""
        return {name: self._metrics[name].snapshot() for name in self.names()}
