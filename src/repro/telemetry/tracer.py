"""Structured span/event timeline with JSONL and Chrome-trace export.

Every event carries a *simulated* timestamp (seconds on the discrete-event
clock) plus two routing labels: ``pid`` — the process lane the event belongs
to (a tenant, ``"fabric"``, ``"cosim"``) — and ``tid`` — the track within it
(an EP name, a link, ``"requests"``, ``"retune"``).  Exported artifacts
never contain wall-clock time, so two seeded runs export byte-identical
traces.

Two export formats:

  * **JSONL** — one compact, key-sorted JSON object per event, in record
    order.  The grep-friendly form.
  * **Chrome trace-event JSON** — the ``{"traceEvents": [...]}`` format
    Perfetto (https://ui.perfetto.dev) and ``chrome://tracing`` load
    directly.  String pids/tids are mapped to stable small integers in
    first-seen order, with ``process_name``/``thread_name`` metadata events
    emitted first, so tenants render as processes and EPs/links as named
    tracks.  Timestamps are exported in microseconds, spans as complete
    (``"ph": "X"``) events, instants as ``"ph": "i"``, counter samples
    (:meth:`SpanTracer.counter` — e.g. per-chiplet temperature) as
    ``"ph": "C"``, which Perfetto renders as a value track per counter
    name.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path


@dataclasses.dataclass(slots=True)
class TraceEvent:
    """One span (``dur`` set), instant (``dur`` None) or counter sample
    (``ph == "C"``) on the timeline.

    Slotted: serving hot paths construct one of these per batch and per
    completed request (they append straight to ``SpanTracer.events`` — see
    ``ServingSimulator._bind_metrics``), so construction cost is part of
    the instrumented/bare overhead ratio the selfbench floor test pins."""

    ts: float  # simulated seconds
    name: str
    cat: str
    pid: str
    tid: str
    dur: float | None = None
    args: dict | None = None
    #: explicit Chrome phase override; only ``"C"`` (counter) is used —
    #: spans and instants keep inferring their phase from ``dur``
    ph: str | None = None


class SpanTracer:
    """Append-only event log; recording order is the export order."""

    def __init__(self):
        self.events: list[TraceEvent] = []

    def span(
        self,
        name: str,
        ts: float,
        dur: float,
        *,
        cat: str = "span",
        pid: str = "sim",
        tid: str = "main",
        args: dict | None = None,
    ) -> None:
        self.events.append(TraceEvent(ts, name, cat, pid, tid, dur, args))

    def instant(
        self,
        name: str,
        ts: float,
        *,
        cat: str = "event",
        pid: str = "sim",
        tid: str = "main",
        args: dict | None = None,
    ) -> None:
        self.events.append(TraceEvent(ts, name, cat, pid, tid, None, args))

    def counter(
        self,
        name: str,
        ts: float,
        value: float,
        *,
        cat: str = "counter",
        pid: str = "sim",
        tid: str = "counters",
    ) -> None:
        """One sample of a numeric track (Chrome ``"C"`` phase).

        Perfetto groups samples by (pid, name) into a stairstep value
        track — how per-chiplet temperatures and package watts render
        alongside the request spans.
        """
        self.events.append(
            TraceEvent(ts, name, cat, pid, tid, None, {"value": value}, "C")
        )

    def __len__(self) -> int:
        return len(self.events)

    # -- exports ------------------------------------------------------------

    def to_jsonl(self) -> str:
        """One key-sorted JSON object per line, record order."""
        lines = []
        for e in self.events:
            row = {
                "ts": e.ts,
                "name": e.name,
                "cat": e.cat,
                "pid": e.pid,
                "tid": e.tid,
            }
            if e.dur is not None:
                row["dur"] = e.dur
            if e.ph is not None:
                row["ph"] = e.ph
            if e.args:
                row["args"] = e.args
            lines.append(json.dumps(row, sort_keys=True, separators=(",", ":")))
        return "\n".join(lines) + ("\n" if lines else "")

    def to_chrome(self) -> dict:
        """The trace as a Chrome trace-event JSON object (Perfetto-loadable)."""
        pids: dict[str, int] = {}
        tids: dict[tuple[str, str], int] = {}
        tid_counts: dict[str, int] = {}
        meta: list[dict] = []
        body: list[dict] = []

        def pid_of(label: str) -> int:
            p = pids.get(label)
            if p is None:
                p = pids[label] = len(pids) + 1
                meta.append(
                    {
                        "name": "process_name",
                        "ph": "M",
                        "pid": p,
                        "tid": 0,
                        "args": {"name": label},
                    }
                )
            return p

        def tid_of(pid_label: str, tid_label: str) -> int:
            key = (pid_label, tid_label)
            t = tids.get(key)
            if t is None:
                t = tids[key] = tid_counts.get(pid_label, 0) + 1
                tid_counts[pid_label] = t
                meta.append(
                    {
                        "name": "thread_name",
                        "ph": "M",
                        "pid": pid_of(pid_label),
                        "tid": t,
                        "args": {"name": tid_label},
                    }
                )
            return t

        for e in self.events:
            row = {
                "name": e.name,
                "cat": e.cat,
                "pid": pid_of(e.pid),
                "tid": tid_of(e.pid, e.tid),
                "ts": round(e.ts * 1e6, 3),
            }
            if e.ph is not None:
                row["ph"] = e.ph
            elif e.dur is None:
                row["ph"] = "i"
                row["s"] = "t"
            else:
                row["ph"] = "X"
                row["dur"] = round(e.dur * 1e6, 3)
            if e.args:
                row["args"] = e.args
            body.append(row)
        return {
            "traceEvents": meta + body,
            "displayTimeUnit": "ms",
            "otherData": {"clock": "simulated"},
        }

    def write_jsonl(self, path) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_jsonl())
        return p

    def write_chrome(self, path) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_chrome(), sort_keys=True, indent=1))
        return p
