"""The :class:`Telemetry` facade the simulation layers record into.

One ``Telemetry`` object is one observation session: a
:class:`~repro.telemetry.metrics.MetricsRegistry`, a
:class:`~repro.telemetry.tracer.SpanTracer` and a wall-clock profile for
the :meth:`Telemetry.timed` hooks, shared by every layer of a run (serving
lanes, co-simulator, tuner traces, fabric).  Pass it to
``ServingSimulator(..., telemetry=...)`` / ``co_serve(..., telemetry=...)``
and export afterwards.

Observability is **off by default**: constructors take ``telemetry=None``
and the instrumented hot paths reduce to a single ``is not None`` check, so
un-instrumented runs stay bit-for-bit what they were.  :class:`NullTelemetry`
(exported as :data:`NULL`) is the explicit no-op sink for callers that want
an object rather than ``None`` — it accepts every call, records nothing,
and reports ``enabled = False``, which the constructors normalize to the
same disabled fast path.

Clock discipline: everything *exported* (metrics observations, span/instant
timestamps) lives on the simulated clock, so seeded runs export
byte-identical artifacts.  The only wall-clock state is :attr:`Telemetry
.profile`, fed by ``timed()`` scopes around real hot loops; it exists for
``benchmarks/selfbench.py`` (simulated-events/sec) and is deliberately kept
out of every trace/JSONL export.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from .metrics import MetricsRegistry
from .tracer import SpanTracer


class _Timer:
    """Accumulates perf_counter wall time into a profile slot."""

    __slots__ = ("_profile", "_scope", "_t0")

    def __init__(self, profile: dict, scope: str):
        self._profile = profile
        self._scope = scope

    def __enter__(self):
        # the one sanctioned wall-clock instrument: timed() profiles real
        # elapsed time by design and its readings are never exported into
        # seeded artifacts (see profile_snapshot)
        self._t0 = time.perf_counter()  # shisha: allow(wall-clock)
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0  # shisha: allow(wall-clock)
        slot = self._profile.get(self._scope)
        if slot is None:
            self._profile[self._scope] = [1, dt]
        else:
            slot[0] += 1
            slot[1] += dt
        return False


class Telemetry:
    """Live observation session: metrics + spans + wall-clock profile."""

    #: constructors treat a telemetry object with ``enabled = False`` (see
    #: :class:`NullTelemetry`) exactly like ``None``
    enabled = True

    def __init__(self):
        self.registry = MetricsRegistry()
        self.tracer = SpanTracer()
        #: scope -> [calls, wall seconds]; wall-clock by design, never exported
        self.profile: dict[str, list] = {}
        #: current simulated time, maintained by the event loop that owns
        #: this session (convenience for recorders without a timestamp)
        self.now = 0.0

    # -- metrics ------------------------------------------------------------

    def counter(self, name: str):
        return self.registry.counter(name)

    def gauge(self, name: str):
        return self.registry.gauge(name)

    def histogram(self, name: str):
        return self.registry.histogram(name)

    # -- tracing ------------------------------------------------------------

    def span(self, name: str, ts: float, dur: float, **kw) -> None:
        self.tracer.span(name, ts, dur, **kw)

    def instant(self, name: str, ts: float, **kw) -> None:
        self.tracer.instant(name, ts, **kw)

    def counter_track(self, name: str, ts: float, value: float, **kw) -> None:
        """One sample of a Perfetto counter track (temperature, watts)."""
        self.tracer.counter(name, ts, value, **kw)

    # -- profiling hooks -----------------------------------------------------

    def timed(self, scope: str) -> _Timer:
        """``with telemetry.timed("event_loop.run"): ...`` — wall profiling."""
        return _Timer(self.profile, scope)

    def profile_snapshot(self) -> dict:
        """scope -> {calls, wall_s}, sorted; for benchmark payloads only."""
        return {
            scope: {"calls": calls, "wall_s": wall}
            for scope, (calls, wall) in sorted(self.profile.items())
        }

    # -- exports (simulated-clock artifacts only) ----------------------------

    def metrics_snapshot(self) -> dict:
        return self.registry.snapshot()

    def export_jsonl(self, path=None) -> str:
        """The timeline as JSONL; optionally written to ``path``."""
        text = self.tracer.to_jsonl()
        if path is not None:
            p = Path(path)
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(text)
        return text

    def export_chrome_trace(self, path=None) -> dict:
        """The timeline as Chrome trace-event JSON (Perfetto-loadable)."""
        trace = self.tracer.to_chrome()
        if path is not None:
            p = Path(path)
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(json.dumps(trace, sort_keys=True, indent=1))
        return trace


class _NullMetric:
    """Accepts any record call, keeps nothing."""

    __slots__ = ()

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass


class _NullTimer:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_METRIC = _NullMetric()
_NULL_TIMER = _NullTimer()


class NullTelemetry(Telemetry):
    """The no-op sink: same interface, records nothing, ``enabled = False``.

    Instrumented constructors normalize it to their ``None`` fast path, so
    passing ``NULL`` costs exactly what passing nothing costs — the contract
    ``tests/test_telemetry.py`` pins (bit-identical summaries, empty sink).
    """

    enabled = False

    def counter(self, name: str):
        return _NULL_METRIC

    def gauge(self, name: str):
        return _NULL_METRIC

    def histogram(self, name: str):
        return _NULL_METRIC

    def span(self, name: str, ts: float, dur: float, **kw) -> None:
        pass

    def instant(self, name: str, ts: float, **kw) -> None:
        pass

    def counter_track(self, name: str, ts: float, value: float, **kw) -> None:
        pass

    def timed(self, scope: str) -> _NullTimer:
        return _NULL_TIMER


#: shared no-op sink; safe to pass anywhere a Telemetry is accepted
NULL = NullTelemetry()


def live(telemetry: "Telemetry | None") -> "Telemetry | None":
    """Normalize to the hot-path sentinel: a live session or ``None``.

    Instrumented constructors call this once so their per-event guard is a
    single ``is not None`` check (``NULL`` and ``None`` both disable).
    """
    return telemetry if telemetry is not None and telemetry.enabled else None
