"""``repro.telemetry`` — cross-layer observability for the co-simulation stack.

Three parts, all zero-dependency and deterministic under seeded runs:

  * :mod:`.metrics` — a registry of counters, gauges and **exact-quantile**
    histograms on the simulated clock: the serving simulator feeds it
    per-event (queue depths, batch occupancy, SLO hits/misses), the fabric
    per-routing-pass (link loads, fair-share contention factor, hotspot
    saturation, adaptive-vs-static price deltas), the tuner per-trial
    (move kinds, beat deltas, charged wall cost).
  * :mod:`.tracer` — a structured span/event timeline (request lifecycles,
    re-tune exploration windows, repartition/revival decisions, per-window
    fabric flow injections) exportable as JSONL and as Chrome trace-event
    JSON loadable in Perfetto — tenants as processes, EPs/links as tracks.
  * :mod:`.core` — the :class:`Telemetry` facade tying both to the
    wall-clock :meth:`~repro.telemetry.core.Telemetry.timed` profiling hooks
    that ``benchmarks/selfbench.py`` turns into a simulated-events/sec
    trajectory (``BENCH_selfbench.json``).

Everything is **off by default**: every instrumented constructor accepts
``telemetry=None`` (or the explicit no-op :data:`NULL` sink) and the hot
paths then reduce to one ``is not None`` check, keeping un-instrumented
results bit-for-bit identical to the pre-telemetry stack.  Exported
artifacts contain only simulated timestamps, never wall time, so two seeded
runs export byte-identical traces.
"""

from .core import NULL, NullTelemetry, Telemetry, live
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .tracer import SpanTracer, TraceEvent

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL",
    "NullTelemetry",
    "SpanTracer",
    "Telemetry",
    "TraceEvent",
    "live",
]
