"""Command-line entry point: ``python -m repro.analysis [paths...]``."""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Sequence

from .framework import run
from .report import render_json, render_rules, render_text


def main(argv: Sequence[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=(
            "shisha-lint: AST-based determinism, layering, and "
            "simulation-contract checker"
        ),
    )
    parser.add_argument(
        "paths", nargs="*", default=["src"],
        help="files or directories to lint (default: src)",
    )
    parser.add_argument(
        "--format", choices=("text", "json"), default="text",
        help="report format (default: text)",
    )
    parser.add_argument(
        "--report-only", action="store_true",
        help="always exit 0: report findings without gating",
    )
    parser.add_argument(
        "--strict", action="store_true",
        help="warnings also fail the gate",
    )
    parser.add_argument(
        "--output", metavar="FILE",
        help="also write the report to FILE",
    )
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the rule registry and exit",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        print(render_rules())
        return 0

    missing = [p for p in args.paths if not Path(p).exists()]
    if missing:
        print(f"error: no such path(s): {', '.join(missing)}", file=sys.stderr)
        return 2

    report = run(args.paths)
    text = render_json(report) if args.format == "json" else render_text(report)
    print(text)
    if args.output:
        Path(args.output).parent.mkdir(parents=True, exist_ok=True)
        Path(args.output).write_text(text + "\n", encoding="utf-8")
    return report.exit_code(report_only=args.report_only, strict=args.strict)
