"""shisha-lint: AST-based determinism, layering, and simulation-contract
checker for this repository.

Usage: ``python -m repro.analysis src/ benchmarks/ examples/`` lints every
``.py`` file under the given roots and exits non-zero on any error-severity
finding (``--report-only`` downgrades the gate, ``--strict`` upgrades
warnings, ``--format=json`` emits a machine-readable report for CI
artifacts, ``--list-rules`` prints the registry).  The suite is pure
stdlib — no third-party imports, enforced on itself by the
``import-layering`` rule — so the CI lint gate runs before any dependency
install.  Rules guard the contracts the simulation stack's bit-for-bit
reproducibility rests on: no wall-clock reads or unseeded RNGs on
simulated paths, no iteration-order tie-breaks (sets, unkeyed dict-view
ordering, ``id()`` keys, float accumulation over unordered iterables), no
unguarded duck-typed telemetry handles, no events scheduled behind the
loop clock, and the core/interconnect/telemetry layering DAG.  Intentional
exceptions are annotated in place with ``# shisha: allow(<rule>)``; a
pragma that stops suppressing anything becomes a ``useless-suppression``
error, so the pragma inventory can never go stale.  The rule ↔ contract
table lives in ROADMAP.md under ``## Static analysis``.
"""

from .framework import (
    RULES,
    Finding,
    FileContext,
    ProgramRule,
    Report,
    Rule,
    lint_source,
    register,
    run,
)
from . import layering as _layering  # noqa: F401  (registers import-layering)
from . import rules as _rules  # noqa: F401  (registers the AST rules)
from .report import render_json, render_rules, render_text

__all__ = [
    "RULES",
    "FileContext",
    "Finding",
    "ProgramRule",
    "Report",
    "Rule",
    "lint_source",
    "register",
    "render_json",
    "render_rules",
    "render_text",
    "run",
]
