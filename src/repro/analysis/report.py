"""Text and JSON rendering for shisha-lint reports."""

from __future__ import annotations

import json

from .framework import RULES, Report

TOOL = "shisha-lint"
VERSION = "1.0"


def render_text(report: Report) -> str:
    lines = [f.format() for f in report.findings]
    n_err, n_warn = len(report.errors), len(report.warnings)
    summary = (
        f"{TOOL}: {report.n_files} files, {n_err} error(s), "
        f"{n_warn} warning(s), {len(report.suppressed)} suppressed"
    )
    return "\n".join(lines + [summary])


def render_json(report: Report) -> str:
    payload = {
        "tool": TOOL,
        "version": VERSION,
        "roots": list(report.roots),
        "files": report.n_files,
        "summary": {
            "errors": len(report.errors),
            "warnings": len(report.warnings),
            "suppressed": len(report.suppressed),
        },
        "findings": [f.to_json() for f in report.findings],
        "suppressed": [f.to_json() for f in report.suppressed],
    }
    return json.dumps(payload, indent=2, sort_keys=True)


def render_rules() -> str:
    """The registry as a table (``--list-rules``)."""
    rows = [
        (name, rule.severity, rule.description)
        for name, rule in sorted(RULES.items())
    ]
    width = max(len(r[0]) for r in rows)
    return "\n".join(
        f"{name:<{width}}  {sev:<7}  {desc}" for name, sev, desc in rows
    )
