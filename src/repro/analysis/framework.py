"""Rule framework for shisha-lint.

The linter is deliberately zero-dependency (stdlib ``ast`` only) so it can
run as a CI gate before any third-party package is importable.  Two rule
shapes exist:

* :class:`Rule` — per-file AST rules.  ``check(ctx)`` yields findings for
  one parsed file; rules never see the filesystem.
* :class:`ProgramRule` — whole-program rules (the import-graph layering
  checker).  ``check_program(ctxs)`` sees every scanned file at once.

Findings carry a rule name, severity, and location; suppression is via
``# shisha: allow(<rule>[, <rule>...])`` pragmas, either trailing on the
offending line or on a comment line directly above it.  Two framework
checks keep the pragma set honest: an unknown rule name in a pragma is a
``bad-pragma`` error, and a pragma that suppresses nothing is a
``useless-suppression`` error — so every pragma in a clean tree is
load-bearing by construction (deleting one re-surfaces a real finding).
"""

from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Iterable, Iterator, Sequence

SEVERITY_ERROR = "error"
SEVERITY_WARNING = "warning"

#: framework-level pseudo-rules (not in the registry, never suppressible)
BAD_PRAGMA = "bad-pragma"
USELESS_SUPPRESSION = "useless-suppression"
PARSE_ERROR = "parse-error"

_PRAGMA_RE = re.compile(r"#\s*shisha:\s*allow\(\s*([^)]*?)\s*\)")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One lint finding, ordered by location for stable reports."""

    path: str
    line: int
    col: int
    rule: str
    severity: str
    message: str

    def format(self) -> str:
        return (
            f"{self.path}:{self.line}:{self.col}: "
            f"{self.severity}: [{self.rule}] {self.message}"
        )

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Pragma:
    """One ``# shisha: allow(...)`` comment."""

    line: int  # line the pragma comment sits on
    applies_to: tuple[int, ...]  # finding lines it suppresses
    rules: tuple[str, ...]


@dataclasses.dataclass
class FileContext:
    """One parsed source file, as seen by per-file rules."""

    path: Path  # real filesystem path
    display: str  # path as reported in findings
    module: str  # dotted module name ("repro.core.seed", "fixture_mod")
    is_package: bool  # True for __init__.py
    source: str
    tree: ast.Module
    pragmas: tuple[Pragma, ...]

    @property
    def top_package(self) -> str:
        """Top sub-package under ``repro`` ("core", "serve", ...) or ""."""
        parts = self.module.split(".")
        if parts[0] == "repro" and len(parts) > 1:
            return parts[1]
        return ""


class Rule:
    """Per-file AST rule.  Subclasses set ``name``/``severity`` and yield
    findings from :meth:`check`."""

    name: str = ""
    severity: str = SEVERITY_ERROR
    description: str = ""

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, ctx: FileContext, node: ast.AST | int, message: str) -> Finding:
        if isinstance(node, int):
            line, col = node, 0
        else:
            line, col = node.lineno, node.col_offset
        return Finding(ctx.display, line, col, self.name, self.severity, message)


class ProgramRule(Rule):
    """Whole-program rule: sees every scanned file at once."""

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        return iter(())

    def check_program(self, ctxs: Sequence[FileContext]) -> Iterator[Finding]:
        raise NotImplementedError


#: rule-name -> rule instance; populated by :func:`register`
RULES: dict[str, Rule] = {}


def register(cls: type[Rule]) -> type[Rule]:
    """Class decorator adding a rule (by its ``name``) to the registry."""
    rule = cls()
    if not rule.name:
        raise ValueError(f"rule {cls.__name__} has no name")
    if rule.name in RULES:
        raise ValueError(f"duplicate rule name {rule.name!r}")
    RULES[rule.name] = rule
    return cls


def parse_pragmas(source: str) -> tuple[Pragma, ...]:
    """Extract ``# shisha: allow(...)`` pragmas from *comment tokens*.

    Tokenizing (rather than regexing raw lines) keeps pragma **mentions**
    inside docstrings — like the ones in this package — from counting as
    live pragmas.  A pragma trailing code applies to its own line; a
    pragma on a comment-only line applies to the next line (and its own,
    so a finding reported *at* the comment is also covered).
    """
    out = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError):
        return ()
    code_lines = {
        t.start[0]
        for t in tokens
        if t.type
        not in (tokenize.COMMENT, tokenize.NL, tokenize.NEWLINE, tokenize.INDENT,
                tokenize.DEDENT, tokenize.ENDMARKER)
    }
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _PRAGMA_RE.search(tok.string)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        i = tok.start[0]
        applies = (i,) if i in code_lines else (i, i + 1)
        out.append(Pragma(line=i, applies_to=applies, rules=rules))
    return tuple(out)


def _module_name(file: Path, root: Path) -> tuple[str, bool]:
    """Dotted module name for ``file`` relative to scan root.

    If a ``repro`` path component exists, the name is rooted there, so a
    fixture tree like ``fixtures/layering_bad/repro/telemetry/x.py`` lints
    as module ``repro.telemetry.x`` and the layering contracts apply.
    """
    try:
        rel = file.resolve().relative_to(root.resolve())
    except ValueError:
        rel = Path(file.name)
    parts = list(rel.with_suffix("").parts)
    # a scan root that is itself a package keeps its name in the module
    # path ("benchmarks/run.py" lints as benchmarks.run, not run), so
    # package-scoped allowlists and layering contracts still apply
    anchor = root
    while (anchor / "__init__.py").exists():
        parts.insert(0, anchor.name)
        anchor = anchor.parent
    if "repro" in parts:
        parts = parts[parts.index("repro") :]
    is_pkg = parts[-1] == "__init__"
    if is_pkg:
        parts = parts[:-1] or [root.name]
    return ".".join(parts), is_pkg


def collect_files(paths: Sequence[str | Path]) -> tuple[list[FileContext], list[Finding]]:
    """Parse every ``.py`` under the given files/directories.

    Returns (contexts, parse_errors); unparseable files become
    ``parse-error`` findings rather than crashing the run.
    """
    ctxs: list[FileContext] = []
    errors: list[Finding] = []
    seen: set[Path] = set()
    for raw in paths:
        p = Path(raw)
        root = p.parent if p.is_file() else p
        files = [p] if p.is_file() else sorted(p.rglob("*.py"))
        for f in files:
            rp = f.resolve()
            if rp in seen or "__pycache__" in f.parts:
                continue
            seen.add(rp)
            display = str(f)
            source = f.read_text(encoding="utf-8")
            module, is_pkg = _module_name(f, root)
            try:
                tree = ast.parse(source, filename=display)
            except SyntaxError as e:
                errors.append(
                    Finding(
                        display, e.lineno or 1, (e.offset or 1) - 1,
                        PARSE_ERROR, SEVERITY_ERROR, f"syntax error: {e.msg}",
                    )
                )
                continue
            ctxs.append(
                FileContext(
                    path=f,
                    display=display,
                    module=module,
                    is_package=is_pkg,
                    source=source,
                    tree=tree,
                    pragmas=parse_pragmas(source),
                )
            )
    return ctxs, errors


def source_context(
    source: str, display: str = "<memory>", module: str = "_memory_"
) -> FileContext:
    """A FileContext for an in-memory snippet (tests, pragma-strip checks)."""
    return FileContext(
        path=Path(display),
        display=display,
        module=module,
        is_package=False,
        source=source,
        tree=ast.parse(source, filename=display),
        pragmas=parse_pragmas(source),
    )


@dataclasses.dataclass
class Report:
    """Outcome of one lint run."""

    findings: list[Finding]  # unsuppressed, sorted by location
    suppressed: list[Finding]
    n_files: int
    roots: tuple[str, ...] = ()

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == SEVERITY_ERROR]

    @property
    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == SEVERITY_WARNING]

    def exit_code(self, *, report_only: bool = False, strict: bool = False) -> int:
        if report_only:
            return 0
        if self.errors or (strict and self.warnings):
            return 1
        return 0


def _apply_suppressions(
    ctx: FileContext, findings: Iterable[Finding], known_rules: set[str]
) -> tuple[list[Finding], list[Finding], list[Finding]]:
    """Split ``findings`` into (kept, suppressed) and emit pragma hygiene
    findings (bad-pragma / useless-suppression) for this file."""
    allow: dict[int, set[str]] = {}
    for pr in ctx.pragmas:
        for line in pr.applies_to:
            allow.setdefault(line, set()).update(pr.rules)
    kept, suppressed = [], []
    used_lines: set[int] = set()
    for f in findings:
        if f.rule in allow.get(f.line, ()):
            suppressed.append(f)
            used_lines.add(f.line)
        else:
            kept.append(f)
    hygiene: list[Finding] = []
    for pr in ctx.pragmas:
        unknown = [r for r in pr.rules if r not in known_rules]
        if unknown:
            hygiene.append(
                Finding(
                    ctx.display, pr.line, 0, BAD_PRAGMA, SEVERITY_ERROR,
                    f"unknown rule name(s) in pragma: {', '.join(unknown)}",
                )
            )
        elif not any(line in used_lines for line in pr.applies_to):
            hygiene.append(
                Finding(
                    ctx.display, pr.line, 0, USELESS_SUPPRESSION, SEVERITY_ERROR,
                    f"pragma suppresses nothing (rules: {', '.join(pr.rules)}); "
                    "delete it or move it to the offending line",
                )
            )
    return kept, suppressed, hygiene


def run(
    paths: Sequence[str | Path], rules: Sequence[Rule] | None = None
) -> Report:
    """Lint files/directories with the given rules (default: full registry)."""
    ctxs, parse_errors = collect_files(paths)
    return _run_contexts(ctxs, rules, parse_errors, roots=tuple(str(p) for p in paths))


def lint_source(
    source: str,
    display: str = "<memory>",
    module: str = "_memory_",
    rules: Sequence[Rule] | None = None,
) -> Report:
    """Lint one in-memory snippet (per-file rules plus pragma hygiene).

    Program rules see the snippet as a one-file program, so layering
    contracts still apply when ``module`` names a ``repro.*`` module.
    """
    ctx = source_context(source, display, module)
    return _run_contexts([ctx], rules, [], roots=(display,))


def _run_contexts(
    ctxs: Sequence[FileContext],
    rules: Sequence[Rule] | None,
    parse_errors: list[Finding],
    roots: tuple[str, ...],
) -> Report:
    active = list(rules) if rules is not None else list(RULES.values())
    known = {r.name for r in active} | {r.name for r in RULES.values()}
    per_file: dict[str, list[Finding]] = {c.display: [] for c in ctxs}
    file_rules = [r for r in active if not isinstance(r, ProgramRule)]
    program_rules = [r for r in active if isinstance(r, ProgramRule)]
    for ctx in ctxs:
        for rule in file_rules:
            per_file[ctx.display].extend(rule.check(ctx))
    for rule in program_rules:
        for f in rule.check_program(ctxs):
            per_file.setdefault(f.path, []).append(f)
    kept_all: list[Finding] = list(parse_errors)
    suppressed_all: list[Finding] = []
    by_display = {c.display: c for c in ctxs}
    for display, found in per_file.items():
        ctx = by_display.get(display)
        if ctx is None:
            kept_all.extend(found)
            continue
        kept, suppressed, hygiene = _apply_suppressions(ctx, found, known)
        kept_all.extend(kept)
        kept_all.extend(hygiene)
        suppressed_all.extend(suppressed)
    return Report(
        findings=sorted(kept_all),
        suppressed=sorted(suppressed_all),
        n_files=len(ctxs),
        roots=roots,
    )
