"""Per-file AST rules for shisha-lint.

Each rule guards one repo contract (see the rule ↔ contract table in
ROADMAP.md ``## Static analysis``).  Rules are pattern checkers, not type
inference: they flag the shapes that have actually bitten simulated-path
code (wall-clock reads, unseeded RNGs, iteration-order tie-breaks), and
intentional exceptions carry a ``# shisha: allow(<rule>)`` pragma so the
exception is visible at the call site.
"""

from __future__ import annotations

import ast
from typing import Iterator

from .framework import (
    SEVERITY_WARNING,
    FileContext,
    Finding,
    Rule,
    register,
)


class ImportMap:
    """Local alias -> dotted origin, from a file's import statements.

    ``import numpy as np`` maps ``np -> numpy``; ``from time import
    perf_counter as pc`` maps ``pc -> time.perf_counter``.  Nested (lazy)
    imports are included: the rules here care about what a name *means*,
    not when it binds.
    """

    def __init__(self, tree: ast.Module):
        self.aliases: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.level == 0 and node.module:
                for a in node.names:
                    self.aliases[a.asname or a.name] = f"{node.module}.{a.name}"

    def resolve(self, node: ast.expr) -> str | None:
        """Dotted origin of an expression like ``np.random.rand`` or
        ``pc`` — None when the base name is not an import alias."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = self.aliases.get(node.id)
        if base is None:
            return None
        return ".".join([base] + list(reversed(parts)))


def _is_set_expr(node: ast.expr) -> bool:
    """Set literal, set comprehension, or a set()/frozenset() call."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id in ("set", "frozenset")
    )


def _keyword(call: ast.Call, name: str) -> ast.keyword | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw
    return None


def _dict_view_call(node: ast.expr) -> str | None:
    """"items"/"values" when ``node`` is a no-arg ``<expr>.items()`` etc."""
    if (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr in ("items", "values", "keys")
        and not node.args
        and not node.keywords
    ):
        return node.func.attr
    return None


@register
class WallClockRule(Rule):
    """Simulated paths must never read real time.

    The serving simulator, tuner traces, fabric pricing, and telemetry
    exports all advance on the *simulated* clock; a stray
    ``time.time()`` makes seeded reruns diverge and un-pins every BENCH
    artifact.  Real-hardware paths (``launch/``, ``pipeline/runtime.py``,
    ``benchmarks/``) are allowlisted; ``telemetry.timed`` is the one
    sanctioned wall-clock instrument and carries explicit pragmas.
    """

    name = "wall-clock"
    description = "wall-clock read on a simulated path"

    WALL_TIME_FNS = {
        "time", "time_ns", "perf_counter", "perf_counter_ns",
        "monotonic", "monotonic_ns", "process_time", "process_time_ns",
        "clock_gettime",
    }
    DATETIME_FNS = {"now", "utcnow", "today"}
    #: module prefixes where wall-clock reads are the point
    ALLOW_MODULES = ("repro.launch", "repro.pipeline.runtime", "benchmarks")
    #: path shapes for the same allowlist (benchmarks/ is a namespace
    #: package, so its module names carry no package prefix)
    ALLOW_DIRS = ("launch", "benchmarks")

    def _allowlisted(self, ctx: FileContext) -> bool:
        if any(
            ctx.module == m or ctx.module.startswith(m + ".")
            for m in self.ALLOW_MODULES
        ):
            return True
        posix = ctx.path.as_posix()
        return any(d in ctx.path.parts for d in self.ALLOW_DIRS) or posix.endswith(
            "pipeline/runtime.py"
        )

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if self._allowlisted(ctx):
            return
        imports = ImportMap(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            origin = imports.resolve(node.func)
            if origin is None:
                continue
            if origin.startswith("time.") and origin.split(".", 1)[1] in self.WALL_TIME_FNS:
                yield self.finding(
                    ctx, node,
                    f"{origin}() reads the wall clock on a simulated path; "
                    "use the simulated clock (or telemetry.timed for profiling)",
                )
            elif (
                origin.startswith("datetime.")
                and origin.split(".")[-1] in self.DATETIME_FNS
            ):
                yield self.finding(
                    ctx, node,
                    f"{origin}() reads the wall clock on a simulated path",
                )


@register
class UnseededRandomRule(Rule):
    """All randomness must flow from an explicit seed.

    The global ``random`` module and the legacy ``numpy.random.*``
    function API draw from hidden process-global state; one call makes a
    "seeded" rerun irreproducible.  Use ``random.Random(seed)`` or
    ``numpy.random.default_rng(seed)`` / ``Generator(PCG64(seed))``.
    """

    name = "unseeded-random"
    description = "global / legacy RNG API instead of a seeded generator"

    ALLOWED_RANDOM = {"Random", "SystemRandom"}
    ALLOWED_NUMPY = {
        "default_rng", "Generator", "BitGenerator", "SeedSequence",
        "PCG64", "PCG64DXSM", "MT19937", "Philox", "SFC64",
    }

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        imports = ImportMap(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            origin = imports.resolve(node.func)
            if origin is None:
                continue
            if origin.startswith("random.") and "." not in origin[len("random.") :]:
                fn = origin.split(".", 1)[1]
                if fn not in self.ALLOWED_RANDOM:
                    yield self.finding(
                        ctx, node,
                        f"{origin}() uses the process-global RNG; "
                        "construct random.Random(seed) instead",
                    )
            elif origin.startswith("numpy.random.") or origin.startswith("np.random."):
                fn = origin.split(".")[-1]
                if fn not in self.ALLOWED_NUMPY:
                    yield self.finding(
                        ctx, node,
                        f"legacy numpy.random.{fn}() draws from global state; "
                        "use numpy.random.default_rng(seed)",
                    )


@register
class SetIterationRule(Rule):
    """Never iterate a set where order can matter.

    Set iteration order depends on insertion history and hash
    randomization of the element types; a ``for`` over a set feeding any
    stateful work is an iteration-order tie-break waiting to happen.
    Sort first (``for x in sorted(s)``) or keep a list.
    """

    name = "set-iteration"
    description = "for-loop / comprehension over an unordered set"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.For, ast.AsyncFor)) and _is_set_expr(node.iter):
                yield self.finding(
                    ctx, node.iter,
                    "iterating a set: order is not deterministic across "
                    "processes; wrap in sorted(...) or keep a list",
                )
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp, ast.DictComp)):
                for gen in node.generators:
                    if _is_set_expr(gen.iter):
                        yield self.finding(
                            ctx, gen.iter,
                            "comprehension over a set: order is not "
                            "deterministic; wrap in sorted(...)",
                        )


@register
class UnkeyedSortRule(Rule):
    """Ordering decisions over dict views need a pinned total order.

    ``min``/``max``/``sorted`` over ``d.values()`` (or over ``d.items()``
    with a ``key=``) resolve ties by dict insertion order — which is
    whatever order the caller happened to build the dict in.  Pin the
    tie-break with an explicit total-order key, or annotate scalar
    aggregations (where ties are value-identical) with a pragma.
    """

    name = "unkeyed-sort"
    description = "min/max/sorted over a dict view with insertion-order ties"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id in ("min", "max", "sorted")
                and node.args
            ):
                continue
            view = _dict_view_call(node.args[0])
            if view is None:
                continue
            kw = _keyword(node, "key")
            has_key = kw is not None and not self._key_includes_dict_key(kw.value)
            if view == "values":
                yield self.finding(
                    ctx, node,
                    f"{node.func.id}() over dict .values(): ties resolve by "
                    "insertion order; aggregate order-insensitively or sort "
                    "items with a total key",
                )
            elif view in ("items", "keys") and has_key:
                yield self.finding(
                    ctx, node,
                    f"{node.func.id}(..., key=...) over dict .{view}(): "
                    "equal keys fall back to insertion order; fold the "
                    "unique dict key into the sort key",
                )

    @staticmethod
    def _key_includes_dict_key(key: ast.expr) -> bool:
        """True when the sort key folds in the element's unique dict key
        (``lambda kv: (..., kv[0], ...)``), making the order total."""
        if not (isinstance(key, ast.Lambda) and key.args.args):
            return False
        arg = key.args.args[0].arg
        for node in ast.walk(key.body):
            if (
                isinstance(node, ast.Subscript)
                and isinstance(node.value, ast.Name)
                and node.value.id == arg
                and isinstance(node.slice, ast.Constant)
                and node.slice.value == 0
            ):
                return True
        return False


@register
class TelemetryGuardRule(Rule):
    """Duck-typed telemetry handles must be guarded before use.

    Core/interconnect stay import-free of ``repro.telemetry``, so their
    ``telemetry`` fields are plain ``object | None``.  The contract: bind
    to a local, check ``is not None`` (after ``live()`` normalization),
    then call — one branch on the hot path, and no AttributeError when a
    caller passes the NULL sink or nothing at all.
    """

    name = "telemetry-guard"
    severity = SEVERITY_WARNING
    description = "telemetry handle used without a live()/None guard"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if ctx.module.startswith("repro.telemetry"):
            return  # the sink itself is concrete, not duck-typed
        for fn in ast.walk(ctx.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_function(ctx, fn)

    def _check_function(self, ctx, fn) -> Iterator[Finding]:
        handles: set[str] = set()
        guard_lines: dict[str, int] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt = node.targets[0]
                if isinstance(tgt, ast.Name) and self._is_handle_expr(node.value):
                    handles.add(tgt.id)
            if isinstance(node, (ast.If, ast.IfExp, ast.While, ast.Assert)):
                for name in self._guarded_names(node.test):
                    line = guard_lines.get(name)
                    guard_lines[name] = min(line, node.lineno) if line else node.lineno
        for node in ast.walk(fn):
            # direct chained use: self.telemetry.counter(...) — never OK,
            # it skips both the local bind and the guard
            if (
                isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Attribute)
                and node.value.attr == "telemetry"
            ):
                yield self.finding(
                    ctx, node,
                    "chained use of a duck-typed .telemetry field; bind it "
                    "to a local and guard with `is not None` first",
                )
            # local-handle use before any guard on that name
            elif (
                isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id in handles
            ):
                guard = guard_lines.get(node.value.id)
                if guard is None or guard > node.lineno:
                    yield self.finding(
                        ctx, node,
                        f"telemetry handle `{node.value.id}` used without a "
                        "preceding `is not None` guard",
                    )

    @staticmethod
    def _is_handle_expr(value: ast.expr) -> bool:
        if isinstance(value, ast.Attribute) and value.attr == "telemetry":
            return True
        return (
            isinstance(value, ast.Call)
            and isinstance(value.func, ast.Name)
            and value.func.id == "live"
        )

    @staticmethod
    def _guarded_names(test: ast.expr) -> Iterator[str]:
        for node in ast.walk(test):
            if isinstance(node, ast.Name):
                yield node.id


@register
class IdOrderingRule(Rule):
    """``id()`` is not an ordering.

    Object addresses vary run to run, so any ``id()``-based comparison or
    sort key is nondeterministic by construction.  Use an explicit index,
    name, or dataclass ordering instead.
    """

    name = "id-ordering"
    description = "id()-based ordering or comparison"

    ORDER_FNS = {"sorted", "min", "max", "nsmallest", "nlargest"}
    CMP_OPS = (ast.Lt, ast.LtE, ast.Gt, ast.GtE)

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                fname = None
                if isinstance(node.func, ast.Name):
                    fname = node.func.id
                elif isinstance(node.func, ast.Attribute):
                    fname = node.func.attr
                if fname in self.ORDER_FNS or fname == "sort":
                    kw = _keyword(node, "key")
                    if kw is not None and self._mentions_id(kw.value):
                        yield self.finding(
                            ctx, node,
                            "sort key built from id(): object addresses are "
                            "not stable across runs",
                        )
                if fname in ("heappush", "heappushpop") and any(
                    self._mentions_id(a) for a in node.args
                ):
                    yield self.finding(
                        ctx, node,
                        "heap entry ordered by id(): addresses are not a "
                        "stable total order; use a sequence number",
                    )
            elif isinstance(node, ast.Compare) and any(
                isinstance(op, self.CMP_OPS) for op in node.ops
            ):
                if any(
                    self._is_id_call(e) for e in [node.left] + list(node.comparators)
                ):
                    yield self.finding(
                        ctx, node,
                        "ordered comparison of id() values is nondeterministic",
                    )

    @staticmethod
    def _is_id_call(node: ast.expr) -> bool:
        return (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "id"
        )

    @classmethod
    def _mentions_id(cls, node: ast.expr) -> bool:
        if isinstance(node, ast.Name) and node.id == "id":
            return True
        return any(cls._is_id_call(n) for n in ast.walk(node))


@register
class FloatAccumRule(Rule):
    """Float accumulation over an unordered iterable is order-dependent.

    fp addition is not associative: ``sum({a, b, c})`` can differ in the
    last ulp between runs when set order shifts, breaking bit-for-bit
    rerun checks.  Sort first, or use ``math.fsum`` (correctly rounded,
    order-insensitive).
    """

    name = "float-accum"
    description = "sum() over a set — fp result depends on iteration order"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "sum"
                and node.args
            ):
                continue
            arg = node.args[0]
            if _is_set_expr(arg):
                yield self.finding(
                    ctx, node,
                    "sum() over a set: float addition is order-dependent; "
                    "sum(sorted(s)) or math.fsum",
                )
            elif isinstance(arg, ast.GeneratorExp) and any(
                _is_set_expr(g.iter) for g in arg.generators
            ):
                yield self.finding(
                    ctx, node,
                    "sum() of a generator over a set: float addition is "
                    "order-dependent; iterate sorted(...)",
                )


@register
class EventPastRule(Rule):
    """Events must never be scheduled behind the loop clock.

    ``EventLoop`` dispatches in (time, kind, push-order) order; pushing
    an event at ``t - dt`` from a handler running at ``t`` silently
    reorders the timeline (the event fires immediately but *after*
    everything already queued at earlier times was dropped).  Pattern:
    a ``.push(...)`` / ``._push(...)`` call site whose time argument is a
    subtraction or a negative constant.
    """

    name = "event-past"
    severity = SEVERITY_WARNING
    description = "event pushed at a time computed by subtraction"

    RECEIVERS = {"loop", "event_loop", "evloop", "_loop"}

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call) and node.args):
                continue
            if not self._is_loop_push(node.func):
                continue
            t = node.args[0]
            if isinstance(t, ast.BinOp) and isinstance(t.op, ast.Sub):
                yield self.finding(
                    ctx, node,
                    "event time is a subtraction — it may precede the loop "
                    "clock; schedule at `t` or `t + delay`",
                )
            elif (
                isinstance(t, ast.UnaryOp)
                and isinstance(t.op, ast.USub)
                or isinstance(t, ast.Constant)
                and isinstance(t.value, (int, float))
                and t.value < 0
            ):
                yield self.finding(
                    ctx, node, "event scheduled at a negative time"
                )

    def _is_loop_push(self, func: ast.expr) -> bool:
        if not isinstance(func, ast.Attribute):
            return False
        if func.attr == "_push":
            return True
        if func.attr != "push":
            return False
        recv = func.value
        if isinstance(recv, ast.Name):
            return recv.id in self.RECEIVERS
        return isinstance(recv, ast.Attribute) and recv.attr in self.RECEIVERS
