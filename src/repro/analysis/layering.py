"""Whole-program import-graph layering checker.

The repo's dependency DAG is a contract, not a convention:

* ``repro.core`` imports nothing internal except **lazy** ``interconnect``
  (inside a function body or a ``TYPE_CHECKING`` block) — the tuner must
  stay usable with no serving/telemetry stack on the path.
* ``repro.interconnect`` imports nothing internal: the fabric is priced
  by core evaluators and serving alike, so it can depend on neither.
* ``repro.telemetry`` imports nothing internal — every layer hands it a
  duck-typed handle precisely so the sink never pulls the stack in.
* ``repro.analysis`` (this package) imports nothing internal *and* is
  stdlib-only, so the lint gate runs before any third-party install.

On top of the per-package contracts, the checker rejects any *eager*
import cycle among the scanned modules: cycles are where "it imported
fine on my machine" comes from, because resolution starts depending on
which module happened to be imported first.
"""

from __future__ import annotations

import ast
import dataclasses
import sys
from typing import Iterator, Sequence

from .framework import FileContext, Finding, ProgramRule, register


@dataclasses.dataclass(frozen=True)
class ImportEdge:
    src_module: str
    src_display: str
    target: str  # dotted absolute target ("repro.serve.simulator", "numpy")
    line: int
    col: int
    lazy: bool  # inside a function body or TYPE_CHECKING block


@dataclasses.dataclass(frozen=True)
class LayerContract:
    eager: frozenset[str]  # internal top packages importable at module scope
    lazy: frozenset[str]  # additionally importable lazily


CONTRACTS: dict[str, LayerContract] = {
    "core": LayerContract(
        eager=frozenset(), lazy=frozenset({"interconnect", "power", "faults"})
    ),
    "interconnect": LayerContract(eager=frozenset(), lazy=frozenset()),
    "power": LayerContract(eager=frozenset(), lazy=frozenset()),
    "telemetry": LayerContract(eager=frozenset(), lazy=frozenset()),
    "analysis": LayerContract(eager=frozenset(), lazy=frozenset()),
    "faults": LayerContract(eager=frozenset(), lazy=frozenset()),
}

#: packages that must import nothing outside the standard library
STDLIB_ONLY = frozenset({"analysis", "power", "faults"})


def _is_type_checking_test(test: ast.expr) -> bool:
    if isinstance(test, ast.Name):
        return test.id == "TYPE_CHECKING"
    if isinstance(test, ast.Attribute):
        return test.attr == "TYPE_CHECKING"
    return False


def collect_edges(ctx: FileContext) -> list[ImportEdge]:
    """Every import in the file, resolved to absolute dotted targets."""
    edges: list[ImportEdge] = []

    def resolve_relative(node: ast.ImportFrom) -> str:
        parts = ctx.module.split(".")
        if not ctx.is_package:
            parts = parts[:-1]
        up = node.level - 1
        base = parts[: len(parts) - up] if up else parts
        if node.module:
            base = base + node.module.split(".")
        return ".".join(base)

    def visit(node: ast.AST, lazy: bool) -> None:
        for child in ast.iter_child_nodes(node):
            child_lazy = lazy
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                child_lazy = True
            elif isinstance(child, ast.If) and _is_type_checking_test(child.test):
                child_lazy = True
            if isinstance(child, ast.Import):
                for a in child.names:
                    edges.append(
                        ImportEdge(
                            ctx.module, ctx.display, a.name,
                            child.lineno, child.col_offset, lazy,
                        )
                    )
            elif isinstance(child, ast.ImportFrom):
                base = (
                    resolve_relative(child) if child.level else (child.module or "")
                )
                if base:
                    edges.append(
                        ImportEdge(
                            ctx.module, ctx.display, base,
                            child.lineno, child.col_offset, lazy,
                        )
                    )
                for a in child.names:
                    if base and a.name != "*":
                        edges.append(
                            ImportEdge(
                                ctx.module, ctx.display, f"{base}.{a.name}",
                                child.lineno, child.col_offset, lazy,
                            )
                        )
            else:
                visit(child, child_lazy)

    visit(ctx.tree, lazy=False)
    return edges


def _top_package(module: str) -> str:
    """"core" for "repro.core.seed"; "" for non-internal modules."""
    parts = module.split(".")
    if parts[0] == "repro" and len(parts) > 1:
        return parts[1]
    return ""


def _is_internal(target: str) -> bool:
    return target == "repro" or target.startswith("repro.")


@register
class ImportLayeringRule(ProgramRule):
    """Enforce the dependency DAG and reject eager import cycles."""

    name = "import-layering"
    description = "layering-contract violation or eager import cycle"

    def check_program(self, ctxs: Sequence[FileContext]) -> Iterator[Finding]:
        modules = {c.module for c in ctxs}
        all_edges: list[ImportEdge] = []
        for ctx in ctxs:
            all_edges.extend(collect_edges(ctx))
        yield from self._contract_findings(all_edges)
        yield from self._cycle_findings(all_edges, modules)

    # -- per-package contracts ----------------------------------------------

    def _contract_findings(self, edges: list[ImportEdge]) -> Iterator[Finding]:
        seen: set[tuple] = set()
        for e in edges:
            src_top = _top_package(e.src_module)
            contract = CONTRACTS.get(src_top)
            if contract is not None and _is_internal(e.target):
                tgt_top = _top_package(e.target)
                if tgt_top and tgt_top != src_top:
                    allowed = contract.eager | (contract.lazy if e.lazy else frozenset())
                    if tgt_top not in allowed:
                        key = (e.src_display, e.line, tgt_top)
                        if key not in seen:
                            seen.add(key)
                            lazily = (
                                " (allowed lazily: move it inside the function "
                                "or a TYPE_CHECKING block)"
                                if tgt_top in contract.lazy
                                else ""
                            )
                            yield Finding(
                                e.src_display, e.line, e.col, self.name,
                                self.severity,
                                f"repro.{src_top} may not import "
                                f"repro.{tgt_top}{lazily}",
                            )
            if src_top in STDLIB_ONLY and not _is_internal(e.target):
                top = e.target.split(".")[0]
                if top not in sys.stdlib_module_names:
                    key = (e.src_display, e.line, "stdlib", top)
                    if key not in seen:
                        seen.add(key)
                        yield Finding(
                            e.src_display, e.line, e.col, self.name,
                            self.severity,
                            f"repro.{src_top} is stdlib-only but imports "
                            f"{top!r}",
                        )

    # -- eager cycle detection ----------------------------------------------

    def _cycle_findings(
        self, edges: list[ImportEdge], modules: set[str]
    ) -> Iterator[Finding]:
        graph: dict[str, set[str]] = {m: set() for m in modules}
        edge_at: dict[tuple[str, str], ImportEdge] = {}
        for e in edges:
            if e.lazy:
                continue
            tgt = self._resolve_scanned(e.target, modules)
            if tgt is None or tgt == e.src_module:
                continue
            graph[e.src_module].add(tgt)
            edge_at.setdefault((e.src_module, tgt), e)
        for comp in self._sccs(graph):
            if len(comp) < 2:
                continue
            cyc = sorted(comp)
            head = cyc[0]
            nxt = next(t for t in sorted(graph[head]) if t in comp)
            e = edge_at[(head, nxt)]
            yield Finding(
                e.src_display, e.line, e.col, self.name, self.severity,
                "eager import cycle: " + " -> ".join(cyc + [cyc[0]]),
            )

    @staticmethod
    def _resolve_scanned(target: str, modules: set[str]) -> str | None:
        """Deepest scanned module matching the dotted target, if any."""
        parts = target.split(".")
        for i in range(len(parts), 0, -1):
            cand = ".".join(parts[:i])
            if cand in modules:
                return cand
        return None

    @staticmethod
    def _sccs(graph: dict[str, set[str]]) -> list[set[str]]:
        """Tarjan strongly-connected components (iterative)."""
        index: dict[str, int] = {}
        low: dict[str, int] = {}
        on_stack: set[str] = set()
        stack: list[str] = []
        out: list[set[str]] = []
        counter = [0]

        for root in sorted(graph):
            if root in index:
                continue
            work: list[tuple[str, Iterator[str]]] = [(root, iter(sorted(graph[root])))]
            index[root] = low[root] = counter[0]
            counter[0] += 1
            stack.append(root)
            on_stack.add(root)
            while work:
                v, it = work[-1]
                advanced = False
                for w in it:
                    if w not in index:
                        index[w] = low[w] = counter[0]
                        counter[0] += 1
                        stack.append(w)
                        on_stack.add(w)
                        work.append((w, iter(sorted(graph[w]))))
                        advanced = True
                        break
                    if w in on_stack:
                        low[v] = min(low[v], index[w])
                if advanced:
                    continue
                work.pop()
                if work:
                    parent = work[-1][0]
                    low[parent] = min(low[parent], low[v])
                if low[v] == index[v]:
                    comp = set()
                    while True:
                        w = stack.pop()
                        on_stack.discard(w)
                        comp.add(w)
                        if w == v:
                            break
                    out.append(comp)
        return out
