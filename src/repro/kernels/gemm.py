"""Blocked GEMM Pallas kernel — the paper's core CNN operator (§6).

TPU-native tiling: (BM, BN) output tiles aligned to the 128×128 MXU, K
swept in BK slices as the innermost (sequential) grid dimension with an
fp32 VMEM accumulator.  VMEM working set per step:
BM·BK + BK·BN + BM·BN fp32 words — 128/128/512 defaults ≈ 0.8 MB,
comfortably inside the ~16 MB/core budget with double-buffering headroom.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """a: [M, K] @ b: [K, N] -> [M, N]. Dims padded up to tile multiples."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    n_k = kp // bk
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
