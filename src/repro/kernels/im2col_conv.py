"""Im2Col + GEMM convolution Pallas kernel.

This is the GEMM-based conv operator the paper simulates in gem5 (§6:
"A GEMM-based implementation consists of two operators: Im2Col and GEMM"),
adapted to the TPU memory hierarchy: instead of materializing the
[HO·WO, R·S·C] patch matrix in HBM (the CPU/gem5 formulation), the kernel
accumulates R·S shifted [HO·WO, C] × [C, K] matmuls out of VMEM — an
implicit-GEMM layout that keeps the patch matrix entirely virtual and the
MXU fed with C/K-contiguous panels.

Tiling: grid (N, K/BK).  One image (padded, NHWC) is resident in VMEM per
step; output channels are swept in BK=128 MXU-aligned slices.  This covers
the paper's CNN layers (≤416² activations) within VMEM; larger frontends
would add an H-halo grid dimension — noted in DESIGN.md, not needed for
the assigned workloads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, o_ref, *, r: int, s: int, stride: int, ho: int, wo: int):
    x = x_ref[0]  # [HP, WP, C] padded input, VMEM-resident
    c = x.shape[-1]
    acc = jnp.zeros((ho * wo, o_ref.shape[-1]), jnp.float32)
    for dr in range(r):  # unrolled R·S implicit-GEMM accumulation
        for ds in range(s):
            patch = jax.lax.slice(
                x,
                (dr, ds, 0),
                (dr + (ho - 1) * stride + 1, ds + (wo - 1) * stride + 1, c),
                (stride, stride, 1),
            )  # [HO, WO, C]
            acc += jnp.dot(
                patch.reshape(ho * wo, c),
                w_ref[dr, ds],
                preferred_element_type=jnp.float32,
            )
    o_ref[0] = acc.reshape(ho, wo, -1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "bk", "interpret"))
def conv2d_im2col(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """SAME-padded conv. x: [N, H, W, C]; w: [R, S, C, K] -> [N, HO, WO, K]."""
    n, h, wid, c = x.shape
    r, s, c2, k = w.shape
    assert c == c2, (x.shape, w.shape)
    ho, wo = -(-h // stride), -(-wid // stride)
    pad_h = max((ho - 1) * stride + r - h, 0)
    pad_w = max((wo - 1) * stride + s - wid, 0)
    xp = jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2), (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    bk = min(bk, k)
    kp = -(-k // bk) * bk
    if kp != k:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, kp - k)))
    hp, wp = xp.shape[1], xp.shape[2]
    out = pl.pallas_call(
        functools.partial(_conv_kernel, r=r, s=s, stride=stride, ho=ho, wo=wo),
        grid=(n, kp // bk),
        in_specs=[
            pl.BlockSpec((1, hp, wp, c), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((r, s, c, bk), lambda i, j: (0, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, bk), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, kp), x.dtype),
        interpret=interpret,
    )(xp, w)
    return out[..., :k]
