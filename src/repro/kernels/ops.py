"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in CPU
tests/examples (Pallas interpret mode executes the kernel body in Python)
and compile to real Mosaic kernels on TPU.
"""

from __future__ import annotations

import jax

from .flash_attention import flash_attention as _flash
from .gemm import gemm as _gemm
from .im2col_conv import conv2d_im2col as _conv
from .ssd_scan import ssd_scan as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def gemm(a, b, **kw):
    kw.setdefault("interpret", _default_interpret())
    return _gemm(a, b, **kw)


def conv2d_im2col(x, w, *, stride: int = 1, **kw):
    kw.setdefault("interpret", _default_interpret())
    return _conv(x, w, stride=stride, **kw)


def flash_attention(q, k, v, *, causal: bool = True, **kw):
    kw.setdefault("interpret", _default_interpret())
    return _flash(q, k, v, causal=causal, **kw)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 64, **kw):
    kw.setdefault("interpret", _default_interpret())
    return _ssd(x, dt, A, B, C, chunk=chunk, **kw)
