"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def conv2d_ref(x: jax.Array, w: jax.Array, *, stride: int = 1) -> jax.Array:
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True) -> jax.Array:
    """q: [B, H, S, D]; k/v: [B, KVH, S, D] (GQA)."""
    b, h, s, d = q.shape
    kvh = k.shape[1]
    group = h // kvh
    qg = q.reshape(b, kvh, group, s, d)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qg, k).astype(jnp.float32) / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v)
    return out.reshape(b, h, s, d)


def ssd_ref(x, dt, A, B, C):
    """Naive sequential SSD recurrence in fp64-ish fp32 (oracle)."""
    b, l, h, p = x.shape
    n = B.shape[-1]

    def step(state, inp):
        xt, dtt, Bt, Ct = inp  # [b,h,p], [b,h], [b,n], [b,n]
        dA = jnp.exp(dtt * A[None, :])  # [b,h]
        state = state * dA[..., None, None] + jnp.einsum("bh,bn,bhp->bhpn", dtt, Bt, xt)
        y = jnp.einsum("bhpn,bn->bhp", state, Ct)
        return state, y

    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        B.transpose(1, 0, 2).astype(jnp.float32),
        C.transpose(1, 0, 2).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, jnp.zeros((b, h, p, n), jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
