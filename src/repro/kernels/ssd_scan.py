"""Mamba2 SSD chunk-scan Pallas kernel.

The SSD recurrence is re-blocked for the MXU exactly as in the chunked
formulation (blocks.py): within a chunk the output is a masked-decay
attention-like product (three small matmuls), across chunks a [P, N] state
is carried.  The carry lives in VMEM scratch across the *sequential* chunk
grid dimension — the TPU grid is the scan loop, so the state never round-
trips to HBM.

Grid: (batch·heads, n_chunks).  Per-step VMEM: chunk panels x [CL, P],
B/C [CL, N], decay matrices [CL, CL], state [P, N] fp32 — with CL=64,
P=64, N=128: ≈ 120 KB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, cl: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)  # [CL, P]
    dt = dt_ref[0].astype(jnp.float32)  # [CL]
    a_h = a_ref[0].astype(jnp.float32)  # scalar A for this head
    bmat = b_ref[0].astype(jnp.float32)  # [CL, N]
    cmat = c_ref[0].astype(jnp.float32)  # [CL, N]

    la = dt * a_h  # [CL] log-decay per step
    cum = jnp.cumsum(la)  # [CL]
    xdt = x * dt[:, None]

    # intra-chunk: masked decay kernel L[l, s] = exp(cum_l - cum_s) for l >= s
    li = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 1)
    ldec = jnp.where(li >= si, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    g = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32)  # [CL, CL]
    y = jnp.dot(g * ldec, xdt, preferred_element_type=jnp.float32)  # [CL, P]

    # inter-chunk: contribution of the carried state, then state update
    state = state_ref[...]  # [P, N]
    y += jnp.exp(cum)[:, None] * jnp.dot(cmat, state.T, preferred_element_type=jnp.float32)
    decay_to_end = jnp.exp(cum[-1] - cum)  # [CL]
    new_contrib = jnp.dot((decay_to_end[:, None] * xdt).T, bmat, preferred_element_type=jnp.float32)
    state_ref[...] = state * jnp.exp(cum[-1]) + new_contrib
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """SSD: x [b, l, h, p]; dt [b, l, h]; A [h]; B, C [b, l, n] -> y like x."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    # layout: fold (b, h), chunk-major sequences
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, l, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, l)
    af = jnp.broadcast_to(A[None, :], (b, h)).reshape(b * h)
    bf = jnp.broadcast_to(B[:, None], (b, h, l, n)).reshape(b * h, l, n)
    cf = jnp.broadcast_to(C[:, None], (b, h, l, n)).reshape(b * h, l, n)
    y = pl.pallas_call(
        functools.partial(_ssd_kernel, cl=chunk),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk), lambda i, c: (i, c)),
            pl.BlockSpec((1,), lambda i, c: (i,)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, l, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, bf, cf)
    return y.reshape(b, h, l, p).transpose(0, 2, 1, 3)
