"""Flash attention Pallas kernel (online-softmax, causal, GQA-aware wrapper).

Grid (batch·kv_heads·group, q_blocks, kv_blocks) with the KV sweep as the
innermost sequential dimension; running max / normalizer / fp32 accumulator
live in VMEM scratch across KV iterations and the output tile is emitted on
the last KV block.  Causal blocks strictly above the diagonal are skipped
with ``pl.when`` (no MXU work issued).

Block shapes default to (128, 128): q tile BQ×D and kv tile BK×D are
MXU-aligned panels; per-step VMEM ≈ (BQ + 2·BK)·D + BQ·BK + BQ·D fp32.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal, bq, bk, n_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0]  # [BQ, D]
        k = k_ref[0]  # [BK, D]
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, H, S, D]; k, v: [B, KVH, S, D] with H % KVH == 0 -> [B, H, S, D]."""
    b, h, s, d = q.shape
    kvh = k.shape[1]
    group = h // kvh
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, "seq must divide block size"
    scale = 1.0 / math.sqrt(d)
    # fold batch and heads; map q-head -> kv-head by integer division
    qf = q.reshape(b * h, s, d)
    n_kv = s // bk

    kf = k.reshape(b * kvh, s, d)
    vf = v.reshape(b * kvh, s, d)
    # q index i runs over b*h: batch = i // h, qhead = i % h, kvhead = qhead // group
    def kv_map(i, qi, ki):
        batch = i // h
        kvhead = (i % h) // group
        return (batch * kvh + kvhead, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, bq=bq, bk=bk, n_kv=n_kv
        ),
        grid=(b * h, s // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, qi, ki: (i, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, qi, ki: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
