"""Version-compat shims for the JAX API surface this repo relies on.

``shard_map`` moved from ``jax.experimental.shard_map`` to top-level
``jax.shard_map`` (and its replication check was renamed ``check_rep`` ->
``check_vma``) across the JAX versions this repo supports.  Route every
call through one helper so call sites stay on the modern spelling and the
repo keeps working on either side of the move.
"""

from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` where available, else the experimental spelling."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )
