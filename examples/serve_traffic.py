"""Serving-under-traffic demo: continuous Shisha rides out faults.

    PYTHONPATH=src python examples/serve_traffic.py

Act 1 — single tenant, straggler:
1. Tunes SynthNet onto the paper's 8-EP big/LITTLE platform (Alg. 1 + 2).
2. Serves bursty (MMPP) traffic through the discrete-event simulator.
3. Injects a 3x slowdown on the bottleneck EP mid-run; the continuous
   autotuner detects the drift, re-runs Algorithm 2 against the derated
   platform model (paying the exploration time on the simulated clock),
   and installs the recovered schedule.
4. Prints the load timeline so you can watch the queue build and drain.

Act 2 — two tenants on one shared clock, EP dropout:
5. Co-serves SynthNet + ResNet50 on disjoint partitions of the same
   platform and kills one of SynthNet's fast EPs mid-run.  The elastic
   partitioner prices every donor EP in requests/second of at-risk
   demand and lets SynthNet steal the cheapest one; both affected
   tenants re-tune, paying the full exploration wall-clock.

Act 3 — the same run, through the telemetry lens:
6. Act 2 ran with a live `Telemetry` session, so every request span,
   re-tune, fabric flow window, and repartition landed in one timeline.
   Exports it as Chrome trace-event JSON (open in Perfetto or
   chrome://tracing — tenants are processes; EPs, the tuner, and the
   request stream are tracks) and pretty-prints the densest tracks plus
   the cross-layer metrics registry.
"""

from repro.core import DatabaseEvaluator, Trace, paper_platform, weights
from repro.core.heuristics import run_shisha
from repro.models.cnn import network_layers
from repro.serve import (
    ContinuousShisha,
    MMPPTraffic,
    PoissonTraffic,
    ServingSimulator,
    Tenant,
    co_serve,
)
from repro.telemetry import Telemetry

HORIZON = 300.0
FAULT_T = 60.0

layers = network_layers("synthnet")
plat = paper_platform(8)
ev = DatabaseEvaluator(plat, layers)

sh = run_shisha(weights(layers), Trace(ev), "H3")
conf, cap = sh.result.best_conf, sh.result.best_throughput
print(f"[tune ] {conf.pretty([ep.name for ep in plat.eps])}")
print(f"[tune ] model capacity {cap:.2f} req/s")

traffic = MMPPTraffic(rate_low=0.3 * cap, rate_high=0.8 * cap, seed=7)
tuner = ContinuousShisha(plat, layers, make_evaluator=lambda p: DatabaseEvaluator(p, layers))
sim = ServingSimulator(ev, conf, slo=3.0 * sum(ev.stage_times(conf)), autotuner=tuner)
sim.schedule_slowdown(FAULT_T, conf.eps[max(range(conf.depth), key=ev.stage_times(conf).__getitem__)], 3.0)

res = sim.run(traffic.arrivals(HORIZON), HORIZON)

print(f"[serve] {res.summary()}")
for r in res.reconfigs:
    print(
        f"[retune] t={r['t']:.1f}s kind={r['kind']} explored for "
        f"{r['tuning_cost_s']:.1f}s (simulated), new depth {r['new_depth']}, "
        f"model throughput {r['model_throughput']:.2f}/s"
    )

# crude load timeline: one row per ~10 s, bar = requests in system
if res.load_samples:
    peak = max(n for _, n in res.load_samples) or 1
    step = max(1, len(res.load_samples) // 30)
    print("[load ] t(s)  requests in system")
    for t, n in res.load_samples[::step]:
        marks = "#" * max(1, round(40 * n / peak)) if n else ""
        print(f"[load ] {t:6.1f} {marks} {n}")

# --- Act 2: elastic multi-tenancy under an EP dropout ----------------------

print()
print("[multi] co-serving synthnet + resnet50 on one shared clock")
r50 = network_layers("resnet50")
tenants = [
    Tenant(
        name="synthnet",
        layers=tuple(layers),
        traffic=PoissonTraffic(rate=0.25 * cap, seed=21),
        slo=2.7,
    ),
    Tenant(
        name="resnet50",
        layers=tuple(r50),
        traffic=MMPPTraffic(rate_low=0.5, rate_high=2.0, seed=22),
        slo=0.8,
    ),
]
tl = Telemetry()
out = co_serve(
    plat,
    tenants,
    horizon=HORIZON,
    elastic=True,
    batch_policy_search=True,
    measure_batches=2,
    alpha=4,
    faults=[("dropout", FAULT_T, 0)],  # kill global FEP0 mid-run
    telemetry=tl,
)
for r in out.results:
    print(f"[multi] {r.tenant.name:9s} eps={list(r.ep_idxs)} {r.sim.summary()}")
for e in out.repartitions:
    costs = ", ".join(f"{k}={v:.1f}s" for k, v in e.retune_costs.items())
    if e.kind == "revival":
        deal = f"EP{e.stolen_ep} revived and was granted to {e.victim}"
    elif e.stolen_ep is None:
        deal = f"EP{e.dead_ep} died; no donor could spare an EP for {e.victim}"
    else:
        price = "unpriced" if e.price is None else f"price {e.price:.2f} req/s at risk"
        deal = f"EP{e.dead_ep} died; {e.victim} stole EP{e.stolen_ep} from {e.donor} ({price})"
    print(f"[elast] t={e.t:.1f}s {deal}; re-tune costs {costs}")

# --- Act 3: the same run, through the telemetry lens -----------------------

print()
print("[trace] act 2 ran under a live Telemetry session; exporting it")
trace_path = "experiments/telemetry/serve_traffic_trace.json"
chrome = tl.export_chrome_trace(trace_path)
spans = [e for e in chrome["traceEvents"] if e.get("ph") == "X"]
instants = [e for e in chrome["traceEvents"] if e.get("ph") == "i"]
print(
    f"[trace] {len(spans)} spans + {len(instants)} instants -> {trace_path}"
    " (open in Perfetto / chrome://tracing)"
)

# densest tracks: which (process, track) pairs carry the timeline
names = {
    (e["args"]["name"], e["pid"]): None
    for e in chrome["traceEvents"]
    if e.get("ph") == "M" and e["name"] == "process_name"
}
pid_name = {pid: proc for (proc, pid) in names}
by_track: dict = {}
for e in spans:
    key = (pid_name.get(e["pid"], e["pid"]), e["name"])
    calls, dur = by_track.get(key, (0, 0.0))
    by_track[key] = (calls + 1, dur + e["dur"] / 1e6)
print("[trace] process/track        spans  busy(sim s)")
for (proc, name), (calls, dur) in sorted(by_track.items(), key=lambda kv: (-kv[1][0], kv[0]))[:8]:
    print(f"[trace] {proc:>9s}/{name:<12s} {calls:5d}  {dur:8.1f}")

# the cross-layer metrics registry: one line per headline metric
snap = tl.metrics_snapshot()
print("[metr ] cross-layer registry highlights:")
for name in sorted(snap):
    m = snap[name]
    if m["kind"] == "counter":
        print(f"[metr ] {name:<28s} count={m['value']}")
    elif m["kind"] == "histogram" and (
        name.endswith("latency_s") or name.startswith(("tune.", "fabric."))
    ):
        print(
            f"[metr ] {name:<28s} n={m['count']:<5d} "
            f"p50={m['p50']:.4f} p99={m['p99']:.4f}"
        )
