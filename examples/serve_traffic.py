"""Serving-under-traffic demo: continuous Shisha rides out a straggler.

    PYTHONPATH=src python examples/serve_traffic.py

1. Tunes SynthNet onto the paper's 8-EP big/LITTLE platform (Alg. 1 + 2).
2. Serves bursty (MMPP) traffic through the discrete-event simulator.
3. Injects a 3x slowdown on the bottleneck EP mid-run; the continuous
   autotuner detects the drift, re-runs Algorithm 2 against the derated
   platform model (paying the exploration time on the simulated clock),
   and installs the recovered schedule.
4. Prints the load timeline so you can watch the queue build and drain.
"""

from repro.core import DatabaseEvaluator, Trace, paper_platform, weights
from repro.core.heuristics import run_shisha
from repro.models.cnn import network_layers
from repro.serve import ContinuousShisha, MMPPTraffic, ServingSimulator

HORIZON = 300.0
FAULT_T = 60.0

layers = network_layers("synthnet")
plat = paper_platform(8)
ev = DatabaseEvaluator(plat, layers)

sh = run_shisha(weights(layers), Trace(ev), "H3")
conf, cap = sh.result.best_conf, sh.result.best_throughput
print(f"[tune ] {conf.pretty([ep.name for ep in plat.eps])}")
print(f"[tune ] model capacity {cap:.2f} req/s")

traffic = MMPPTraffic(rate_low=0.3 * cap, rate_high=0.8 * cap, seed=7)
tuner = ContinuousShisha(plat, layers, make_evaluator=lambda p: DatabaseEvaluator(p, layers))
sim = ServingSimulator(ev, conf, slo=3.0 * sum(ev.stage_times(conf)), autotuner=tuner)
sim.schedule_slowdown(FAULT_T, conf.eps[max(range(conf.depth), key=ev.stage_times(conf).__getitem__)], 3.0)

res = sim.run(traffic.arrivals(HORIZON), HORIZON)

print(f"[serve] {res.summary()}")
for r in res.reconfigs:
    print(
        f"[retune] t={r['t']:.1f}s kind={r['kind']} explored for "
        f"{r['tuning_cost_s']:.1f}s (simulated), new depth {r['new_depth']}, "
        f"model throughput {r['model_throughput']:.2f}/s"
    )

# crude load timeline: one row per ~10 s, bar = requests in system
if res.load_samples:
    peak = max(n for _, n in res.load_samples) or 1
    step = max(1, len(res.load_samples) // 30)
    print("[load ] t(s)  requests in system")
    for t, n in res.load_samples[::step]:
        marks = "#" * max(1, round(40 * n / peak)) if n else ""
        print(f"[load ] {t:6.1f} {marks} {n}")
