"""Batched LM serving: prefill a prompt batch, decode with the ring cache.

    PYTHONPATH=src python examples/serve_lm.py --arch granite-3-2b
"""

import argparse

from repro.configs import ARCHS, get_smoke
from repro.launch.serve import serve

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = get_smoke(args.arch)
    out = serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)
    print(
        f"[example] {args.arch}: generated {out['tokens'].shape} tokens | "
        f"prefill {out['prefill_s']:.2f}s | decode {out['decode_tok_per_s']:.1f} tok/s"
    )
