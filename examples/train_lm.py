"""Train an LM end to end with checkpoint/restore and deterministic resume.

    PYTHONPATH=src python examples/train_lm.py              # ~8M params, fast
    PYTHONPATH=src python examples/train_lm.py --m100       # ~100M params

The ~100M config (d=768, 12L, GQA, SwiGLU) is the assignment's "train a
~100M model for a few hundred steps" driver — on this CPU box each step is
seconds, so default step count is modest; pass --steps to go longer.
"""

import argparse
from pathlib import Path

import jax.numpy as jnp

from repro.models.lm_common import LMConfig
from repro.launch.train import train

SMALL = LMConfig(
    name="lm-8m", n_layers=6, d_model=256, n_heads=8, n_kv_heads=4,
    d_ff=1024, vocab=8192, remat="none",
)

M100 = LMConfig(
    name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=3072, vocab=32768, remat="none",
)

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--m100", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", type=Path, default=Path("/tmp/repro_train_lm"))
    args = ap.parse_args()
    cfg = M100 if args.m100 else SMALL
    steps = args.steps or (200 if args.m100 else 120)
    print(f"[example] training {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, {steps} steps")
    out = train(cfg, steps=steps, batch=8, seq=128, ckpt_dir=args.ckpt, save_every=50, log_every=10)
    l = out["losses"]
    print(f"[example] loss {l[0]:.3f} -> {l[-1]:.3f} over {len(l)} steps "
          f"({out['steps_per_s']:.2f} steps/s); checkpoints in {args.ckpt}")
