"""Interconnect-fabric tour: from the scalar Fig. 9 knob to a routed,
contention-priced chiplet fabric.

    PYTHONPATH=src python examples/fabric_tour.py

Stops on the tour:
1. Builds a 2x4 mesh fabric over the paper's 8-EP big/LITTLE platform and
   prints a few XY routes (hops x per-link latency = the routed form of the
   Fig. 9 inter-chiplet-latency knob).
2. Shows the degenerate fully-connected fabric reproducing the scalar-link
   evaluator exactly (same stage times, same tuned schedule).
3. Prices one activation transfer alone vs. under a co-tenant flow on the
   same link (fair-share slowdown) and vs. a memory-controller hotspot.
4. Re-runs the Fig. 9 latency sweep on the mesh: the same knob, but now a
   3-hop transfer pays 3x the per-link latency.
5. Tunes contention-blind vs. contention-aware (live co-tenant flow set in
   the model + placement moves) and scores both under the congested ground
   truth — the Fig. 9-style experiment of benchmarks/fig9_interconnect.py.
6. Flips the same fabric to routing="adaptive": the identical schedule's
   boundary transfers detour around the hammered row, strictly lowering the
   beat — and an express channel (a heterogeneous link XY routing cannot
   use) widens the gap.  Also prices a placement trial at its routed
   hop-priced weight-shipping cost (benchmarks/fig9_adaptive).
"""

from repro.core import DatabaseEvaluator, Trace, paper_platform, weights
from repro.core.heuristics import run_shisha
from repro.core.tuner import tune
from repro.interconnect import Flow, mesh2d, scalar_fabric, uniform_fabric
from repro.models.cnn import network_layers

layers = network_layers("synthnet")
ws = weights(layers)
base = paper_platform(8)

# -- 1. a mesh fabric and its routes ----------------------------------------

mesh = uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
plat = base.with_fabric(mesh)
print("[topo ] 2x4 mesh, FEP0..3 on row 0, SEP0..3 on row 1")
for src, dst in ((0, 1), (0, 7), (3, 4)):
    route = mesh.route_ep(src, dst)
    print(
        f"[route] EP{src} -> EP{dst}: {len(route)} hops via {route}, "
        f"routed latency {mesh.latency_ep(src, dst) * 1e6:.1f}us"
    )

# -- 2. the degenerate fabric is the old scalar model -----------------------

flat = base.with_fabric(scalar_fabric(base))
conf = run_shisha(ws, Trace(DatabaseEvaluator(base, layers)), "H3").result.best_conf
same = DatabaseEvaluator(base, layers).stage_times(conf) == DatabaseEvaluator(
    flat, layers
).stage_times(conf)
print(f"[degen] fully-connected fabric == scalar evaluator, bit-for-bit: {same}")

# -- 3. contention pricing ---------------------------------------------------

nbytes = 2e6
solo = mesh.transfer_time(0, 1, nbytes)
shared = mesh.transfer_time(0, 1, nbytes, background=[Flow(0, 1, nbytes, nodes=True)])
print(f"[price] {nbytes / 1e6:.0f}MB EP0->EP1 alone: {solo * 1e3:.1f}ms")
print(f"[price] same transfer next to a co-tenant flow: {shared * 1e3:.1f}ms (fair share)")
hot = uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6), mc_bw=5e7)
fan_in = hot.flow_times([Flow(1, 0, nbytes), Flow(4, 0, nbytes)])
print(f"[price] two flows fanning into EP0's memory controller: {fan_in[0] * 1e3:.1f}ms each")

# -- 4. the Fig. 9 knob, routed ----------------------------------------------

for lat in (1e-6, 1e-4, 1e-3):
    swept = plat.with_latency(lat)
    tp = DatabaseEvaluator(swept, layers).throughput(conf)
    print(
        f"[fig9 ] per-link latency {lat:7.0e}s -> EP0..EP7 route pays "
        f"{swept.fabric.latency_ep(0, 7) * 1e3:7.3f}ms, throughput {tp:.3f}/s"
    )

# -- 5. contention-blind vs contention-aware tuning --------------------------

congestor_pairs = ((0, 1), (1, 2), (2, 3), (0, 3))
congestor = tuple(Flow(src=s, dst=d, nbytes=2e6, nodes=True) for s, d in congestor_pairs)
blind = run_shisha(ws, Trace(DatabaseEvaluator(plat, layers)), "H3", placement=True).result.best_conf
aware_ev = DatabaseEvaluator(plat, layers)
aware_ev.background_flows = congestor
aware = tune(blind, Trace(aware_ev), placement=True).best_conf
gt = DatabaseEvaluator(plat, layers)
gt.background_flows = congestor
print(f"[tune ] co-tenant hammers the FEP-row links {list(congestor_pairs)}")
print(f"[tune ] contention-blind: {blind.pretty()} -> {gt.throughput(blind):.3f}/s under congestion")
print(f"[tune ] contention-aware: {aware.pretty()} -> {gt.throughput(aware):.3f}/s under congestion")

# -- 6. adaptive congestion-aware routing ------------------------------------

from repro.core.tuner import placement_reconfig_cost
from repro.interconnect import mesh2d as _mesh2d

adaptive_plat = base.with_fabric(mesh.with_routing("adaptive"))
ev_a = DatabaseEvaluator(adaptive_plat, layers)
ev_a.background_flows = congestor
beat_static, beat_adaptive = max(gt.stage_times(blind)), max(ev_a.stage_times(blind))
print(
    f"[route] same schedule, same flows: static beat {beat_static * 1e3:.1f}ms "
    f"-> adaptive beat {beat_adaptive * 1e3:.1f}ms (flows detour via row 1)"
)
express = base.with_fabric(
    uniform_fabric(
        _mesh2d(2, 4, bw=1e8, latency=1e-6, express_bw=2e8), routing="adaptive"
    )
)
ev_x = DatabaseEvaluator(express, layers)
ev_x.background_flows = congestor
print(
    f"[route] + row express channels (2x bw, invisible to XY): "
    f"adaptive beat {max(ev_x.stage_times(blind)) * 1e3:.1f}ms"
)
trace = Trace(DatabaseEvaluator(plat, layers))
far_ep = max(range(8), key=lambda e: len(mesh.route_ep(blind.eps[0], e)))
print(
    f"[price] relocating stage 0 ({blind.stages[0]} layers) to EP{far_ep} "
    f"({len(mesh.route_ep(blind.eps[0], far_ep))} hops) costs the trial "
    f"{placement_reconfig_cost(trace, blind, 0, far_ep) * 1e3:.1f}ms vs the flat "
    f"{trace.reconfig_overhead * 1e3:.1f}ms — distant chiplets are expensive to even try"
)
