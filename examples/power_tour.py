"""Power/thermal tour: DVFS ladders, a package power cap, and a throttle
loop the online tuner answers with frequency steps instead of re-tunes.

    PYTHONPATH=src python examples/power_tour.py

Stops on the tour:
1. Attaches a package power model to the paper's 4-EP big/LITTLE platform
   and prints one FEP's DVFS ladder — the cubic dynamic-power law makes a
   20% clock cut roughly halve the dynamic watts.
2. Shows the degenerate model (one nominal level, no cap) reproducing the
   power-free schedule bit-for-bit — the fabric playbook's regression pin.
3. Down-clocks one EP and prices the trade directly: slower stage times,
   fewer watts.
4. Tunes under a binding package cap with ``tune(dvfs=True)``: the loop
   steps in-use EPs down until the cap admits them, then keeps exploring
   boundary moves and frequency knobs together.
5. Serves the tuned pipeline with the thermal RC model live and reports
   the serving-loop energy telemetry: joules/request, peak/average
   package watts, hottest chiplet.
6. Turns the heat up (fast RC, narrow hysteresis) so a busy FEP throttles,
   and lets :class:`ContinuousShisha` classify the oscillating derate as
   ``"throttle"`` drift — answered with a DVFS step-down, not a re-tune.
"""

from repro.core import DatabaseEvaluator, Trace, paper_platform, weights
from repro.core.heuristics import run_shisha
from repro.core.tuner import tune
from repro.models.cnn import network_layers
from repro.power import ThermalModel, degenerate_power, uniform_power, uniform_thermal
from repro.serve import ContinuousShisha, PoissonTraffic, ServingSimulator

layers = network_layers("synthnet")
ws = weights(layers)
plat = paper_platform(4)

# -- 1. the package model and one EP's DVFS ladder ---------------------------

pm = uniform_power(plat)
print("[power] FEP0 DVFS ladder (cubic dynamic law, mild leakage slope):")
for i, lvl in enumerate(pm.specs[0].levels):
    print(
        f"[power]   {lvl.name}: scale {lvl.scale:.2f} -> "
        f"{lvl.dynamic_w:5.2f} W dynamic + {lvl.static_w:.2f} W static"
    )
conf = run_shisha(ws, Trace(DatabaseEvaluator(plat, layers)), "H3").result.best_conf
print(
    f"[power] nominal package draw with {conf.pretty()} all-busy: "
    f"{pm.package_w(conf.eps):.1f} W ({pm.static_package_w:.1f} W of it leakage)"
)

# -- 2. the degenerate model is the power-free platform ----------------------

plain = DatabaseEvaluator(plat, layers).stage_times(conf)
degen = DatabaseEvaluator(
    plat.with_power(degenerate_power(plat)), layers
).stage_times(conf)
print(f"[degen] degenerate power model == power-free evaluator, bit-for-bit: {plain == degen}")

# -- 3. one EP down a level: the speed/watts trade priced --------------------

pm_slow = uniform_power(plat)
pm_slow.set_level(conf.eps[0], 2)
slow = DatabaseEvaluator(plat.with_power(pm_slow), layers).stage_times(conf)
print(
    f"[dvfs ] EP{conf.eps[0]} at L2 (scale {pm_slow.scale(conf.eps[0]):.2f}): "
    f"stage 0 {plain[0] * 1e3:.2f}ms -> {slow[0] * 1e3:.2f}ms, "
    f"dynamic {pm.dynamic_w(conf.eps[0]):.1f} W -> {pm_slow.dynamic_w(conf.eps[0]):.1f} W"
)

# -- 4. tuning under a binding package cap -----------------------------------

cap_w = 0.7 * pm.package_w(conf.eps)
pm_cap = uniform_power(plat, cap_w=cap_w)
trace = Trace(DatabaseEvaluator(plat.with_power(pm_cap), layers))
capped = tune(conf, trace, dvfs=True)
print(
    f"[cap  ] {cap_w:.1f} W cap (binding at nominal): tune(dvfs=True) adopts "
    f"levels {list(capped.dvfs_levels)} -> {pm_cap.package_w(capped.best_conf.eps):.1f} W, "
    f"throughput {capped.best_throughput:.2f}/s over {trace.n_trials} paid trials"
)

# -- 5. serving with energy telemetry ----------------------------------------

plat_p = plat.with_power(uniform_power(plat, thermal=uniform_thermal(4, seed=3)))
ev = DatabaseEvaluator(plat_p, layers)
cap_tp = run_shisha(ws, Trace(DatabaseEvaluator(plat, layers)), "H3").result.best_throughput
slo = 3.0 * sum(ev.stage_times(conf))
arrivals = PoissonTraffic(rate=0.6 * cap_tp, seed=5).arrivals(60.0)
res = ServingSimulator(ev, conf, slo=slo).run(arrivals, 60.0)
p = res.power
print(
    f"[serve] {res.n_completed} requests in 60s: {p['joules_per_request']:.2f} J/req, "
    f"peak {p['peak_package_w']:.1f} W, avg {p['avg_package_w']:.1f} W, "
    f"hottest chiplet {p['max_temp_c']:.1f}C"
)

# -- 6. thermal throttling as drift the tuner answers with DVFS --------------

hot = ThermalModel(r_k_per_w=(4.0,) * 4, c_j_per_k=(1.0,) * 4, t_hot_c=80.0, t_cool_c=76.0)
plat_hot = plat.with_power(uniform_power(plat, thermal=hot))
tuner = ContinuousShisha(
    platform=plat_hot,
    layers=tuple(layers),
    make_evaluator=lambda pf: DatabaseEvaluator(pf, layers),
    cooldown=1.0,
    alpha=2,
    measure_batches=2,
)
sim = ServingSimulator(
    DatabaseEvaluator(plat_hot, layers),
    conf,
    slo=slo,
    autotuner=tuner,
    monitor_interval=0.5,
)
res = sim.run(PoissonTraffic(rate=0.7 * cap_tp, seed=5).arrivals(120.0), 120.0)
kinds = [r.kind for r in tuner.history]
first = next(r for r in tuner.history if r.kind == "throttle")
print(
    f"[heat ] fast RC + narrow hysteresis: {res.power['throttle_events']} throttle "
    f"events, max {res.power['max_temp_c']:.1f}C, drift kinds seen: {kinds}"
)
print(
    f"[heat ] first 'throttle' response: DVFS levels {list(first.dvfs_levels)} "
    f"(a frequency step-down, schedule untouched) vs a full re-tune for 'slowdown'"
)
