"""End-to-end driver (the paper's kind: online-scheduled CNN inference).

    PYTHONPATH=src python examples/pipeline_serve_cnn.py

1. Builds a runnable SynthNet CNN and MEASURES each layer on the real
   device (the live `execute()` oracle — no gem5, no model).
2. Runs Shisha (seed + online tuning) against the measured times on a
   heterogeneous 4-EP platform (EP derates emulate FEP/SEP chiplets).
3. Launches the chosen schedule as a real shard_map GPipe pipeline on a
   4-way stage mesh and streams batched requests through it.
4. Injects a straggler on one EP and lets the runtime rebalance with the
   same online tuner (fault-tolerance demo).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp

from repro.core import Trace, weights
from repro.models.cnn import canonical_pipeline_apply, make_cnn, network_layers
from repro.launch.mesh import make_stage_mesh
from repro.pipeline import MeasuringEvaluator, PipelineRunner, pipeline_throughput
from repro.pipeline.hetero import tpu_platform_from_mesh
from repro.runtime import StragglerMitigator
from repro.core.heuristics import run_shisha

N_STAGES = 4
IN_SHAPE = (8, 8, 8)

model = make_cnn("synthnet", scale=0.12)
params = model.init(jax.random.PRNGKey(0))
cost_layers = network_layers("synthnet")
platform = tpu_platform_from_mesh(N_STAGES, chips_per_stage=1, slow_fraction=0.5)

# 1-2. measured oracle + Shisha
x_probe = jnp.zeros((2, *IN_SHAPE), jnp.float32)
layer_fns = [lambda x, i=i: model.apply_layer(i, params[i], x) for i in range(len(model.specs))]
probe_args = [(x_probe,)] * len(layer_fns)
ev = MeasuringEvaluator(platform, cost_layers, layer_fns=layer_fns, layer_args=probe_args)
trace = Trace(ev)
res = run_shisha(weights(cost_layers), trace, "H3", n_stages=N_STAGES)
conf = res.result.best_conf
print(f"[schedule] {conf.pretty([ep.name for ep in platform.eps])}")
print(f"[schedule] measured-model throughput {res.result.best_throughput:.1f}/s after {trace.n_trials} trials")

# 3. run it for real
mesh = make_stage_mesh(conf.depth)
apply_fn, to_canon, crop_out, _ = canonical_pipeline_apply(model, params, IN_SHAPE)
runner = PipelineRunner(mesh=mesh, conf=conf, apply_layer=apply_fn, n_micro=8)
micro = jax.vmap(to_canon)(jax.random.normal(jax.random.PRNGKey(1), (8, 2, *IN_SHAPE)))
out = crop_out(runner.run(micro))
tp = pipeline_throughput(runner, micro)
print(f"[serve] pipelined {out.shape[0]} microbatches, output {out.shape}, measured {tp:.1f} micro/s")

# 4. straggler: stage 1's EP becomes 4x slower
mit = StragglerMitigator(platform, conf, lambda p: Trace(MeasuringEvaluator(p, cost_layers, layer_fns=layer_fns, layer_args=probe_args)))
times = ev.stage_times(conf)
times[1] *= 4.0
rebalanced = mit.rebalance(times)
if rebalanced:
    new_conf, result = rebalanced
    print(f"[fault] straggler on stage 1 -> rebalanced: {new_conf.pretty()}")
    print(f"[fault] modeled throughput after rebalance {result.best_throughput:.1f}/s")
else:
    print("[fault] imbalance below threshold; no rebalance needed")
