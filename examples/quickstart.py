"""Quickstart: schedule a CNN pipeline with Shisha in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds ResNet50's layer cost table (Eq. 1), a heterogeneous 8-EP platform,
runs seed generation (Alg. 1) + online tuning (Alg. 2, heuristic H3), and
compares against Hill Climbing under identical online cost accounting.
"""

from repro.core import (
    DatabaseEvaluator,
    Trace,
    hill_climbing,
    paper_platform,
    run_shisha,
    space_size,
    weights,
)
from repro.models.cnn import network_layers

layers = network_layers("resnet50")  # 50 compute-intensive layers
platform = paper_platform(8)  # 4 fast + 4 slow EPs (big.LITTLE-style)

trace = Trace(DatabaseEvaluator(platform, layers))
result = run_shisha(weights(layers), trace, heuristic="H3")

print("Shisha (H3) on ResNet50, 8 EPs")
print(f"  design space     : {space_size(len(layers), 8):.2e} configurations")
print(f"  explored         : {trace.n_trials} ({trace.n_trials / space_size(len(layers), 8) * 100:.5f}%)")
print(f"  best schedule    : {result.result.best_conf.pretty([ep.name for ep in platform.eps])}")
print(f"  throughput       : {result.result.best_throughput:.3f} inferences/s (modeled)")
print(f"  online time spent: {trace.wall:.1f}s (simulated pipeline time)")

hc_trace = Trace(DatabaseEvaluator(platform, layers))
hc = hill_climbing(hc_trace, len(layers), budget_s=trace.wall * 35)
print("\nHill Climbing with a 35x larger online budget")
print(f"  explored         : {hc_trace.n_trials}")
print(f"  throughput       : {hc.best_throughput:.3f} ({hc.best_throughput / result.result.best_throughput * 100:.1f}% of Shisha)")
