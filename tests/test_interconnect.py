"""Interconnect fabric invariants.

The fabric replaces the scalar link model everywhere, so four properties
are guarded hard:

  * routing determinism — routes are pure functions of the topology;
  * triangle inequality — routed latency is a metric on uniform fabrics;
  * degenerate equivalence — a fully-connected fabric built from the EP
    scalar link specs reproduces the pre-fabric evaluator bit-for-bit;
  * contention monotonicity — adding a flow never speeds up existing flows.
"""

import math

import pytest

from repro.core import (
    AnalyticEvaluator,
    DatabaseEvaluator,
    Trace,
    paper_platform,
    weights,
)
from repro.core.heuristics import run_shisha
from repro.core.tuner import placement_candidate, tune
from repro.interconnect import (
    Flow,
    crossbar,
    fully_connected,
    hierarchical,
    mesh2d,
    ring,
    scalar_fabric,
    uniform_fabric,
)
from repro.models.cnn import network_layers


def _all_topologies():
    return [
        mesh2d(2, 4, bw=1e8, latency=1e-6),
        mesh2d(3, 3, bw=1e8, latency=1e-6),
        ring(8, bw=1e8, latency=1e-6),
        crossbar(8, bw=1e8, latency=1e-6),
        hierarchical(2, 4),
        fully_connected(8),
    ]


# ---------------------------------------------------------------------------
# routing determinism
# ---------------------------------------------------------------------------


def test_routing_is_deterministic_within_and_across_instances():
    for make in (
        lambda: mesh2d(3, 3, bw=1e8, latency=1e-6),
        lambda: ring(8, bw=1e8, latency=1e-6),
        lambda: crossbar(8, bw=1e8, latency=1e-6),
        lambda: hierarchical(2, 4),
    ):
        a, b = make(), make()
        for s in range(a.n_nodes):
            for d in range(a.n_nodes):
                r1 = a.route(s, d)
                assert r1 == a.route(s, d), "route changed between calls"
                assert r1 == b.route(s, d), "route differs across instances"


def test_routes_are_valid_walks():
    for topo in _all_topologies():
        for s in range(topo.n_nodes):
            for d in range(topo.n_nodes):
                route = topo.route(s, d)
                if s == d:
                    assert route == ()
                    continue
                node = s
                for (u, v) in route:
                    assert node in (u, v), f"route {route} breaks at {node}"
                    node = v if node == u else u
                assert node == d


def test_mesh_xy_route_has_manhattan_length():
    topo = mesh2d(3, 4, bw=1e8, latency=1e-6)
    for s in range(topo.n_nodes):
        for d in range(topo.n_nodes):
            (sx, sy), (dx, dy) = topo.coords[s], topo.coords[d]
            assert topo.hops(s, d) == abs(sx - dx) + abs(sy - dy)


# ---------------------------------------------------------------------------
# triangle inequality
# ---------------------------------------------------------------------------


def test_routed_latency_triangle_inequality():
    for topo in _all_topologies():
        n = topo.n_nodes
        lat = [[topo.path_latency(a, b) for b in range(n)] for a in range(n)]
        for a in range(n):
            for b in range(n):
                for c in range(n):
                    assert lat[a][c] <= lat[a][b] + lat[b][c] + 1e-15, (
                        f"{topo.name}: d({a},{c}) > d({a},{b}) + d({b},{c})"
                    )


# ---------------------------------------------------------------------------
# degenerate fully-connected fabric == scalar-link evaluator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("evaluator_cls", [AnalyticEvaluator, DatabaseEvaluator])
def test_scalar_fabric_reproduces_scalar_evaluator_bit_for_bit(evaluator_cls):
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    platf = plat.with_fabric(scalar_fabric(plat))
    scalar = run_shisha(weights(layers), Trace(evaluator_cls(plat, layers)), "H3")
    fabric = run_shisha(weights(layers), Trace(evaluator_cls(platf, layers)), "H3")
    # identical trial sequence: every conf, throughput and wall timestamp
    assert scalar.result == fabric.result
    assert [(t.conf, t.throughput, t.t_wall) for t in scalar.trace.trials] == [
        (t.conf, t.throughput, t.t_wall) for t in fabric.trace.trials
    ]
    ev_s, ev_f = evaluator_cls(plat, layers), evaluator_cls(platf, layers)
    for trial in scalar.trace.trials:
        assert ev_s.stage_times(trial.conf) == ev_f.stage_times(trial.conf)


def test_scalar_fabric_equivalence_survives_latency_knob():
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    conf = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3"
    ).result.best_conf
    for lat in (1e-7, 1e-4, 1e-2):
        a = DatabaseEvaluator(plat.with_latency(lat), layers)
        b = DatabaseEvaluator(
            plat.with_fabric(scalar_fabric(plat)).with_latency(lat), layers
        )
        assert a.stage_times(conf) == pytest.approx(b.stage_times(conf), abs=1e-9)


def test_with_latency_rescales_fabric_links():
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
    )
    swept = plat.with_latency(1e-3)
    for link in swept.fabric.topology.links.values():
        assert link.latency == 1e-3
    # the knob must actually move routed prices: hops * latency
    assert swept.fabric.latency_ep(0, 7) == pytest.approx(4e-3)


# ---------------------------------------------------------------------------
# contention
# ---------------------------------------------------------------------------


def test_contention_monotonicity_adding_flows_never_speeds_up():
    for topo in _all_topologies():
        fab = uniform_fabric(topo, n_eps=8)
        flows = [
            Flow(0, 7, 1e6),
            Flow(1, 6, 2e6),
            Flow(2, 5, 5e5),
            Flow(3, 4, 1e6),
        ]
        for k in range(1, len(flows)):
            before = fab.flow_times(flows[:k])
            after = fab.flow_times(flows[: k + 1])
            for i in range(k):
                assert after[i] >= before[i] - 1e-15, (
                    f"{topo.name}: flow {i} sped up when flow {k} was added"
                )


def test_fair_share_halves_bandwidth_on_a_shared_link():
    fab = uniform_fabric(mesh2d(1, 2, bw=1e8, latency=0.0))
    solo = fab.transfer_time(0, 1, 1e6)
    shared = fab.transfer_time(0, 1, 1e6, background=[Flow(0, 1, 1e6)])
    assert solo == pytest.approx(1e6 / 1e8)
    assert shared == pytest.approx(2 * solo)


def test_memory_controller_hotspot_throttles_fan_in():
    topo = mesh2d(2, 4, bw=1e9, latency=0.0)
    free = uniform_fabric(topo)
    capped = uniform_fabric(mesh2d(2, 4, bw=1e9, latency=0.0), mc_bw=1e8)
    # three flows converging on node 0 over disjoint links
    flows = [Flow(1, 0, 1e6), Flow(4, 0, 1e6)]
    t_free = free.flow_times(flows)
    t_capped = capped.flow_times(flows)
    # link fair-share alone sees disjoint links (full bw each); the MC cap
    # makes the two flows share 1e8 at node 0
    assert t_free[0] == pytest.approx(1e6 / 1e9)
    assert t_capped[0] == pytest.approx(1e6 / (1e8 / 2))


def test_colocated_flow_is_free_and_restrict_preserves_routes():
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
    )
    fab = plat.fabric
    assert fab.flow_times([Flow(3, 3, 1e9)]) == [0.0]
    sub = fab.restrict([2, 5, 7])
    # local EP 0 is global EP 2: same node, same physical routes
    assert sub.node(0) == fab.node(2)
    assert sub.route_ep(0, 2) == fab.route_ep(2, 7)


# ---------------------------------------------------------------------------
# placement-aware tuning
# ---------------------------------------------------------------------------


def test_placement_candidate_prefers_fast_then_near_free_ep():
    layers = network_layers("synthnet")
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
    )
    conf = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3", n_stages=4
    ).result.best_conf
    slowest = 1
    cand = placement_candidate(conf, slowest, plat)
    assert cand is not None and cand not in conf.eps
    # FEPs are 0..3: a free FEP always outranks any free SEP
    free_feps = [e for e in range(4) if e not in conf.eps]
    if free_feps:
        assert cand in free_feps


def test_placement_moves_rescue_a_congested_bottleneck():
    """With the row-0 links congested, the placement-enabled tuner must find
    a strictly better schedule than boundary moves alone (same warm start)."""
    layers = network_layers("synthnet")
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
    )
    bg = tuple(
        Flow(src=s, dst=d, nbytes=2e6, nodes=True)
        for s, d in ((0, 1), (1, 2), (2, 3), (0, 3))
    )
    incumbent = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3"
    ).result.best_conf

    def retune(placement):
        ev = DatabaseEvaluator(plat, layers)
        ev.background_flows = bg
        return tune(incumbent, Trace(ev), placement=placement)

    gt = DatabaseEvaluator(plat, layers)
    gt.background_flows = bg
    boundary_only = gt.throughput(retune(False).best_conf)
    with_placement = gt.throughput(retune(True).best_conf)
    assert with_placement > boundary_only


def test_tune_without_placement_is_unchanged_by_the_flag_default():
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    a = run_shisha(weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3")
    b = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3", placement=False
    )
    assert a.result == b.result
    assert a.trace.n_trials == b.trace.n_trials


# ---------------------------------------------------------------------------
# evaluator-level contention
# ---------------------------------------------------------------------------


def test_background_flows_only_slow_stages_that_share_links():
    layers = network_layers("synthnet")
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
    )
    ev = AnalyticEvaluator(plat, layers)
    conf = run_shisha(weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3").result.best_conf
    base = ev.stage_times(conf)
    ev.background_flows = (Flow(0, 1, 1e7, nodes=True),)
    congested = ev.stage_times(conf)
    assert all(c >= b - 1e-15 for b, c in zip(base, congested))
    assert any(c > b for b, c in zip(base, congested)), (
        "congestion on a used link must show up in some stage time"
    )
    assert math.isclose(
        1.0 / max(congested), ev.throughput(conf), rel_tol=1e-12
    )
