"""Interconnect fabric invariants.

The fabric replaces the scalar link model everywhere, so four properties
are guarded hard:

  * routing determinism — routes are pure functions of the topology;
  * triangle inequality — routed latency is a metric on uniform fabrics;
  * degenerate equivalence — a fully-connected fabric built from the EP
    scalar link specs reproduces the pre-fabric evaluator bit-for-bit;
  * contention monotonicity — adding a flow never speeds up existing flows.

Plus the adaptive-routing and hop-priced-reconfiguration contracts
(metamorphic forms; the randomized versions live in
``tests/test_fabric_properties.py``):

  * adaptive routing strictly beats static on the congested mesh under an
    identical schedule, and never prices a flow set worse in total;
  * doubling every link bandwidth never increases an evaluated beat;
  * zero-byte activations make the topology choice irrelevant;
  * hop-priced placement trials reduce to the old flat ``reconfig_overhead``
    on a fully-connected fabric (the PR-1/2/3 regression pin) and charge
    multi-hop relocations more;
  * ``mc_bw="auto"`` turns the memory-controller hotspot on from EP
    ``mem_bw`` for the gem5-style preset platforms.
"""

import dataclasses
import math

import pytest

from repro.core import (
    AnalyticEvaluator,
    DatabaseEvaluator,
    Trace,
    paper_platform,
    weights,
)
from repro.core.heuristics import run_shisha
from repro.core.platform import table3_platform
from repro.core.tuner import placement_candidate, placement_reconfig_cost, tune
from repro.interconnect import (
    Flow,
    crossbar,
    fully_connected,
    hierarchical,
    mesh2d,
    ring,
    scalar_fabric,
    uniform_fabric,
)
from repro.models.cnn import network_layers


def _all_topologies():
    return [
        mesh2d(2, 4, bw=1e8, latency=1e-6),
        mesh2d(3, 3, bw=1e8, latency=1e-6),
        ring(8, bw=1e8, latency=1e-6),
        crossbar(8, bw=1e8, latency=1e-6),
        hierarchical(2, 4),
        fully_connected(8),
    ]


# ---------------------------------------------------------------------------
# routing determinism
# ---------------------------------------------------------------------------


def test_routing_is_deterministic_within_and_across_instances():
    for make in (
        lambda: mesh2d(3, 3, bw=1e8, latency=1e-6),
        lambda: ring(8, bw=1e8, latency=1e-6),
        lambda: crossbar(8, bw=1e8, latency=1e-6),
        lambda: hierarchical(2, 4),
    ):
        a, b = make(), make()
        for s in range(a.n_nodes):
            for d in range(a.n_nodes):
                r1 = a.route(s, d)
                assert r1 == a.route(s, d), "route changed between calls"
                assert r1 == b.route(s, d), "route differs across instances"


def test_routes_are_valid_walks():
    for topo in _all_topologies():
        for s in range(topo.n_nodes):
            for d in range(topo.n_nodes):
                route = topo.route(s, d)
                if s == d:
                    assert route == ()
                    continue
                node = s
                for (u, v) in route:
                    assert node in (u, v), f"route {route} breaks at {node}"
                    node = v if node == u else u
                assert node == d


def test_mesh_xy_route_has_manhattan_length():
    topo = mesh2d(3, 4, bw=1e8, latency=1e-6)
    for s in range(topo.n_nodes):
        for d in range(topo.n_nodes):
            (sx, sy), (dx, dy) = topo.coords[s], topo.coords[d]
            assert topo.hops(s, d) == abs(sx - dx) + abs(sy - dy)


# ---------------------------------------------------------------------------
# triangle inequality
# ---------------------------------------------------------------------------


def test_routed_latency_triangle_inequality():
    for topo in _all_topologies():
        n = topo.n_nodes
        lat = [[topo.path_latency(a, b) for b in range(n)] for a in range(n)]
        for a in range(n):
            for b in range(n):
                for c in range(n):
                    assert lat[a][c] <= lat[a][b] + lat[b][c] + 1e-15, (
                        f"{topo.name}: d({a},{c}) > d({a},{b}) + d({b},{c})"
                    )


# ---------------------------------------------------------------------------
# degenerate fully-connected fabric == scalar-link evaluator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("evaluator_cls", [AnalyticEvaluator, DatabaseEvaluator])
def test_scalar_fabric_reproduces_scalar_evaluator_bit_for_bit(evaluator_cls):
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    platf = plat.with_fabric(scalar_fabric(plat))
    scalar = run_shisha(weights(layers), Trace(evaluator_cls(plat, layers)), "H3")
    fabric = run_shisha(weights(layers), Trace(evaluator_cls(platf, layers)), "H3")
    # identical trial sequence: every conf, throughput and wall timestamp
    assert scalar.result == fabric.result
    assert [(t.conf, t.throughput, t.t_wall) for t in scalar.trace.trials] == [
        (t.conf, t.throughput, t.t_wall) for t in fabric.trace.trials
    ]
    ev_s, ev_f = evaluator_cls(plat, layers), evaluator_cls(platf, layers)
    for trial in scalar.trace.trials:
        assert ev_s.stage_times(trial.conf) == ev_f.stage_times(trial.conf)


def test_scalar_fabric_equivalence_survives_latency_knob():
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    conf = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3"
    ).result.best_conf
    for lat in (1e-7, 1e-4, 1e-2):
        a = DatabaseEvaluator(plat.with_latency(lat), layers)
        b = DatabaseEvaluator(
            plat.with_fabric(scalar_fabric(plat)).with_latency(lat), layers
        )
        assert a.stage_times(conf) == pytest.approx(b.stage_times(conf), abs=1e-9)


def test_with_latency_rescales_fabric_links():
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
    )
    swept = plat.with_latency(1e-3)
    for link in swept.fabric.topology.links.values():
        assert link.latency == 1e-3
    # the knob must actually move routed prices: hops * latency
    assert swept.fabric.latency_ep(0, 7) == pytest.approx(4e-3)


# ---------------------------------------------------------------------------
# contention
# ---------------------------------------------------------------------------


def test_contention_monotonicity_adding_flows_never_speeds_up():
    for topo in _all_topologies():
        fab = uniform_fabric(topo, n_eps=8)
        flows = [
            Flow(0, 7, 1e6),
            Flow(1, 6, 2e6),
            Flow(2, 5, 5e5),
            Flow(3, 4, 1e6),
        ]
        for k in range(1, len(flows)):
            before = fab.flow_times(flows[:k])
            after = fab.flow_times(flows[: k + 1])
            for i in range(k):
                assert after[i] >= before[i] - 1e-15, (
                    f"{topo.name}: flow {i} sped up when flow {k} was added"
                )


def test_fair_share_halves_bandwidth_on_a_shared_link():
    fab = uniform_fabric(mesh2d(1, 2, bw=1e8, latency=0.0))
    solo = fab.transfer_time(0, 1, 1e6)
    shared = fab.transfer_time(0, 1, 1e6, background=[Flow(0, 1, 1e6)])
    assert solo == pytest.approx(1e6 / 1e8)
    assert shared == pytest.approx(2 * solo)


def test_memory_controller_hotspot_throttles_fan_in():
    topo = mesh2d(2, 4, bw=1e9, latency=0.0)
    free = uniform_fabric(topo)
    capped = uniform_fabric(mesh2d(2, 4, bw=1e9, latency=0.0), mc_bw=1e8)
    # three flows converging on node 0 over disjoint links
    flows = [Flow(1, 0, 1e6), Flow(4, 0, 1e6)]
    t_free = free.flow_times(flows)
    t_capped = capped.flow_times(flows)
    # link fair-share alone sees disjoint links (full bw each); the MC cap
    # makes the two flows share 1e8 at node 0
    assert t_free[0] == pytest.approx(1e6 / 1e9)
    assert t_capped[0] == pytest.approx(1e6 / (1e8 / 2))


def test_colocated_flow_is_free_and_restrict_preserves_routes():
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
    )
    fab = plat.fabric
    assert fab.flow_times([Flow(3, 3, 1e9)]) == [0.0]
    sub = fab.restrict([2, 5, 7])
    # local EP 0 is global EP 2: same node, same physical routes
    assert sub.node(0) == fab.node(2)
    assert sub.route_ep(0, 2) == fab.route_ep(2, 7)


# ---------------------------------------------------------------------------
# placement-aware tuning
# ---------------------------------------------------------------------------


def test_placement_candidate_prefers_fast_then_near_free_ep():
    layers = network_layers("synthnet")
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
    )
    conf = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3", n_stages=4
    ).result.best_conf
    slowest = 1
    cand = placement_candidate(conf, slowest, plat)
    assert cand is not None and cand not in conf.eps
    # FEPs are 0..3: a free FEP always outranks any free SEP
    free_feps = [e for e in range(4) if e not in conf.eps]
    if free_feps:
        assert cand in free_feps


def test_placement_moves_rescue_a_congested_bottleneck():
    """With the row-0 links congested, the placement-enabled tuner must find
    a strictly better schedule than boundary moves alone (same warm start)."""
    layers = network_layers("synthnet")
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
    )
    bg = tuple(
        Flow(src=s, dst=d, nbytes=2e6, nodes=True)
        for s, d in ((0, 1), (1, 2), (2, 3), (0, 3))
    )
    incumbent = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3"
    ).result.best_conf

    def retune(placement):
        ev = DatabaseEvaluator(plat, layers)
        ev.background_flows = bg
        return tune(incumbent, Trace(ev), placement=placement)

    gt = DatabaseEvaluator(plat, layers)
    gt.background_flows = bg
    boundary_only = gt.throughput(retune(False).best_conf)
    with_placement = gt.throughput(retune(True).best_conf)
    assert with_placement > boundary_only


def test_tune_without_placement_is_unchanged_by_the_flag_default():
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    a = run_shisha(weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3")
    b = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3", placement=False
    )
    assert a.result == b.result
    assert a.trace.n_trials == b.trace.n_trials


# ---------------------------------------------------------------------------
# adaptive congestion-aware routing
# ---------------------------------------------------------------------------


def _congestor():
    return tuple(
        Flow(src=s, dst=d, nbytes=2e6, nodes=True)
        for s, d in ((0, 1), (1, 2), (2, 3), (0, 3))
    )


def test_adaptive_routing_strictly_beats_static_on_the_congested_mesh():
    """The fig9_adaptive acceptance cell: same schedule, same flows — the
    routing layer alone must lower the beat by detouring around the
    hammered row-0 links."""
    layers = network_layers("synthnet")
    fab = uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
    plat_s = paper_platform(8).with_fabric(fab)
    plat_a = paper_platform(8).with_fabric(fab.with_routing("adaptive"))
    conf = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat_s, layers)), "H3"
    ).result.best_conf
    beats = {}
    for name, plat in (("static", plat_s), ("adaptive", plat_a)):
        ev = DatabaseEvaluator(plat, layers)
        ev.background_flows = _congestor()
        beats[name] = max(ev.stage_times(conf))
    assert beats["adaptive"] < beats["static"]


def test_adaptive_rerouting_relieves_a_congested_row():
    fab = uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6), mc_bw=None)
    flows = [Flow(1, 2, 1e6)] + list(_congestor())
    static_t = fab.flow_times(flows)
    adaptive = fab.with_routing("adaptive")
    adaptive_t = adaptive.flow_times(flows)
    assert sum(adaptive_t) < sum(static_t)
    # some flow detoured off the hammered row-0 links: their total load drops
    row0 = {(0, 1), (1, 2), (2, 3)}

    def row0_load(routes):
        return sum(1 for r in routes for k in r if k in row0)

    assert row0_load(adaptive.route_flows(flows)) < row0_load(fab.route_flows(flows))


def test_adaptive_assignment_insensitive_to_flow_list_order():
    """Shisha-lint contract audit: adaptive routing is a function of the
    flow *multiset*, so permuting the caller's flow list must permute the
    per-flow times identically — no dict/set iteration-order tie-break
    may leak the assembly order into the assignment."""
    fab = uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6), mc_bw=None)
    adaptive = fab.with_routing("adaptive")
    flows = [Flow(1, 2, 1e6)] + list(_congestor()) + [Flow(1, 2, 1e6)]
    times = adaptive.flow_times(flows)
    perm = [4, 0, 5, 2, 1, 3]
    times_perm = adaptive.flow_times([flows[i] for i in perm])
    assert times_perm == [times[i] for i in perm]
    # and the seeded rerun is bit-for-bit: same fabric, same flows, twice
    assert adaptive.flow_times(flows) == times


def test_express_links_invisible_to_xy_but_exploited_by_adaptive():
    topo = mesh2d(2, 4, bw=1e8, latency=1e-6, express_bw=2e8)
    assert (0, 2) in topo.links  # the express channel exists ...
    assert topo.route(0, 2) == ((0, 1), (1, 2))  # ... but XY never takes it
    fab = uniform_fabric(topo, mc_bw=None).with_routing("adaptive")
    route = fab.route_flows([Flow(0, 2, 1e6)])[0]
    assert route == ((0, 2),)  # one express hop: cheaper in latency and bw


def test_heterogeneous_preset_links():
    xbar = crossbar(4, bw=1e8, latency=1e-6, port_bws=[1e8, 1e8, 2.5e7, 1e8])
    assert xbar.link(2, 4).bw == 2.5e7 and xbar.link(0, 4).bw == 1e8
    rg = ring(4, bw=1e8, latency=1e-6, segment_bws=[1e8, 1e8, 1e8, 2.5e7])
    assert rg.link(3, 0).bw == 2.5e7 and rg.link(0, 1).bw == 1e8
    hier = hierarchical(2, 2)
    assert hier.link(0, 1).bw > hier.link(0, 2).bw  # intra faster than inter


def test_doubling_every_link_bandwidth_never_increases_the_beat():
    layers = network_layers("synthnet")
    topo = mesh2d(2, 4, bw=1e8, latency=1e-6)
    conf = run_shisha(
        weights(layers),
        Trace(DatabaseEvaluator(paper_platform(8).with_fabric(uniform_fabric(topo)), layers)),
        "H3",
    ).result.best_conf
    for routing in ("static", "adaptive"):
        for factor in (2.0, 4.0):
            beats = []
            for t in (topo, topo.with_scaled_bw(factor)):
                ev = DatabaseEvaluator(
                    paper_platform(8).with_fabric(uniform_fabric(t, routing=routing)),
                    layers,
                )
                ev.background_flows = _congestor()
                beats.append(max(ev.stage_times(conf)))
            assert beats[1] <= beats[0] + 1e-15, (
                f"{routing}: beat rose from {beats[0]} to {beats[1]} at {factor}x bw"
            )


def test_zero_byte_activations_make_topology_choice_irrelevant():
    layers = [
        dataclasses.replace(l, act_bytes=0.0) for l in network_layers("synthnet")
    ]
    ref_plat = paper_platform(8).with_latency(0.0)
    conf = run_shisha(
        weights(layers), Trace(AnalyticEvaluator(ref_plat, layers)), "H3"
    ).result.best_conf
    ref = AnalyticEvaluator(ref_plat, layers).stage_times(conf)
    fabrics = [
        uniform_fabric(mesh2d(2, 4, bw=1e8, latency=0.0)),
        uniform_fabric(ring(8, bw=1e8, latency=0.0)),
        uniform_fabric(crossbar(8, bw=1e8, latency=0.0), n_eps=8),
        uniform_fabric(fully_connected(8, latency=0.0)),
        uniform_fabric(mesh2d(2, 4, bw=1e8, latency=0.0), routing="adaptive"),
    ]
    for fab in fabrics:
        plat = paper_platform(8).with_fabric(fab)
        assert AnalyticEvaluator(plat, layers).stage_times(conf) == ref, (
            f"zero-byte transfers still depend on topology {fab.topology.name}"
        )


# ---------------------------------------------------------------------------
# hop-priced placement reconfiguration
# ---------------------------------------------------------------------------


def test_hop_priced_placement_reduces_to_flat_cost_on_fully_connected():
    """Regression pin for PR-1/2/3: on the degenerate fabric every route is
    one hop, so placement trials must charge exactly the flat overhead —
    the whole trace's wall reproduces the pre-hop-pricing arithmetic."""
    layers = network_layers("synthnet")
    base = paper_platform(8)
    plat = base.with_fabric(scalar_fabric(base))
    trace = Trace(DatabaseEvaluator(plat, layers))
    run_shisha(weights(layers), trace, "H3", placement=True)
    ev = DatabaseEvaluator(plat, layers)
    wall = 0.0
    for trial in trace.trials:
        times = ev.stage_times(trial.conf)
        wall += trace.reconfig_overhead + sum(times) + trace.measure_batches * max(times)
        assert trial.t_wall == pytest.approx(wall, rel=1e-12)
    # and the unit-level statement: every relocation is priced flat
    conf = trace.trials[-1].conf
    for ep in range(plat.n_eps):
        if ep not in conf.eps:
            assert placement_reconfig_cost(trace, conf, 0, ep) == trace.reconfig_overhead


def test_hop_priced_placement_charges_multi_hop_relocations_more():
    layers = network_layers("synthnet")
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
    )
    trace = Trace(DatabaseEvaluator(plat, layers))
    conf = run_shisha(weights(layers), trace, "H3", n_stages=4).result.best_conf
    stage = 0
    src = conf.eps[stage]
    a, b = conf.boundaries()[stage]
    wbytes = sum(layers[i].weight_bytes for i in range(a, b))
    assert wbytes > 0
    flat = trace.reconfig_overhead
    free = [e for e in range(plat.n_eps) if e not in conf.eps]
    costs = {e: placement_reconfig_cost(trace, conf, stage, e) for e in free}
    for e, cost in costs.items():
        hops = len(plat.fabric.route_ep(src, e))
        expected = flat + (hops - 1) * (wbytes / 1e8 + 1e-6)
        assert cost == pytest.approx(expected, rel=1e-12)
        if hops > 1:
            assert cost > flat


def test_placement_tuning_prefers_near_over_far_when_throughput_ties():
    """The hop price is charged to the trace: a placement-enabled tune on a
    mesh accumulates strictly more wall than the same trials priced flat
    whenever any relocation crossed more than one hop."""
    layers = network_layers("synthnet")
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
    )
    ev = DatabaseEvaluator(plat, layers)
    ev.background_flows = _congestor()
    trace = Trace(ev)
    run_shisha(weights(layers), trace, "H3", placement=True)
    ev2 = DatabaseEvaluator(plat, layers)
    ev2.background_flows = _congestor()
    flat_wall = sum(
        trace.reconfig_overhead
        + sum(ev2.stage_times(t.conf))
        + trace.measure_batches * max(ev2.stage_times(t.conf))
        for t in trace.trials
    )
    assert trace.wall >= flat_wall - 1e-12


# ---------------------------------------------------------------------------
# memory-controller hotspot defaults
# ---------------------------------------------------------------------------


def test_mc_bw_defaults_from_ep_mem_bw_on_gem5_presets():
    plat = table3_platform("C2").with_fabric(uniform_fabric(fully_connected(4)))
    # paper Table 1: HBM 40 GB/s on the FEPs, DDR 20 GB/s on the SEPs
    assert plat.fabric.mc_bw == {0: 40e9, 1: 40e9, 2: 20e9, 3: 20e9}
    # three flows fanning into SEP node 3 over disjoint 25 GB/s links: the
    # link fair-share alone would give each the full link, but the DDR
    # controller cap (20e9 / 3) must bind
    flows = [Flow(i, 3, 1e8) for i in range(3)]
    capped = plat.fabric.flow_times(flows)
    free = uniform_fabric(fully_connected(4), mc_bw=None).flow_times(flows)
    assert capped[0] == pytest.approx(1e8 / (20e9 / 3) + 1e-7)
    assert free[0] == pytest.approx(1e8 / 25e9 + 1e-7)
    assert capped[0] > free[0]


def test_scalar_fabric_stays_exempt_from_auto_mc_bw():
    base = table3_platform("C2")
    assert base.with_fabric(scalar_fabric(base)).fabric.mc_bw is None


def test_unattached_auto_fabric_prices_uncapped():
    fab = uniform_fabric(fully_connected(4))  # "auto", never attached
    flows = [Flow(i, 3, 1e8) for i in range(3)]
    assert fab.flow_times(flows) == uniform_fabric(
        fully_connected(4), mc_bw=None
    ).flow_times(flows)


# ---------------------------------------------------------------------------
# evaluator-level contention
# ---------------------------------------------------------------------------


def test_background_flows_only_slow_stages_that_share_links():
    layers = network_layers("synthnet")
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
    )
    ev = AnalyticEvaluator(plat, layers)
    conf = run_shisha(weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3").result.best_conf
    base = ev.stage_times(conf)
    ev.background_flows = (Flow(0, 1, 1e7, nodes=True),)
    congested = ev.stage_times(conf)
    assert all(c >= b - 1e-15 for b, c in zip(base, congested))
    assert any(c > b for b, c in zip(base, congested)), (
        "congestion on a used link must show up in some stage time"
    )
    assert math.isclose(
        1.0 / max(congested), ev.throughput(conf), rel_tol=1e-12
    )
