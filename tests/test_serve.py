"""Tests for the repro.serve subsystem (traffic, simulator, autotuner, tenancy)."""

import math

import pytest

from repro.core import DatabaseEvaluator, Trace, paper_platform, weights
from repro.core.heuristics import run_shisha
from repro.models.cnn import network_layers
from repro.serve import (
    ContinuousShisha,
    DiurnalTraffic,
    DriftDetector,
    MMPPTraffic,
    PoissonTraffic,
    ReplayTraffic,
    ServingSimulator,
    drifted_platform,
    partition_eps,
    percentile,
    interarrival_cv2,
    slo_violation_rate,
    subplatform,
    tune_batch_policy,
)
from repro.pipeline.hetero import EPDerates

# ---------------------------------------------------------------------------
# shared fixture: tuned synthnet pipeline on the paper's 8-EP platform
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tuned():
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    ev = DatabaseEvaluator(plat, layers)
    sh = run_shisha(weights(layers), Trace(ev), "H3")
    return {
        "layers": layers,
        "plat": plat,
        "ev": ev,
        "conf": sh.result.best_conf,
        "cap": sh.result.best_throughput,
    }


def _slo(t):
    return 3.0 * sum(t["ev"].stage_times(t["conf"]))


# ---------------------------------------------------------------------------
# traffic: seeded determinism
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "gen",
    [
        PoissonTraffic(rate=5.0, seed=3),
        MMPPTraffic(rate_low=2.0, rate_high=20.0, seed=3),
        DiurnalTraffic(base_rate=1.0, peak_rate=10.0, period=30.0, seed=3),
    ],
    ids=["poisson", "mmpp", "diurnal"],
)
def test_traffic_deterministic_and_sorted(gen):
    a = gen.arrivals(60.0)
    b = gen.arrivals(60.0)
    assert a == b  # same seed => bit-identical
    assert a == sorted(a)
    assert all(0.0 <= t < 60.0 for t in a)
    assert len(a) > 10


def test_traffic_seed_matters():
    a = PoissonTraffic(rate=5.0, seed=0).arrivals(60.0)
    b = PoissonTraffic(rate=5.0, seed=1).arrivals(60.0)
    assert a != b


def test_replay_roundtrip(tmp_path):
    gen = MMPPTraffic(rate_low=2.0, rate_high=20.0, seed=9)
    rec = ReplayTraffic.record(gen, 30.0)
    assert rec.arrivals(30.0) == gen.arrivals(30.0)
    assert rec.arrivals(10.0) == [t for t in gen.arrivals(30.0) if t < 10.0]
    p = rec.save(tmp_path / "trace.json")
    assert ReplayTraffic.load(p).arrivals(30.0) == rec.arrivals(30.0)


# ---------------------------------------------------------------------------
# simulator: conservation, SLO accounting, determinism
# ---------------------------------------------------------------------------


def test_queue_conservation_under_overload(tuned):
    # 2x overload so the run ends with requests queued and in flight
    traffic = PoissonTraffic(rate=2.0 * tuned["cap"], seed=5)
    sim = ServingSimulator(tuned["ev"], tuned["conf"], slo=_slo(tuned))
    res = sim.run(traffic.arrivals(30.0), 30.0)
    assert res.n_arrived == len(traffic.arrivals(30.0))
    assert res.n_arrived == res.n_completed + res.n_in_flight + res.n_queued
    assert res.n_queued > 0  # overload actually built a backlog


def test_simulator_is_deterministic(tuned):
    traffic = PoissonTraffic(rate=0.5 * tuned["cap"], seed=5)
    runs = []
    for _ in range(2):
        sim = ServingSimulator(tuned["ev"], tuned["conf"], slo=_slo(tuned))
        runs.append(sim.run(traffic.arrivals(60.0), 60.0))
    assert runs[0].latencies == runs[1].latencies
    assert runs[0].occupancy == runs[1].occupancy


def test_underload_completes_with_zero_violations(tuned):
    traffic = PoissonTraffic(rate=0.4 * tuned["cap"], seed=5)
    sim = ServingSimulator(tuned["ev"], tuned["conf"], slo=_slo(tuned))
    res = sim.run(traffic.arrivals(60.0), 60.0)
    assert res.n_completed >= 0.9 * res.n_arrived
    assert res.slo_rate == 0.0
    assert all(0.0 <= v <= 1.0 for v in res.occupancy.values())


def test_slo_accounting_monotone_in_threshold(tuned):
    traffic = PoissonTraffic(rate=0.9 * tuned["cap"], seed=5)
    lats = None
    rates = []
    for slo_mult in (4.0, 2.0, 1.0, 0.5):
        sim = ServingSimulator(
            tuned["ev"], tuned["conf"], slo=slo_mult * sum(tuned["ev"].stage_times(tuned["conf"]))
        )
        res = sim.run(traffic.arrivals(40.0), 40.0)
        if lats is None:
            lats = res.latencies
        assert res.latencies == lats  # SLO threshold never affects dynamics
        rates.append(res.slo_rate)
    assert rates == sorted(rates)  # stricter SLO => violation rate can only grow


def test_slo_violation_rate_helper():
    lats = [0.1, 0.5, 1.0, 2.0]
    assert slo_violation_rate(lats, 10.0) == 0.0
    assert slo_violation_rate(lats, 0.05) == 1.0
    r1, r2 = slo_violation_rate(lats, 0.6), slo_violation_rate(lats, 0.4)
    assert r2 >= r1


def test_percentile_nearest_rank():
    vals = [1.0, 2.0, 3.0, 4.0]
    assert percentile(vals, 0.50) == 2.0
    assert percentile(vals, 0.99) == 4.0
    assert math.isnan(percentile([], 0.5))


# ---------------------------------------------------------------------------
# continuous Shisha: drift handling
# ---------------------------------------------------------------------------


def _tuner(tuned, **kw):
    return ContinuousShisha(
        tuned["plat"],
        tuned["layers"],
        make_evaluator=lambda p: DatabaseEvaluator(p, tuned["layers"]),
        **kw,
    )


def test_no_drift_no_retune(tuned):
    traffic = PoissonTraffic(rate=0.5 * tuned["cap"], seed=5)
    sim = ServingSimulator(tuned["ev"], tuned["conf"], slo=_slo(tuned), autotuner=_tuner(tuned))
    res = sim.run(traffic.arrivals(60.0), 60.0)
    assert res.reconfigs == []  # intrinsic imbalance must not trigger a re-tune


def test_retune_fires_once_per_drift_state(tuned):
    traffic = PoissonTraffic(rate=0.5 * tuned["cap"], seed=5)
    times = tuned["ev"].stage_times(tuned["conf"])
    bad_ep = tuned["conf"].eps[max(range(tuned["conf"].depth), key=times.__getitem__)]
    sim = ServingSimulator(tuned["ev"], tuned["conf"], slo=_slo(tuned), autotuner=_tuner(tuned))
    sim.schedule_slowdown(20.0, bad_ep, 3.0)
    res = sim.run(traffic.arrivals(250.0), 250.0)
    assert len(res.reconfigs) == 1
    assert res.reconfigs[0]["kind"] == "slowdown"


def test_dropout_recovery_at_least_90_percent(tuned):
    """Regression: continuous re-tuning recovers >=90% of pre-fault throughput."""
    traffic = PoissonTraffic(rate=0.5 * tuned["cap"], seed=1)
    times = tuned["ev"].stage_times(tuned["conf"])
    bad_ep = tuned["conf"].eps[max(range(tuned["conf"].depth), key=times.__getitem__)]

    results = {}
    for arm in ("static", "continuous"):
        tuner = _tuner(tuned) if arm == "continuous" else None
        sim = ServingSimulator(tuned["ev"], tuned["conf"], slo=_slo(tuned), autotuner=tuner)
        sim.schedule_dropout(60.0, bad_ep)
        results[arm] = sim.run(traffic.arrivals(300.0), 300.0)

    cont = results["continuous"]
    assert len(cont.reconfigs) == 1
    rc = cont.reconfigs[0]
    assert rc["kind"] == "dropout"
    assert rc["model_throughput"] >= 0.9 * tuned["cap"]
    assert cont.n_completed > results["static"].n_completed
    assert cont.throughput_rps > results["static"].throughput_rps


def test_revival_retune_reclaims_revived_ep(tuned):
    """A dead EP coming back triggers a recovery re-seed onto it."""
    traffic = PoissonTraffic(rate=0.5 * tuned["cap"], seed=5)
    times = tuned["ev"].stage_times(tuned["conf"])
    bad_ep = tuned["conf"].eps[max(range(tuned["conf"].depth), key=times.__getitem__)]
    sim = ServingSimulator(tuned["ev"], tuned["conf"], slo=_slo(tuned), autotuner=_tuner(tuned))
    sim.schedule_dropout(20.0, bad_ep)
    sim.schedule_revival(200.0, bad_ep)
    res = sim.run(traffic.arrivals(400.0), 400.0)
    kinds = [r["kind"] for r in res.reconfigs]
    assert kinds == ["dropout", "recovery"]
    assert res.reconfigs[1]["model_throughput"] >= 0.95 * tuned["cap"]


def test_recovery_retune_reclaims_recovered_ep(tuned):
    """When a derate eases back, continuous Shisha re-seeds to reclaim it."""
    traffic = PoissonTraffic(rate=0.5 * tuned["cap"], seed=5)
    times = tuned["ev"].stage_times(tuned["conf"])
    bad_ep = tuned["conf"].eps[max(range(tuned["conf"].depth), key=times.__getitem__)]
    sim = ServingSimulator(tuned["ev"], tuned["conf"], slo=_slo(tuned), autotuner=_tuner(tuned))
    sim.schedule_slowdown(20.0, bad_ep, 3.0)
    sim.schedule_slowdown(200.0, bad_ep, 1.0 / 3.0)  # back to full speed
    res = sim.run(traffic.arrivals(400.0), 400.0)
    kinds = [r["kind"] for r in res.reconfigs]
    assert kinds == ["slowdown", "recovery"]
    # the recovery re-tune restores (model) capacity to the pre-fault level
    assert res.reconfigs[1]["model_throughput"] >= 0.95 * tuned["cap"]


def test_depth_reducing_reconfig_with_in_flight_batches(tuned):
    """Regression: pre-reconfig _DONE events must not touch the new stages.

    A retune that shrinks the pipeline while batches are in flight used to
    either crash (stale stage index past the new depth) or prematurely
    complete a new batch (stale token matching a fresh stage).
    """
    from repro.core import PipelineConfig
    from repro.serve import Retune

    one_stage = PipelineConfig(stages=(len(tuned["layers"]),), eps=(0,))

    class CollapseTuner:
        def __init__(self):
            self.fired = False

        def observe(self, t, conf, observed, drift, dead):
            if self.fired:
                return None
            self.fired = True
            return Retune(
                conf=one_stage,
                tuning_cost=0.5,
                downtime=0.01,
                kind="slowdown",
                model_throughput=1.0,
                tune_result=None,
            )

    # 2x overload keeps every stage busy, so batches are in flight when the
    # 8-stage conf collapses to 1 stage
    traffic = PoissonTraffic(rate=2.0 * tuned["cap"], seed=5)
    sim = ServingSimulator(tuned["ev"], tuned["conf"], slo=_slo(tuned), autotuner=CollapseTuner())
    res = sim.run(traffic.arrivals(30.0), 30.0)  # used to raise IndexError
    assert len(res.reconfigs) == 1
    assert res.n_arrived == res.n_completed + res.n_in_flight + res.n_queued
    assert res.n_completed > 0


def test_retuned_conf_avoids_dead_ep(tuned):
    tuner = _tuner(tuned)
    drift = EPDerates(factors=(1.0,) * tuned["plat"].n_eps)
    dead = frozenset({tuned["conf"].eps[0]})
    observed = [math.inf if tuned["conf"].eps[s] in dead else 0.1 for s in range(tuned["conf"].depth)]
    retune = tuner.observe(1.0, tuned["conf"], observed, drift, dead)
    assert retune is not None and retune.kind == "dropout"
    assert not set(retune.conf.eps) & dead
    assert retune.conf.n_layers == len(tuned["layers"])


def test_drift_detector_tolerates_short_factors_tuple(tuned):
    """Regression: a factors tuple shorter than the platform's EP count must
    not raise IndexError — missing entries mean 'no derate observed', the
    same contract drifted_platform already honours."""
    det = DriftDetector()
    conf = tuned["conf"]  # references EP indices well past 0
    short = EPDerates(factors=(1.0,))
    assert det.detect(conf, [0.1] * conf.depth, short, frozenset()) is None
    # a derate that *is* covered still fires
    short_hot = EPDerates(factors=(3.0,))
    if 0 in conf.eps:
        drift = det.detect(conf, [0.1] * conf.depth, short_hot, frozenset())
        assert drift is not None and drift.kind == "slowdown"


def test_retune_carries_batch_policy_when_enabled(tuned):
    tuner = ContinuousShisha(
        tuned["plat"],
        tuned["layers"],
        make_evaluator=lambda p: DatabaseEvaluator(p, tuned["layers"]),
        slo=_slo(tuned),
        batch_policy_search=True,
    )
    drift = EPDerates(factors=(1.0,) * tuned["plat"].n_eps)
    dead = frozenset({tuned["conf"].eps[0]})
    observed = [
        math.inf if tuned["conf"].eps[s] in dead else 0.1
        for s in range(tuned["conf"].depth)
    ]
    retune = tuner.observe(1.0, tuned["conf"], observed, drift, dead)
    assert retune is not None
    assert retune.batch_policy is not None
    assert len(retune.batch_policy) == retune.conf.depth
    assert all(b >= 1 for b in retune.batch_policy)


def test_tune_batch_policy_charges_trace_and_respects_slo(tuned):
    trace = Trace(tuned["ev"])
    w0 = trace.wall
    policy = tune_batch_policy(trace, tuned["conf"], slo=100.0, max_batch_cap=8)
    assert len(policy) == tuned["conf"].depth
    # a wide-open SLO lets every stage amortise up to the cap, and the knob
    # exploration is charged to the trace like any Algorithm 2 move
    assert policy == (8,) * tuned["conf"].depth
    assert trace.wall > w0
    # an impossible SLO admits no batching and charges nothing
    free = Trace(tuned["ev"])
    assert tune_batch_policy(free, tuned["conf"], slo=1e-9) == (1,) * tuned["conf"].depth
    assert free.wall == 0.0


def test_per_stage_batch_policy_drives_simulator(tuned):
    # 2x overload keeps queues full, so the amortised batch beat (efficiency
    # < 1 => b requests in less than b beats) must raise completions
    traffic = PoissonTraffic(rate=2.0 * tuned["cap"], seed=5)
    flat = ServingSimulator(tuned["ev"], tuned["conf"], slo=_slo(tuned), max_batch=1)
    res_flat = flat.run(traffic.arrivals(40.0), 40.0)
    boosted = ServingSimulator(
        tuned["ev"],
        tuned["conf"],
        slo=_slo(tuned),
        max_batch=1,  # overridden per stage below
        batch_policy=(4,) * tuned["conf"].depth,
    )
    res_boost = boosted.run(traffic.arrivals(40.0), 40.0)
    assert res_boost.n_completed > res_flat.n_completed
    # a per-stage policy of all-1 is exactly the unbatched simulator
    single = ServingSimulator(
        tuned["ev"],
        tuned["conf"],
        slo=_slo(tuned),
        max_batch=4,
        batch_policy=(1,) * tuned["conf"].depth,
    )
    res_single = single.run(traffic.arrivals(40.0), 40.0)
    assert res_single.latencies == res_flat.latencies


def test_drifted_platform_model(tuned):
    plat = tuned["plat"]
    f = [1.0] * plat.n_eps
    f[2] = 2.0
    model = drifted_platform(plat, EPDerates(factors=tuple(f)), dead=frozenset({5}))
    assert model.eps[2].flops == pytest.approx(plat.eps[2].flops / 2.0)
    assert model.ranked()[-1] == 5  # dead EP buried at the bottom of H_e
    assert model.n_eps == plat.n_eps  # indices stay comparable


# ---------------------------------------------------------------------------
# multi-tenancy: partitioning
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["interleaved", "blocked", "proportional"])
def test_partitions_are_disjoint_and_cover(strategy):
    plat = paper_platform(8)
    parts = partition_eps(plat, 3, strategy)
    seen = [ep for p in parts for ep in p]
    assert sorted(seen) == list(range(8))
    assert all(len(p) >= 1 for p in parts)


def test_interleaved_shares_feps_fairly():
    plat = paper_platform(8)  # 4 FEPs, 4 SEPs
    parts = partition_eps(plat, 2, "interleaved")
    feps = set(plat.feps)
    assert all(len(set(p) & feps) == 2 for p in parts)


def test_blocked_gives_tenant0_the_fast_block():
    plat = paper_platform(8)
    parts = partition_eps(plat, 2, "blocked")
    assert set(parts[0]) == set(plat.ranked()[:4])


def test_subplatform_reindexes():
    plat = paper_platform(8)
    sub = subplatform(plat, (6, 1), "sub")
    assert sub.n_eps == 2
    assert sub.eps[0].name == plat.eps[6].name
    assert sub.eps[1].name == plat.eps[1].name


def test_blocked_partition_skewed_shares_keeps_all_tenants():
    """Regression: heavily skewed shares must rebalance, not starve a tenant."""
    plat = paper_platform(8)
    for shares in ([1000.0, 1.0, 1.0], [1e6, 1e-6], [0.001, 5.0, 0.001, 5.0]):
        parts = partition_eps(plat, len(shares), "blocked", shares=shares)
        assert sorted(ep for p in parts for ep in p) == list(range(8))
        assert all(len(p) >= 1 for p in parts)
    # the dominant share still gets the biggest block
    parts = partition_eps(plat, 3, "blocked", shares=[1000.0, 1.0, 1.0])
    assert len(parts[0]) > len(parts[1])


# ---------------------------------------------------------------------------
# MMPP calibration (ReplayTraffic.fit_mmpp)
# ---------------------------------------------------------------------------


def test_fit_mmpp_round_trips_a_synthetic_mmpp():
    """Moments fit on a recorded MMPP recovers rates and sojourns."""
    true = MMPPTraffic(rate_low=4.0, rate_high=40.0, mean_calm=6.0, mean_burst=1.5, seed=3)
    trace = ReplayTraffic.record(true, 3000.0)
    assert interarrival_cv2(trace.times) > 1.5  # visibly bursty
    fit = trace.fit_mmpp(horizon=3000.0)
    assert fit.rate_low == pytest.approx(true.rate_low, rel=0.35)
    assert fit.rate_high == pytest.approx(true.rate_high, rel=0.35)
    assert fit.mean_calm == pytest.approx(true.mean_calm, rel=0.6)
    assert fit.mean_burst == pytest.approx(true.mean_burst, rel=0.6)
    # the calibrated process reproduces the recording's mean rate
    n_true = len(trace.times)
    n_fit = len(fit.arrivals(3000.0))
    assert n_fit == pytest.approx(n_true, rel=0.25)


def test_fit_mmpp_degenerates_on_poisson_traffic():
    """A memoryless trace (CV^2 ~ 1) must fit to a flat two-state process."""
    trace = ReplayTraffic.record(PoissonTraffic(rate=10.0, seed=1), 2000.0)
    assert interarrival_cv2(trace.times) == pytest.approx(1.0, abs=0.1)
    fit = trace.fit_mmpp(horizon=2000.0)
    assert fit.rate_low == fit.rate_high == pytest.approx(10.0, rel=0.1)


def test_fit_mmpp_is_deterministic_and_handles_tiny_traces():
    trace = ReplayTraffic.record(
        MMPPTraffic(rate_low=2.0, rate_high=30.0, seed=7), 500.0
    )
    a, b = trace.fit_mmpp(horizon=500.0), trace.fit_mmpp(horizon=500.0)
    assert a == b
    empty = ReplayTraffic(times=())
    assert empty.fit_mmpp(horizon=10.0).rate_low == 0.0
    short = ReplayTraffic(times=(0.5, 1.0, 1.5))
    flat = short.fit_mmpp(horizon=2.0)
    assert flat.rate_low == flat.rate_high == pytest.approx(1.5)


def test_fit_mmpp_default_horizon_keeps_every_arrival():
    """Regression: T derived from the last timestamp must not drop it."""
    flat = ReplayTraffic(times=(1.0, 2.0, 3.0, 4.0, 5.0)).fit_mmpp()
    assert flat.rate_low == flat.rate_high == pytest.approx(1.0)
    # an explicit horizon is an exclusive bound: later arrivals are cut
    prefix = ReplayTraffic(times=(1.0, 2.0, 3.0, 4.0, 5.0, 50.0)).fit_mmpp(horizon=5.5)
    assert prefix.rate_low == prefix.rate_high == pytest.approx(5 / 5.5, rel=0.01)


def test_placement_retune_never_trials_a_dead_ep():
    """Regression: a dropout re-tune with placement moves must not relocate
    a stage onto the buried dead EP — its near-zero sentinel specs would
    charge an absurd trial to the exploration window."""
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    tuner = ContinuousShisha(
        plat,
        layers,
        make_evaluator=lambda p: DatabaseEvaluator(p, layers),
        placement=True,
        measure_batches=2,
        alpha=4,
    )
    retune = tuner.force_retune(
        0.0, EPDerates(factors=(1.0,) * 8), frozenset({0}), kind="dropout"
    )
    # the exploration wall must be sane (a dead-EP trial would be ~1e19 s)
    assert retune.tuning_cost < 1e3
    assert 0 not in retune.conf.eps


# ---------------------------------------------------------------------------
# fabric metamorphics on the serving layer
# ---------------------------------------------------------------------------


def _mesh_serving(bw_scale: float, routing: str = "static"):
    """A tuned synthnet lane on a 2x4-mesh fabric, congested by co-tenant
    flows, with every link bandwidth scaled by ``bw_scale``."""
    from repro.interconnect import Flow, mesh2d, uniform_fabric

    layers = network_layers("synthnet")
    topo = mesh2d(2, 4, bw=1e8, latency=1e-6).with_scaled_bw(bw_scale)
    plat = paper_platform(8).with_fabric(uniform_fabric(topo, routing=routing))
    ev = DatabaseEvaluator(plat, layers)
    conf = run_shisha(weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3").result.best_conf
    sim = ServingSimulator(ev, conf, slo=1.0)
    sim.set_background_flows(
        tuple(Flow(s, d, 2e6, nodes=True) for s, d in ((0, 1), (1, 2), (2, 3), (0, 3)))
    )
    return sim


@pytest.mark.parametrize("routing", ["static", "adaptive"])
def test_doubling_fabric_bandwidth_never_slows_served_stage_times(routing):
    """Metamorphic: a uniformly faster fabric can only lower the service
    times a lane observes — under live co-tenant congestion, in both
    routing modes (the conf is re-tuned per platform, so compare the
    slower platform's conf priced on both)."""
    slow = _mesh_serving(1.0, routing)
    fast = _mesh_serving(2.0, routing)
    fast.conf = slow.conf
    fast._base_times = list(fast.evaluator.stage_times(fast.conf))
    for t_slow, t_fast in zip(slow.observed_stage_times(), fast.observed_stage_times()):
        assert t_fast <= t_slow + 1e-15


def test_co_serve_on_adaptive_fabric_deterministic_and_diverges_from_static():
    """The co-simulator re-prices (and, with an adaptive fabric, re-routes)
    every lane's transfers each monitor window.  Two adaptive runs must be
    bit-identical; the adaptive arm must diverge from the static arm (the
    routing decision reaches the served latencies)."""
    from repro.interconnect import mesh2d, uniform_fabric
    from repro.serve import Tenant, co_serve

    fab = uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6))
    layers_a = network_layers("synthnet")
    layers_b = network_layers("resnet50")

    def arm(routing):
        plat = paper_platform(8).with_fabric(fab.with_routing(routing))
        tenants = []
        for name, layers, seed, slo in (("a", layers_a, 5, 2.5), ("b", layers_b, 6, 1.0)):
            cap = run_shisha(
                weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3"
            ).result.best_throughput
            tenants.append(
                Tenant(
                    name=name,
                    layers=tuple(layers),
                    traffic=PoissonTraffic(rate=0.6 * cap, seed=seed),
                    slo=slo,
                )
            )
        return co_serve(
            plat, tenants, horizon=20.0, elastic=False, measure_batches=2, alpha=4
        )

    adaptive_1, adaptive_2 = arm("adaptive"), arm("adaptive")
    for r1, r2 in zip(adaptive_1.results, adaptive_2.results):
        assert r1.sim.latencies == r2.sim.latencies, "adaptive co-serve not replayable"
    static = arm("static")
    assert any(
        rs.sim.latencies != ra.sim.latencies
        for rs, ra in zip(static.results, adaptive_1.results)
    ), "adaptive routing never reached the served latencies"
