"""Event-engine contracts: drain-sorted :class:`EventLoop` vs legacy heap.

PR 9 replaced the per-event ``heapq`` loop with a drain-sorted engine
(sort staged events once per refill, interleave a small near-heap for
mid-dispatch pushes).  The refactor is only admissible because the
dispatch contract is *bit-for-bit* preserved; this module is that pin:

  * **windowed-run regression** — ``run(h)`` must peek, not pop, at the
    horizon: an event past ``h`` stays queued for the next window.  The
    legacy loop silently consumed it (the beyond-horizon loss bug this PR
    fixes); both engines are now held to peek semantics.
  * **order property** — dispatch order equals ``sorted`` by
    ``(time, kind, push-order)``, including heavy timestamp ties.
  * **cross-engine equivalence** — randomized trials with mid-dispatch
    follow-up pushes (including same-time, lower-kind pushes that must
    pre-empt the current drain) dispatch identically on both engines.
  * **end-to-end pins** — seeded serve scenarios (plain, scripted faults,
    power+thermal, adaptive fabric) and an elastic faulted co-serve run
    produce field-for-field identical results under either engine.
  * **throughput floor** — the drain engine must hold >= 2.5x the legacy
    heap on raw no-op dispatch (relative, in-process, so CI machine speed
    cancels), and the committed ``BENCH_selfbench.json`` must witness the
    >= 3x headline speedup.
"""

import json
import math
import random
import time
from pathlib import Path

import pytest

from repro.core import DatabaseEvaluator, Trace, paper_platform, weights
from repro.core.heuristics import run_shisha
from repro.models.cnn import network_layers
from repro.serve import (
    PoissonTraffic,
    ReplayTraffic,
    ServingSimulator,
    Tenant,
    co_serve,
)
from repro.serve.simulator import EventLoop, HeapEventLoop
from repro.serve.traffic import _rng

ROOT = Path(__file__).resolve().parent.parent

ENGINES = [EventLoop, HeapEventLoop]
ENGINE_IDS = ["drain", "heap"]


class _Recorder:
    """Owner that records every dispatch it receives."""

    def __init__(self):
        self.events = []

    def _dispatch(self, t, kind, payload):
        self.events.append((t, kind, payload))


class _Chainer(_Recorder):
    """Owner whose dispatches deterministically push follow-up events.

    The follow-up schedule (seeded, identical across engines) exercises the
    hard cases: pushes *into* the active drain region, zero-delta pushes,
    and same-time lower-kind pushes that must still dispatch before later
    drain entries.
    """

    def __init__(self, loop, seed):
        super().__init__()
        self.loop = loop
        self.rng = random.Random(seed)

    def _dispatch(self, t, kind, payload):
        super()._dispatch(t, kind, payload)
        r = self.rng.random()
        if r < 0.3 and payload < 4:
            dt = self.rng.choice([0.0, 1e-9, 0.001, 0.01, 0.5, 10.0])
            self.loop.push(t + dt, self.rng.randrange(5), self, payload + 1)
        if r < 0.05:
            self.loop.push(t, 0, self, payload + 1)  # same time, lowest kind


def _scripted_run(cls, seed, horizons):
    """Seeded random pushes + chained follow-ups, run over ``horizons``."""
    rng = random.Random(seed)
    loop = cls()
    rec = _Chainer(loop, seed * 7 + 1)
    for _ in range(rng.randrange(1, 200)):
        # mix of continuous times and small integers (deliberate ties)
        t = rng.choice([rng.uniform(0, 100), float(rng.randrange(10))])
        loop.push(t, rng.randrange(5), rec, 0)
    for h in horizons:
        loop.run(h)
    return rec.events, loop.n_dispatched


# ---------------------------------------------------------------------------
# windowed runs: peek-don't-pop at the horizon
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cls", ENGINES, ids=ENGINE_IDS)
def test_beyond_horizon_event_is_not_consumed(cls):
    """The PR 9 bug: the legacy loop popped the first beyond-horizon event
    before noticing it was late, silently dropping it from later windows."""
    rec = _Recorder()
    loop = cls()
    loop.push(5.0, 0, rec, "late")
    loop.push(0.5, 0, rec, "early")
    loop.run(1.0)
    assert rec.events == [(0.5, 0, "early")]
    assert len(loop) == 1  # the late event is still queued, not lost
    loop.run(10.0)
    assert rec.events == [(0.5, 0, "early"), (5.0, 0, "late")]
    assert len(loop) == 0


@pytest.mark.parametrize("cls", ENGINES, ids=ENGINE_IDS)
def test_windowed_run_equals_single_horizon(cls):
    """Running in 3 windows dispatches exactly what one run would."""
    for seed in range(40):
        single = _scripted_run(cls, seed, [120.0])
        windowed = _scripted_run(cls, seed, [15.0, 40.0, 120.0])
        assert single == windowed, f"seed {seed}: windowed != single-horizon"


def test_repeated_and_zero_width_windows_are_idempotent():
    rec = _Recorder()
    loop = EventLoop()
    for t in (3.0, 1.0, 2.0):
        loop.push(t, 0, rec, t)
    loop.run(1.0)
    loop.run(1.0)  # re-running an exhausted window dispatches nothing new
    loop.run(0.0)
    assert rec.events == [(1.0, 0, 1.0)]
    loop.run(math.inf)
    assert [p for _, _, p in rec.events] == [1.0, 2.0, 3.0]


# ---------------------------------------------------------------------------
# dispatch-order property
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cls", ENGINES, ids=ENGINE_IDS)
def test_dispatch_order_is_sorted_time_kind_pushorder(cls):
    """Property: dispatch order == sorted (t, kind, seq), under heavy ties.

    Payloads are the 1-based push index, which equals the engine's internal
    ``seq``, so the recorded stream directly witnesses the tiebreak chain:
    time first, kind next, push order last.
    """
    for trial in range(30):
        rng = random.Random(1000 + trial)
        loop = cls()
        rec = _Recorder()
        pushed = []
        for s in range(1, rng.randrange(2, 150)):
            t = float(rng.randrange(5))  # 5 distinct times -> many ties
            k = rng.randrange(3)
            loop.push(t, k, rec, s)
            pushed.append((t, k, s))
        loop.run(math.inf)
        assert rec.events == sorted(pushed), f"trial {trial}: order violated"
        assert loop.n_dispatched == len(pushed)


@pytest.mark.parametrize("windows", [None, (15.0, 15.0, 40.0, 99.0, 120.0)])
def test_engines_dispatch_identically(windows):
    """Randomized cross-engine equivalence, with mid-dispatch pushes."""
    horizons = list(windows) if windows else [120.0]
    for seed in range(60):
        a = _scripted_run(EventLoop, seed, horizons)
        b = _scripted_run(HeapEventLoop, seed, horizons)
        assert a == b, f"seed {seed}: engines diverged"


# ---------------------------------------------------------------------------
# push_batch: bulk priming == N sequential pushes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cls", ENGINES, ids=ENGINE_IDS)
def test_push_batch_equals_sequential_pushes(cls):
    rng = random.Random(77)
    batch = sorted(rng.uniform(0, 50) for _ in range(200))
    singles = [(rng.uniform(0, 50), rng.randrange(5)) for _ in range(40)]

    def prime(bulk):
        loop = cls()
        rec = _Recorder()
        for t, k in singles[:20]:
            loop.push(t, k, rec, "pre")
        if bulk:
            loop.push_batch(batch, 1, rec, list(range(len(batch))))
        else:
            for i, t in enumerate(batch):
                loop.push(t, 1, rec, i)
        for t, k in singles[20:]:
            loop.push(t, k, rec, "post")
        loop.run(math.inf)
        return rec.events, loop.n_dispatched

    assert prime(bulk=True) == prime(bulk=False)


def test_push_batch_mid_drain_interleaves_correctly():
    """A batch pushed *during* dispatch (drain active) must land exactly
    where sequential pushes would — including entries below the drain tail."""

    class _BatchOnFirst(_Recorder):
        def __init__(self, loop, bulk):
            super().__init__()
            self.loop, self.bulk, self.fired = loop, bulk, False

        def _dispatch(self, t, kind, payload):
            super()._dispatch(t, kind, payload)
            if not self.fired:
                self.fired = True
                times = [t + 0.1, t + 0.2, 90.0]
                if self.bulk:
                    self.loop.push_batch(times, 0, self, ["a", "b", "c"])
                else:
                    for ti, p in zip(times, ["a", "b", "c"]):
                        self.loop.push(ti, 0, self, p)

    outcomes = []
    for bulk in (True, False):
        loop = EventLoop()
        rec = _BatchOnFirst(loop, bulk)
        for t in (1.0, 2.0, 3.0, 50.0):
            loop.push(t, 1, rec, t)
        loop.run(math.inf)
        outcomes.append(rec.events)
    assert outcomes[0] == outcomes[1]
    assert [p for _, _, p in outcomes[0]] == [1.0, "a", "b", 2.0, 3.0, 50.0, "c"]


# ---------------------------------------------------------------------------
# vectorized Poisson arrivals: bit-exact vs the scalar reference loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "rate,seed,horizon",
    [(0.5, 0, 60.0), (5.0, 3, 60.0), (120.0, 7, 40.0), (5000.0, 1, 2.0)],
)
def test_poisson_vectorized_matches_scalar_reference(rate, seed, horizon):
    """The chunked carry-in-cumsum draw must reproduce the scalar
    ``t += rng.exponential(...)`` loop bit-for-bit (the 5000-rate case
    crosses several chunk boundaries, where naive ``t + cumsum`` drifts)."""
    rng = _rng(seed)
    ref, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / rate)
        if t >= horizon:
            break
        ref.append(t)
    assert PoissonTraffic(rate=rate, seed=seed).arrivals(horizon) == ref


# ---------------------------------------------------------------------------
# end-to-end: seeded serve results bit-for-bit across engines
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tuned():
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    ev = DatabaseEvaluator(plat, layers)
    sh = run_shisha(weights(layers), Trace(ev), "H3")
    conf = sh.result.best_conf
    return {
        "layers": layers,
        "plat": plat,
        "conf": conf,
        "cap": sh.result.best_throughput,
        "slo": 3.0 * sum(ev.stage_times(conf)),
    }


def _serve_result(tuned, loop_cls, scenario):
    plat = tuned["plat"]
    if scenario == "power":
        from repro.power import uniform_power, uniform_thermal

        plat = plat.with_power(
            uniform_power(plat, thermal=uniform_thermal(plat.n_eps, seed=3))
        )
    elif scenario == "fabric":
        from repro.interconnect import mesh2d, uniform_fabric

        plat = plat.with_fabric(
            uniform_fabric(mesh2d(2, 4, bw=1e8, latency=1e-6), routing="adaptive")
        )
    ev = DatabaseEvaluator(plat, tuned["layers"])
    sim = ServingSimulator(ev, tuned["conf"], slo=tuned["slo"], loop=loop_cls())
    if scenario == "faults":
        sim.schedule_slowdown(8.0, 1, 2.0)
        sim.schedule_dropout(15.0, 0)
    arrivals = PoissonTraffic(rate=0.6 * tuned["cap"], seed=5).arrivals(30.0)
    return sim.run(arrivals, 30.0)


@pytest.mark.parametrize("scenario", ["plain", "faults", "power", "fabric"])
def test_sim_result_bit_for_bit_across_engines(tuned, scenario):
    res_new = _serve_result(tuned, EventLoop, scenario)
    res_old = _serve_result(tuned, HeapEventLoop, scenario)
    assert res_new == res_old  # every SimResult field, incl. power block


@pytest.mark.parametrize("order", ["drop-then-revive", "revive-then-drop"])
def test_same_timestamp_dropout_revival_dispatch_in_push_order(tuned, order):
    """Scripted dropout + revival of one EP at the *same* timestamp are
    both ``_PLATFORM`` events: the (time, kind, push-order) contract says
    the one pushed first wins, identically on both engines."""
    ep = tuned["conf"].eps[0]

    def run(loop_cls):
        ev = DatabaseEvaluator(tuned["plat"], tuned["layers"])
        sim = ServingSimulator(ev, tuned["conf"], slo=tuned["slo"], loop=loop_cls())
        sim.schedule_dropout(5.0, ep)
        if order == "drop-then-revive":
            sim.schedule_dropout(12.0, ep)
            sim.schedule_revival(12.0, ep)  # pushed last: EP ends alive
        else:
            sim.schedule_revival(12.0, ep)
            sim.schedule_dropout(12.0, ep)  # pushed last: EP stays dead
        arrivals = PoissonTraffic(rate=0.6 * tuned["cap"], seed=5).arrivals(30.0)
        return sim, sim.run(arrivals, 30.0)

    sim_new, res_new = run(EventLoop)
    sim_old, res_old = run(HeapEventLoop)
    assert res_new == res_old
    if order == "drop-then-revive":
        assert ep not in sim_new.dead and ep not in sim_old.dead
    else:
        assert ep in sim_new.dead and ep in sim_old.dead


def test_push_order_of_same_timestamp_faults_changes_the_outcome(tuned):
    """The two orders above are genuinely different programs — if they
    converged, the tie-break test would be vacuous."""
    ep = tuned["conf"].eps[0]

    def run(first, second):
        ev = DatabaseEvaluator(tuned["plat"], tuned["layers"])
        sim = ServingSimulator(ev, tuned["conf"], slo=tuned["slo"], loop=EventLoop())
        sim.schedule_dropout(5.0, ep)
        first(sim)
        second(sim)
        arrivals = PoissonTraffic(rate=0.6 * tuned["cap"], seed=5).arrivals(30.0)
        return sim.run(arrivals, 30.0)

    drop = lambda sim: sim.schedule_dropout(12.0, ep)
    revive = lambda sim: sim.schedule_revival(12.0, ep)
    assert run(drop, revive) != run(revive, drop)


def test_co_serve_result_bit_for_bit_across_engines():
    """Elastic, faulted shared-clock co-simulation under either engine."""
    plat = paper_platform(8)
    tenants = [
        Tenant(
            name="synthnet",
            layers=tuple(network_layers("synthnet")),
            traffic=ReplayTraffic.record(PoissonTraffic(rate=3.0, seed=11), 40.0),
            slo=2.7,
        ),
        Tenant(
            name="alexnet",
            layers=tuple(network_layers("alexnet")),
            traffic=ReplayTraffic.record(PoissonTraffic(rate=2.0, seed=12), 40.0),
            slo=2.0,
        ),
    ]

    def arm(loop_cls):
        return co_serve(
            plat,
            tenants,
            horizon=40.0,
            elastic=True,
            measure_batches=2,
            alpha=4,
            faults=[("dropout", 12.0, 0), ("slowdown", 20.0, 2, 3.0)],
            loop=loop_cls(),
        )

    res_new, res_old = arm(EventLoop), arm(HeapEventLoop)
    assert res_new == res_old  # results, repartitions, partitions, dead


# ---------------------------------------------------------------------------
# throughput: relative floor + committed-artifact witness
# ---------------------------------------------------------------------------


class _NullOwner:
    def _dispatch(self, t, kind, payload):
        pass


def test_drain_engine_dispatch_floor_vs_legacy():
    """Raw no-op dispatch: drain engine >= 2.5x the legacy heap.

    Relative and in-process (warmed, interleaved best-of), so absolute
    machine speed and load cancel; the measured ratio is ~4-6x, 2.5x
    leaves margin for CI jitter.
    """
    n = 100_000
    owner = _NullOwner()
    times = [i * 1e-6 for i in range(n)]
    payloads = [None] * n

    def arm(cls):
        loop = cls()
        loop.push_batch(times, 0, owner, payloads)
        t0 = time.perf_counter()
        loop.run(math.inf)
        wall = time.perf_counter() - t0
        assert loop.n_dispatched == n
        return wall

    arm(EventLoop), arm(HeapEventLoop)  # warmup, untimed
    new = old = math.inf
    for _ in range(5):
        new = min(new, arm(EventLoop))
        old = min(old, arm(HeapEventLoop))
    assert old / new >= 2.5, f"drain engine only {old / new:.2f}x the legacy heap"


def test_selfbench_artifact_witnesses_engine_speedup():
    """The committed payload must pin the >= 3x raw-dispatch headline and
    carry the legacy arm it was measured against."""
    data = json.loads((ROOT / "BENCH_selfbench.json").read_text())
    el = data["event_loop"]
    assert el["legacy_heap"]["events_per_s"] > 0
    assert el["speedup_vs_legacy"] >= 3.0
    assert "legacy_heap" in data["serve"]
    assert data["serve"]["speedup_vs_legacy"] > 0
