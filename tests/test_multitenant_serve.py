"""Shared-clock elastic multi-tenant co-simulation: invariants + behaviour.

The elastic co-simulator moves EPs between tenants mid-flight, which makes
two invariants worth guarding hard:

  * partition sanity — after *every* re-partition the tenants' EP sets are
    pairwise disjoint and together cover exactly the alive EPs;
  * conservation — every request that arrived is accounted for at the
    horizon (completed, in flight, or queued), summed over all tenants,
    even across drain-and-restart re-tunes and evaluator swaps.
"""

import pytest

from repro.core import DatabaseEvaluator, Trace, paper_platform, weights
from repro.core.heuristics import run_shisha
from repro.models.cnn import network_layers
from repro.serve import (
    ElasticPartitioner,
    MMPPTraffic,
    PoissonTraffic,
    ReplayTraffic,
    Tenant,
    co_schedule,
    co_serve,
    partition_eps,
    subplatform,
)

HORIZON = 150.0
FAULT_T = HORIZON / 3.0


@pytest.fixture(scope="module")
def plat():
    return paper_platform(8)


@pytest.fixture(scope="module")
def tenants(plat):
    """Victim at 65% of its partition capacity, donor deeply headroomed.

    Traffic is recorded so every test (and both arms of any comparison)
    replays the identical request stream.
    """
    parts = partition_eps(plat, 2, "interleaved")
    caps = {}
    layer_sets = {}
    for name, part in zip(("synthnet", "resnet50"), parts):
        layers = network_layers(name)
        ev = DatabaseEvaluator(subplatform(plat, part, name), layers)
        caps[name] = run_shisha(weights(layers), Trace(ev), "H3").result.best_throughput
        layer_sets[name] = layers
    return [
        Tenant(
            name="synthnet",
            layers=tuple(layer_sets["synthnet"]),
            traffic=ReplayTraffic.record(
                PoissonTraffic(rate=0.65 * caps["synthnet"], seed=11), HORIZON
            ),
            slo=2.7,
        ),
        Tenant(
            name="resnet50",
            layers=tuple(layer_sets["resnet50"]),
            traffic=ReplayTraffic.record(
                MMPPTraffic(
                    rate_low=0.08 * caps["resnet50"],
                    rate_high=0.30 * caps["resnet50"],
                    seed=12,
                ),
                HORIZON,
            ),
            slo=0.8,
        ),
    ]


def _co_serve(plat, tenants, *, elastic, faults=()):
    return co_serve(
        plat,
        tenants,
        horizon=HORIZON,
        elastic=elastic,
        batch_policy_search=True,
        measure_batches=2,
        alpha=4,
        faults=list(faults),
    )


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------


def test_partitions_disjoint_and_cover_alive_after_every_repartition(plat, tenants):
    res = _co_serve(plat, tenants, elastic=True, faults=[("dropout", FAULT_T, 0)])
    assert res.repartitions, "the dropout must trigger at least one re-partition"
    dead_so_far: set[int] = set()
    for event in res.repartitions:
        dead_so_far.add(event.dead_ep)
        owned = [ep for part in event.partitions.values() for ep in part]
        assert len(owned) == len(set(owned)), f"overlap at t={event.t}: {event.partitions}"
        assert set(owned) == set(range(plat.n_eps)) - dead_so_far, (
            f"partitions at t={event.t} do not cover exactly the alive EPs"
        )
    # the final partitions agree with the last event's snapshot
    assert res.partitions == res.repartitions[-1].partitions
    assert res.dead == frozenset(dead_so_far)


def test_global_queue_conservation_at_horizon(plat, tenants):
    for elastic in (False, True):
        res = _co_serve(plat, tenants, elastic=elastic, faults=[("dropout", FAULT_T, 0)])
        for r in res.results:
            assert (
                r.sim.n_arrived
                == r.sim.n_completed + r.sim.n_in_flight + r.sim.n_queued
            ), f"{r.tenant.name} leaked requests (elastic={elastic})"
        total_arrived = sum(r.sim.n_arrived for r in res.results)
        total_accounted = sum(
            r.sim.n_completed + r.sim.n_in_flight + r.sim.n_queued
            for r in res.results
        )
        assert total_arrived == total_accounted
        # every tenant's traffic actually arrived
        assert total_arrived == sum(
            len(t.traffic.arrivals(HORIZON)) for t in tenants
        )


def test_no_ep_oversubscription_across_tenants(plat, tenants):
    """The handover is atomic: a stolen EP is never part of two serving
    platforms at once, so no EP's occupancy summed over tenants can top 1."""
    res = _co_serve(plat, tenants, elastic=True, faults=[("dropout", FAULT_T, 0)])
    assert res.repartitions
    total: dict[str, float] = {}
    for r in res.results:
        for name, occ in r.sim.occupancy.items():
            total[name] = total.get(name, 0.0) + occ
    assert all(v <= 1.0 + 1e-9 for v in total.values()), total


# ---------------------------------------------------------------------------
# behaviour
# ---------------------------------------------------------------------------


def test_co_serve_is_deterministic(plat, tenants):
    runs = [
        _co_serve(plat, tenants, elastic=True, faults=[("dropout", FAULT_T, 0)])
        for _ in range(2)
    ]
    assert runs[0].partitions == runs[1].partitions
    assert len(runs[0].repartitions) == len(runs[1].repartitions)
    for a, b in zip(runs[0].results, runs[1].results):
        assert a.sim.latencies == b.sim.latencies
        assert a.sim.reconfigs == b.sim.reconfigs


def test_elastic_beats_static_under_fep_dropout(plat, tenants):
    """Acceptance: same fault, same replayed traffic -> elastic wins on
    aggregate SLO violations, and the events carry their Trace.wall costs."""
    faults = [("dropout", FAULT_T, 0)]
    static = _co_serve(plat, tenants, elastic=False, faults=faults)
    elastic = _co_serve(plat, tenants, elastic=True, faults=faults)
    assert elastic.aggregate_slo_rate < static.aggregate_slo_rate
    assert static.repartitions == []
    assert len(elastic.repartitions) == 1
    event = elastic.repartitions[0]
    assert event.victim == "synthnet"
    assert event.stolen_ep is not None and event.donor == "resnet50"
    # both affected tenants were charged real exploration time
    assert set(event.retune_costs) == {"synthnet", "resnet50"}
    assert all(c > 0 for c in event.retune_costs.values())


def test_fault_during_exploration_window_survives_install(plat, tenants):
    """A slowdown landing *inside* a re-partition's exploration window must
    hit the lane still serving that EP and survive the install: the
    install-time refresh re-bases the lane's drift from the global state,
    so the lingering derate triggers a follow-up slowdown re-tune."""
    res = _co_serve(
        plat,
        tenants,
        elastic=True,
        faults=[("dropout", FAULT_T, 0), ("slowdown", FAULT_T + 5.0, 2, 3.0)],
    )
    assert len(res.repartitions) == 1
    syn = next(r for r in res.results if r.tenant.name == "synthnet")
    assert syn.sim.n_arrived == (
        syn.sim.n_completed + syn.sim.n_in_flight + syn.sim.n_queued
    )
    kinds = [rc["kind"] for rc in syn.sim.reconfigs]
    assert "repartition" in kinds
    assert "slowdown" in kinds, f"post-install drift was lost: {kinds}"


def test_global_slowdown_lands_on_owner_lane(plat, tenants):
    """A scripted global slowdown must reach the tenant owning that EP."""
    res = _co_serve(plat, tenants, elastic=True, faults=[("slowdown", FAULT_T, 1, 3.0)])
    # global EP 1 belongs to resnet50 under the interleaved split
    r50 = next(r for r in res.results if r.tenant.name == "resnet50")
    syn = next(r for r in res.results if r.tenant.name == "synthnet")
    assert any(rc["kind"] == "slowdown" for rc in r50.sim.reconfigs)
    assert syn.sim.reconfigs == []
    assert res.repartitions == []  # slowdowns do not re-partition


def test_revived_ep_rejoins_exactly_one_tenant(plat, tenants):
    """Revival-aware elasticity: after a dropout is rebalanced away, the
    revived global EP is offered via the ElasticPartitioner pricing and
    rejoins exactly one tenant's partition (with a charged re-tune)."""
    res = _co_serve(
        plat,
        tenants,
        elastic=True,
        faults=[("dropout", FAULT_T, 0), ("revival", 2 * FAULT_T, 0)],
    )
    kinds = [e.kind for e in res.repartitions]
    assert kinds == ["dropout", "revival"], kinds
    revival = res.repartitions[-1]
    assert revival.stolen_ep == 0 and revival.donor is None
    owners = [name for name, part in res.partitions.items() if 0 in part]
    assert len(owners) == 1, f"revived EP owned by {owners}"
    assert owners == [revival.victim]
    assert 0 not in res.dead
    # the grant is a real partition change: the winner paid exploration time
    assert set(revival.retune_costs) == {revival.victim}
    assert revival.retune_costs[revival.victim] > 0
    # partition invariants hold after the revival too
    owned = [ep for part in res.partitions.values() for ep in part]
    assert len(owned) == len(set(owned))
    assert set(owned) == set(range(plat.n_eps))
    # conservation across the extra reconfig
    for r in res.results:
        assert r.sim.n_arrived == (
            r.sim.n_completed + r.sim.n_in_flight + r.sim.n_queued
        )


def test_revival_inside_repartition_window_is_not_orphaned(plat, tenants):
    """Regression: a revival landing *during* the dropout's exploration
    window (the ex-victim still serves on the EP until install) must still
    be re-granted — allocation truth, not installed truth, decides."""
    res = _co_serve(
        plat,
        tenants,
        elastic=True,
        # the dropout's re-partition is decided at the first monitor tick
        # after FAULT_T and its install lands a full exploration window
        # later (several seconds at measure_batches=2); +2s is inside it
        faults=[("dropout", FAULT_T, 0), ("revival", FAULT_T + 2.0, 0)],
    )
    assert 0 not in res.dead
    owned = [ep for part in res.partitions.values() for ep in part]
    assert len(owned) == len(set(owned))
    assert set(owned) == set(range(plat.n_eps)), (
        f"revived EP was orphaned: partitions cover {sorted(owned)}"
    )
    assert [e.kind for e in res.repartitions] == ["dropout", "revival"]


def test_co_schedule_keeps_fixed_partitions(plat, tenants):
    rows = co_schedule(plat, tenants, horizon=60.0)
    parts = partition_eps(plat, 2, "interleaved")
    for row, part in zip(rows, parts):
        assert row.ep_idxs == tuple(part)
        assert row.sim.n_arrived == (
            row.sim.n_completed + row.sim.n_in_flight + row.sim.n_queued
        )


# ---------------------------------------------------------------------------
# pricing unit behaviour
# ---------------------------------------------------------------------------


def test_partitioner_prices_headroomed_donor_near_zero(plat, tenants):
    ep = ElasticPartitioner(plat, lambda p, L: DatabaseEvaluator(p, L))
    donor = tenants[1]  # resnet50, huge capacity
    part = (1, 3, 5, 7)
    # demand far below capacity: giving up even a fast EP risks nothing
    assert ep.price(donor, part, 3, demand=1.0, urgency=0.0) == 0.0
    # demand near capacity: the same EP becomes expensive
    cap = ep.tuned_throughput(donor, part)
    assert ep.price(donor, part, 3, demand=cap, urgency=0.0) > 0.0


def test_rebalance_insensitive_to_partition_insertion_order(plat, tenants):
    """Shisha-lint contract audit: offer pricing scans donors in *name*
    order, not dict insertion order, and the offer sort key is total —
    so the same partition content must produce bit-for-bit identical
    deals no matter how the caller assembled the partitions dict."""
    tmap = {t.name: t for t in tenants}
    base = {"synthnet": (0, 2, 4), "resnet50": (1, 3, 5, 7)}
    flipped = {"resnet50": (1, 3, 5, 7), "synthnet": (0, 2, 4)}
    pricer = ElasticPartitioner(plat, lambda p, L: DatabaseEvaluator(p, L))
    cap = pricer.tuned_throughput(tmap["synthnet"], base["synthnet"])
    loads = {"synthnet": (2.0 * cap, 3.0), "resnet50": (1.0, 0.0)}
    deals_a, parts_a = pricer.rebalance_bundle(
        base, "synthnet", tmap, loads, max_bundle=2
    )
    # a fresh pricer for the permuted dict, so the shared pricing cache
    # cannot mask an iteration-order dependence in the cold path
    fresh = ElasticPartitioner(plat, lambda p, L: DatabaseEvaluator(p, L))
    deals_b, parts_b = fresh.rebalance_bundle(
        flipped, "synthnet", tmap, loads, max_bundle=2
    )
    assert deals_a, "pressured victim with a headroomed donor must steal"
    assert deals_a == deals_b
    assert parts_a == parts_b
    # seeded rerun on the warm pricer is bit-for-bit too
    assert pricer.rebalance_bundle(base, "synthnet", tmap, loads, max_bundle=2) == (
        deals_a,
        parts_a,
    )


def test_partitioner_ignores_useless_ep_for_victim(plat, tenants):
    ep = ElasticPartitioner(plat, lambda p, L: DatabaseEvaluator(p, L))
    victim = tenants[0]  # synthnet
    part = (2, 4, 6)
    cap = ep.tuned_throughput(victim, part)
    # a slow EP does not move synthnet's bottleneck: zero gain even under
    # heavy pressure
    slow_gain = ep.gain(victim, part, 7, demand=2 * cap, urgency=5.0)
    fast_gain = ep.gain(victim, part, 1, demand=2 * cap, urgency=5.0)
    assert slow_gain == 0.0
    assert fast_gain > 0.0
