"""Deterministic tie-breaking in Alg. 2's pick_target + Trace caching.

These tests run without hypothesis (unlike test_core_scheduler.py) so the
core tuner invariants stay covered on minimal installs.
"""

import pytest

from repro.core import (
    AnalyticEvaluator,
    DatabaseEvaluator,
    PipelineConfig,
    Trace,
    paper_platform,
    pick_target,
    tune,
    weights,
)
from repro.models.cnn import network_layers

# ---------------------------------------------------------------------------
# pick_target tie-breaks (crafted platforms; all candidate EPs same class)
# ---------------------------------------------------------------------------


def _all_fep_platform(n=4):
    return paper_platform(n, fep_fraction=1.0)


def test_nfep_full_tie_resolves_to_lowest_stage_index():
    # slowest in the middle; stages 0 and 2 tie on distance AND beat
    plat = _all_fep_platform(3)
    conf = PipelineConfig(stages=(2, 4, 2), eps=(0, 1, 2))
    times = [1.0, 5.0, 1.0]
    assert pick_target(conf, times, 1, plat, "nfep") == 0


def test_nlfep_full_tie_resolves_to_lowest_stage_index():
    plat = _all_fep_platform(3)
    conf = PipelineConfig(stages=(2, 4, 2), eps=(0, 1, 2))
    times = [1.0, 5.0, 1.0]
    assert pick_target(conf, times, 1, plat, "nlfep") == 0


def test_nfep_distance_tie_broken_by_load():
    # equal distance, unequal beat: nfep must take the lighter stage
    plat = _all_fep_platform(3)
    conf = PipelineConfig(stages=(2, 4, 2), eps=(0, 1, 2))
    times = [2.0, 5.0, 1.0]
    assert pick_target(conf, times, 1, plat, "nfep") == 2


def test_nlfep_load_tie_broken_by_distance_then_index():
    # stages 1 and 3 tie on beat (1.0) AND distance (1) from slowest=2:
    # the (beat, distance, index) key must resolve to the lower index
    plat = _all_fep_platform(4)
    conf = PipelineConfig(stages=(2, 2, 4, 2), eps=(0, 1, 2, 3))
    times = [1.0, 1.0, 5.0, 1.0]
    assert pick_target(conf, times, 2, plat, "nlfep") == 1


def test_nfep_vs_nlfep_disagree_deterministically():
    # nfep goes to the nearest stage even if heavier; nlfep to the lightest
    plat = _all_fep_platform(4)
    conf = PipelineConfig(stages=(2, 2, 4, 2), eps=(0, 1, 2, 3))
    times = [0.5, 3.0, 5.0, 3.0]
    assert pick_target(conf, times, 2, plat, "nfep") == 1
    assert pick_target(conf, times, 2, plat, "nlfep") == 0


def test_fast_ep_candidates_preferred_over_nearer_slow():
    # mixed platform: a nearer SEP-hosted stage loses to a farther FEP stage
    plat = paper_platform(4)  # EPs 0,1 fast; 2,3 slow
    conf = PipelineConfig(stages=(4, 2, 2), eps=(2, 3, 0))  # slowest on SEP
    times = [5.0, 1.0, 1.0]
    assert pick_target(conf, times, 0, plat, "nfep") == 2


def test_tune_deterministic_after_stage_collapse():
    """Two identical tune runs stay in lock-step even through collapses."""
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    confs = []
    for _ in range(2):
        trace = Trace(DatabaseEvaluator(plat, layers))
        res = tune(
            PipelineConfig(
                stages=(1, 1, 1, 1, 1, 13), eps=(0, 1, 2, 3, 4, 5)
            ),  # heavy tail stage forces collapses
            trace,
            alpha=10,
        )
        confs.append([t.conf for t in trace.trials])
    assert confs[0] == confs[1]


# ---------------------------------------------------------------------------
# Trace cache (satellite: _cache was write-only before)
# ---------------------------------------------------------------------------


def _mk_trace(**kw):
    layers = network_layers("alexnet")
    plat = paper_platform(4)
    return Trace(AnalyticEvaluator(plat, layers), **kw), PipelineConfig(
        stages=(2, 3), eps=(0, 1)
    )


def test_trace_revisit_paid_by_default():
    trace, conf = _mk_trace()
    tp1 = trace.execute(conf)
    w1 = trace.wall
    tp2 = trace.execute(conf)
    assert tp1 == tp2
    assert trace.n_trials == 2  # both visits recorded
    assert trace.wall > w1  # and both visits paid for


def test_trace_cache_short_circuits_when_enabled():
    trace, conf = _mk_trace(use_cache=True)
    tp1 = trace.execute(conf)
    w1 = trace.wall
    tp2 = trace.execute(conf)
    assert tp1 == tp2
    assert trace.n_trials == 1  # revisit served from cache
    assert trace.wall == w1  # for free
    other = PipelineConfig(stages=(1, 4), eps=(0, 1))
    trace.execute(other)
    assert trace.n_trials == 2  # new confs still measured
