"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4), jnp.bfloat16: dict(rtol=6e-2, atol=6e-2)}


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (200, 300, 150), (128, 512, 256), (33, 65, 17)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm(m, k, n, dtype):
    a = _rand(jax.random.fold_in(KEY, m), (m, k), dtype)
    b = _rand(jax.random.fold_in(KEY, n), (k, n), dtype)
    y = ops.gemm(a, b, bm=64, bn=64, bk=128)
    yr = ref.gemm_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("r,s", [(1, 1), (3, 3), (5, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_conv2d(stride, r, s, dtype):
    x = _rand(jax.random.fold_in(KEY, r), (2, 12, 12, 8), dtype)
    w = _rand(jax.random.fold_in(KEY, s), (r, s, 8, 24), dtype)
    y = ops.conv2d_im2col(x, w, stride=stride, bk=16)
    yr = ref.conv2d_ref(x, w, stride=stride)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("h,kvh", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("s", [64, 128])
def test_flash_attention(causal, h, kvh, s):
    d = 32
    q = _rand(jax.random.fold_in(KEY, h), (2, h, s, d), jnp.float32)
    k = _rand(jax.random.fold_in(KEY, kvh), (2, kvh, s, d), jnp.float32)
    v = _rand(jax.random.fold_in(KEY, s), (2, kvh, s, d), jnp.float32)
    y = ops.flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    yr = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [16, 32])
@pytest.mark.parametrize("h,p,n", [(2, 16, 8), (3, 8, 16)])
def test_ssd_scan(chunk, h, p, n):
    b, l = 2, 128
    ks = jax.random.split(KEY, 5)
    x = _rand(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (b, l, h), jnp.float32))
    A = -jnp.exp(_rand(ks[2], (h,), jnp.float32) * 0.5)
    B = _rand(ks[3], (b, l, n), jnp.float32)
    C = _rand(ks[4], (b, l, n), jnp.float32)
    y = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    yr = ref.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-3, atol=2e-3)


def test_blockwise_sdpa_matches_full():
    """The jnp blockwise attention (model path) equals exact attention."""
    import dataclasses

    from repro.configs import get_smoke
    from repro.models.blocks import _sdpa

    cfg = dataclasses.replace(get_smoke("granite-3-2b"), attn_q_block=16)
    b, s, h, kvh, d = 2, 64, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (b, s, h, d), jnp.float32)
    k = _rand(ks[1], (b, s, kvh, d), jnp.float32)
    v = _rand(ks[2], (b, s, kvh, d), jnp.float32)
    y = _sdpa(cfg, q, k, v, causal=True)
    yr = ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), causal=True
    ).transpose(0, 2, 1, 3).reshape(b, s, h * d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)
