"""Clean: accumulation order is pinned before summing."""


def total(xs):
    direct = sum(sorted(x * 0.1 for x in xs))
    via_gen = sum(sorted(v + 1.0 for v in sorted(set(xs))))
    return direct + via_gen
