"""Violates id-ordering: object addresses used as an ordering."""


def stable(items):
    return sorted(items, key=id)


def racy(a, b):
    return id(a) < id(b)
