"""Violates unseeded-random: global RNG and legacy numpy API."""
import random

import numpy as np


def jitter(n):
    base = np.random.rand(n)
    return [b + random.random() for b in base]
