"""A load-bearing pragma: the wall-clock read is the fixture's point."""
import time


def stamp(events):
    events.append(time.time())  # shisha: allow(wall-clock)
