"""A stale pragma: nothing on the line needs suppressing."""


def add(a, b):
    return a + b  # shisha: allow(wall-clock)
