"""Clean: the unique dict key is folded into every ordering decision."""


def hottest(load):
    worst, _ = max(load.items(), key=lambda kv: (kv[1], kv[0]))
    first = min(load.items(), key=lambda kv: (kv[1], kv[0]))
    return worst, first
