"""Clean: ordering uses stable, run-independent keys."""


def stable(items):
    return sorted(items, key=str)


def racy(a, b):
    return str(a) < str(b)
