"""Clean: the thermal step takes simulated dt and sums in pinned order."""


def integrate(temps, heat_w, r, c, dt):
    package_w = sum(sorted(w * 1.0 for w in heat_w))
    for i, t in enumerate(temps):
        temps[i] = t + (package_w * r - t) * dt / (r * c)
    return dt
