"""Violates wall-clock and float-accum: a thermal integrator that reads
real time for its step and folds per-chiplet heat in set order."""
import time


def integrate(temps, heat_w, r, c, last):
    now = time.perf_counter()
    dt = now - last
    package_w = sum({w * 1.0 for w in heat_w})
    for i, t in enumerate(temps):
        temps[i] = t + (package_w * r - t) * dt / (r * c)
    return now
