"""Clean: events are scheduled at or after the current loop time."""


def reschedule(loop, t, dt):
    loop.push(t, 0, None, "now")
    loop.push(t + dt, 0, None, "later")
