"""Violates unseeded-random: MTBF renewal sampling off the global RNG."""
import random


def down_intervals(mtbf, mttr, horizon):
    out = []
    t = random.expovariate(1.0 / mtbf)
    while t < horizon:
        repair = random.expovariate(1.0 / mttr)
        out.append((t, t + repair))
        t = t + repair + random.expovariate(1.0 / mtbf)
    return out
