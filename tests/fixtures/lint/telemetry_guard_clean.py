"""Clean: bind the handle to a local, guard, then use."""


def record(sim, value):
    tl = sim.telemetry
    if tl is not None:
        tl.gauge("y").set(value)
