"""Clean: every draw flows from an explicit seed."""
import random

import numpy as np


def jitter(n, seed):
    rng = np.random.default_rng(seed)
    fallback = random.Random(seed)
    return [b + fallback.random() for b in rng.random(n)]
