"""Clean: timestamps come from the simulated clock handed in."""


def stamp(events, now):
    events.append(now)
