"""Clean MTBF sampler: every draw threads an explicit seeded stream."""
import random


def down_intervals(rng: random.Random, mtbf, mttr, horizon):
    out = []
    t = rng.expovariate(1.0 / mtbf)
    while t < horizon:
        repair = rng.expovariate(1.0 / mttr)
        out.append((t, t + repair))
        t = t + repair + rng.expovariate(1.0 / mtbf)
    return out


def trace(seed, mtbf, mttr, horizon):
    return down_intervals(random.Random(seed), mtbf, mttr, horizon)
