"""Violates telemetry-guard: duck-typed handle used without a guard."""


def record(sim, value):
    sim.telemetry.counter("x").inc()
    tl = sim.telemetry
    tl.gauge("y").set(value)
