"""Violates event-past: events scheduled behind the loop clock."""


def reschedule(loop, t, dt):
    loop.push(t - dt, 0, None, "late")
    loop.push(-1.0, 0, None, "negative")
