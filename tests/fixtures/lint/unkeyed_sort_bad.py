"""Violates unkeyed-sort: dict-view ordering with insertion-order ties."""


def hottest(load):
    worst = max(load.values())
    first = min(load.items(), key=lambda kv: kv[1])
    return worst, first
