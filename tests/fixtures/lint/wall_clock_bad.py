"""Violates wall-clock: reads real time on a simulated path."""
import time
from datetime import datetime


def stamp(events):
    events.append(time.time())
    events.append(time.perf_counter())
    events.append(datetime.now())
