"""Violates float-accum: fp sum over an unordered set."""


def total(xs):
    direct = sum({x * 0.1 for x in xs})
    via_gen = sum(v + 1.0 for v in set(xs))
    return direct + via_gen
