"""Violates set-iteration: order-sensitive work driven by a set."""


def drain(pending):
    order = []
    for ep in {3, 1, 2}:
        order.append(ep)
    return order + [x for x in set(pending)]
