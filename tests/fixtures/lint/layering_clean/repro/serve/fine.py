"""Clean: serve sits above core/interconnect/telemetry in the DAG."""
from repro.core import config  # noqa: F401
from repro.telemetry import live  # noqa: F401
