"""Clean: sets are sorted before any order-sensitive iteration."""


def drain(pending):
    order = []
    for ep in sorted({3, 1, 2}):
        order.append(ep)
    return order + [x for x in sorted(set(pending))]
