"""Violates import-layering: telemetry must import nothing internal."""
from repro.serve import simulator  # noqa: F401
