"""Violates import-layering: core may import interconnect only lazily."""
from repro.interconnect import Fabric  # noqa: F401
