"""Half of an eager import cycle (with mod_b)."""
import mod_b  # noqa: F401

VALUE_A = 1
