"""Other half of the eager import cycle (with mod_a)."""
import mod_a  # noqa: F401

VALUE_B = 2
