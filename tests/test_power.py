"""Power/thermal co-simulation: DVFS ladders, caps, throttle drift, energy.

Three contracts guarded hard, mirroring the fabric playbook:

  * **degenerate identity** — attaching :func:`~repro.power.degenerate_power`
    (one nominal level, no cap, no thermal) reproduces the power-free
    results *bit-for-bit* across tune, serve and co_serve (the power
    analogue of ``scalar_fabric``);
  * **cap semantics** — ``tune(dvfs=True)`` under a binding package cap
    steps in-use EPs down until the cap holds, pays every enforced level as
    an online trial, and never adopts a cap-infeasible candidate;
  * **throttle classification** — a hysteretic thermal oscillation is
    classified ``"throttle"`` (answered by a cheap DVFS step-down), while a
    monotone step derate stays ``"slowdown"`` (full re-tune).
"""

import json
import math

import pytest

from repro.core import (
    AnalyticEvaluator,
    DatabaseEvaluator,
    Trace,
    paper_platform,
    tune,
    weights,
)
from repro.core.heuristics import run_shisha
from repro.models.cnn import network_layers
from repro.pipeline.hetero import EPDerates
from repro.power import (
    DVFSLevel,
    EPPowerSpec,
    PowerModel,
    ThermalModel,
    degenerate_power,
    dvfs_ladder,
    uniform_power,
    uniform_thermal,
)
from repro.serve import (
    DRIFT_KINDS,
    ContinuousShisha,
    Drift,
    DriftDetector,
    PoissonTraffic,
    ReplayTraffic,
    ServingSimulator,
    Tenant,
    co_serve,
)
from repro.telemetry import Telemetry


# ---------------------------------------------------------------------------
# model arithmetic
# ---------------------------------------------------------------------------


def test_dvfs_level_validation():
    with pytest.raises(ValueError):
        DVFSLevel("bad", scale=0.0, dynamic_w=1.0, static_w=0.1)
    with pytest.raises(ValueError):
        DVFSLevel("bad", scale=1.5, dynamic_w=1.0, static_w=0.1)
    with pytest.raises(ValueError):
        DVFSLevel("bad", scale=0.5, dynamic_w=-1.0, static_w=0.1)


def test_spec_must_be_fastest_first():
    lo = DVFSLevel("lo", 0.5, 1.0, 0.1)
    hi = DVFSLevel("hi", 1.0, 8.0, 0.2)
    with pytest.raises(ValueError):
        EPPowerSpec(levels=(lo, hi))
    EPPowerSpec(levels=(hi, lo))  # fastest-first is fine
    with pytest.raises(ValueError):
        EPPowerSpec(levels=(hi, lo), nominal=2)


def test_ladder_follows_cubic_law():
    levels = dvfs_ladder(16.0, 2.0, n_levels=4, min_scale=0.4)
    assert [l.scale for l in levels] == pytest.approx([1.0, 0.8, 0.6, 0.4])
    assert [l.scale for l in levels] == sorted(
        (l.scale for l in levels), reverse=True
    )
    for l in levels:
        assert l.dynamic_w == pytest.approx(16.0 * l.scale**3)
        assert l.static_w == pytest.approx(2.0 * (0.5 + 0.5 * l.scale))


def test_package_arithmetic_and_stepping():
    pm = PowerModel(
        specs=tuple(EPPowerSpec(dvfs_ladder(10.0, 1.0)) for _ in range(3)),
        cap_w=25.0,
    )
    assert pm.n_eps == 3 and pm.tunable
    assert pm.static_package_w == pytest.approx(3.0)
    # duplicate in-use entries count once
    assert pm.package_w([0, 0, 1]) == pytest.approx(3.0 + 20.0)
    assert not pm.cap_feasible([0, 1, 2])  # 3 + 30 > 25
    assert not pm.can_step_up(0)
    pm.set_level(0, 3)
    assert pm.can_step_up(0) and not pm.can_step_down(0)
    assert pm.scale(0) == pytest.approx(0.4)
    # cubic dip makes the package fit now
    assert pm.cap_feasible([0, 1, 2])
    snap = pm.snapshot()
    assert snap == (3, 0, 0)
    pm.set_level(0, 0)
    pm.restore(snap)
    assert pm.level(0) == 3
    with pytest.raises(ValueError):
        pm.set_level(0, 9)
    with pytest.raises(ValueError):
        pm.restore((0, 0))


def test_restrict_carries_levels_and_platform_without():
    plat = paper_platform(4)
    pm = uniform_power(plat, cap_w=100.0, thermal=uniform_thermal(4, seed=7))
    pm.set_level(2, 1)
    pm.thermal.temps[2] = 60.0
    sub = pm.restrict([1, 2])
    assert sub.n_eps == 2 and sub.cap_w == 100.0
    assert sub.snapshot() == (0, 1)
    assert sub.thermal.temps == [pm.thermal.temps[1], 60.0]
    # Platform.without routes through the same restriction
    smaller = plat.with_power(pm).without([0, 3])
    assert smaller.power.n_eps == 2
    assert smaller.power.snapshot() == (0, 1)


def test_degenerate_model_is_identity():
    plat = paper_platform(4)
    pm = degenerate_power(plat)
    assert not pm.tunable
    assert math.isinf(pm.cap_w)
    for ep in range(4):
        assert pm.scale(ep) == 1.0


# ---------------------------------------------------------------------------
# evaluator scaling
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("evaluator_cls", [AnalyticEvaluator, DatabaseEvaluator])
def test_dvfs_scale_divides_stage_times(evaluator_cls):
    layers = network_layers("synthnet")
    plat = paper_platform(4)
    pm = uniform_power(plat)
    conf = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3"
    ).result.best_conf
    ev = evaluator_cls(plat.with_power(pm), layers)
    nominal = ev.stage_times(conf)
    pm.set_level(conf.eps[0], 2)  # scale 0.6 on stage 0's EP
    scaled = ev.stage_times(conf)
    # stage 0 slowed; compute share grew by exactly 1/scale, link share fixed
    assert scaled[0] > nominal[0]
    for s in range(1, conf.depth):
        assert scaled[s] == nominal[s]
    pm.set_level(conf.eps[0], 0)
    assert ev.stage_times(conf) == nominal


@pytest.mark.parametrize("evaluator_cls", [AnalyticEvaluator, DatabaseEvaluator])
def test_degenerate_power_tune_is_bit_for_bit(evaluator_cls):
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    platp = plat.with_power(degenerate_power(plat))
    bare = run_shisha(weights(layers), Trace(evaluator_cls(plat, layers)), "H3")
    powered = run_shisha(weights(layers), Trace(evaluator_cls(platp, layers)), "H3")
    assert bare.result == powered.result
    assert [(t.conf, t.throughput, t.t_wall) for t in bare.trace.trials] == [
        (t.conf, t.throughput, t.t_wall) for t in powered.trace.trials
    ]


def test_degenerate_power_dvfs_tune_matches_plain_tune():
    # single-level ladders under a satisfied cap: dvfs=True must degrade to
    # exactly the paper's loop, trial for trial
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    seed = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3"
    ).result.best_conf
    tr_a = Trace(DatabaseEvaluator(plat, layers))
    tr_b = Trace(DatabaseEvaluator(plat.with_power(degenerate_power(plat)), layers))
    a = tune(seed, tr_a, dvfs=False)
    b = tune(seed, tr_b, dvfs=True)
    assert (a.best_conf, a.best_throughput, a.n_explored) == (
        b.best_conf,
        b.best_throughput,
        b.n_explored,
    )
    assert b.dvfs_levels is None  # degenerate model: nothing was tuned
    assert [(t.conf, t.throughput, t.t_wall) for t in tr_a.trials] == [
        (t.conf, t.throughput, t.t_wall) for t in tr_b.trials
    ]


# ---------------------------------------------------------------------------
# DVFS-aware tuning under a cap
# ---------------------------------------------------------------------------


def test_tune_dvfs_enforces_binding_cap():
    layers = network_layers("synthnet")
    plat = paper_platform(4)
    pm = uniform_power(plat)
    seed = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3"
    ).result.best_conf
    nominal_w = pm.package_w(seed.conf.eps if hasattr(seed, "conf") else seed.eps)
    cap = 0.75 * nominal_w  # binding at nominal clocks
    pmc = uniform_power(plat, cap_w=cap)
    assert not pmc.cap_feasible(seed.eps)
    trace = Trace(DatabaseEvaluator(plat.with_power(pmc), layers))
    result = tune(seed, trace, dvfs=True)
    assert result.dvfs_levels is not None
    assert any(l > 0 for l in result.dvfs_levels)  # someone stepped down
    # the adopted configuration satisfies the cap at the adopted levels
    pmc.restore(result.dvfs_levels)
    assert pmc.cap_feasible(result.best_conf.eps)
    # enforcement paid online trials beyond the baseline measurement
    assert trace.n_trials > 1
    # and the model was left at the winning vector
    assert pmc.snapshot() == result.dvfs_levels


def test_tune_dvfs_loose_cap_still_returns_levels():
    layers = network_layers("synthnet")
    plat = paper_platform(4)
    pm = uniform_power(plat, cap_w=1e9)
    seed = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat, layers)), "H3"
    ).result.best_conf
    result = tune(seed, Trace(DatabaseEvaluator(plat.with_power(pm), layers)), dvfs=True)
    assert result.dvfs_levels is not None
    assert len(result.dvfs_levels) == 4


# ---------------------------------------------------------------------------
# thermal RC nodes
# ---------------------------------------------------------------------------


def test_thermal_trajectory_deterministic_and_converges():
    a = uniform_thermal(3, seed=5)
    b = uniform_thermal(3, seed=5)
    assert a.r_k_per_w == b.r_k_per_w and a.c_j_per_k == b.c_j_per_k
    assert uniform_thermal(3, seed=6).r_k_per_w != a.r_k_per_w
    for _ in range(500):
        for th in (a, b):
            th.step(0, 10.0, 1.0)
    assert a.temps == b.temps  # bit-identical trajectory
    target = 10.0 * a.r_k_per_w[0] + a.t_ambient_c
    assert a.temps[0] == pytest.approx(target, rel=1e-3)


def test_thermal_hysteresis_oscillates():
    th = ThermalModel(
        r_k_per_w=(5.0,),
        c_j_per_k=(2.0,),
        t_hot_c=85.0,
        t_cool_c=75.0,
    )
    # heat: 12 W -> target 105 C, crosses t_hot
    derates = [th.step(0, 12.0, 1.0) for _ in range(60)]
    assert th.throttled[0] and th.throttle_events == 1
    assert derates[-1] == th.throttle_derate
    # cool: idle until the latch releases below t_cool (hysteresis band)
    while th.throttled[0]:
        th.step(0, 0.0, 1.0)
    assert th.temps[0] <= th.t_cool_c
    assert th.factor(0) == 1.0
    # re-heat: second engagement
    for _ in range(60):
        th.step(0, 12.0, 1.0)
    assert th.throttle_events == 2
    # throttling burns superlinearly less than it slows
    assert th.electrical_derate == pytest.approx(th.throttle_derate**2)


def test_thermal_validation():
    with pytest.raises(ValueError):
        ThermalModel(r_k_per_w=(1.0,), c_j_per_k=(1.0, 2.0))
    with pytest.raises(ValueError):
        ThermalModel(r_k_per_w=(1.0,), c_j_per_k=(1.0,), t_hot_c=70.0, t_cool_c=80.0)
    with pytest.raises(ValueError):
        uniform_thermal(0)


# ---------------------------------------------------------------------------
# drift classification: throttle vs slowdown
# ---------------------------------------------------------------------------


def _conf_on(eps):
    from repro.core import PipelineConfig

    return PipelineConfig(stages=(2,) * len(eps), eps=tuple(eps))


def test_drift_kind_is_validated():
    Drift("slowdown", "ok")
    with pytest.raises(ValueError):
        Drift("meltdown", "nope")
    assert "throttle" in DRIFT_KINDS


def test_step_slowdown_stays_slowdown():
    det = DriftDetector()
    conf = _conf_on([0, 1])
    flat = EPDerates(factors=(1.0, 1.0))
    stepped = EPDerates(factors=(2.0, 1.0))
    assert det.detect(conf, [1.0, 1.0], flat, frozenset()) is None
    # a step derate rises once and holds: never classified as throttle
    for _ in range(6):
        ev = det.detect(conf, [2.0, 1.0], stepped, frozenset())
        assert ev is not None and ev.kind == "slowdown"
        assert ev.eps == (0,)


def test_oscillating_derate_becomes_throttle():
    det = DriftDetector()
    conf = _conf_on([0, 1])
    hot = EPDerates(factors=(1.6, 1.0))
    cool = EPDerates(factors=(1.0, 1.0))
    # first engagement: the detector has no reversal evidence yet
    ev = det.detect(conf, [1.6, 1.0], hot, frozenset())
    assert ev.kind == "slowdown"
    # release: factors ease back (no event; easing is handled upstream)
    det.detect(conf, [1.0, 1.0], cool, frozenset())
    # re-engage: history [1.6, 1.0, 1.6] shows rise AND fall -> throttle
    ev = det.detect(conf, [1.6, 1.0], hot, frozenset())
    assert ev.kind == "throttle"
    assert ev.eps == (0,)


def test_mixed_step_and_oscillation_stays_slowdown():
    # one EP oscillates, the other stepped: the composite is NOT attributed
    # to thermal (a sick host is in there too) -> conservative "slowdown"
    det = DriftDetector()
    conf = _conf_on([0, 1])
    seq = [(1.6, 1.0), (1.0, 1.0), (1.6, 2.0)]
    ev = None
    for f in seq:
        ev = det.detect(conf, list(f), EPDerates(factors=f), frozenset())
    assert ev is not None and ev.kind == "slowdown"
    assert set(ev.eps) == {0, 1}


def test_dropout_outranks_throttle():
    det = DriftDetector()
    conf = _conf_on([0, 1])
    hot = EPDerates(factors=(1.6, 1.0))
    det.detect(conf, [1.6, 1.0], hot, frozenset())
    det.detect(conf, [1.0, 1.0], EPDerates(factors=(1.0, 1.0)), frozenset())
    ev = det.detect(conf, [1.6, 1.0], hot, frozenset({1}))
    assert ev.kind == "dropout" and ev.eps == (1,)


# ---------------------------------------------------------------------------
# the throttle fast path: step-down instead of re-tune
# ---------------------------------------------------------------------------


def _throttle_tuner(plat_p, layers):
    return ContinuousShisha(
        platform=plat_p,
        layers=tuple(layers),
        make_evaluator=lambda p: DatabaseEvaluator(p, layers),
        cooldown=0.5,
    )


def test_throttle_event_answers_with_dvfs_stepdown():
    layers = network_layers("synthnet")
    plat = paper_platform(4)
    pm = uniform_power(plat)
    plat_p = plat.with_power(pm)
    conf = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat_p, layers)), "H3"
    ).result.best_conf
    tuner = _throttle_tuner(plat_p, layers)
    hot_ep = conf.eps[0]
    factors = [1.0] * 4
    factors[hot_ep] = 1.6
    hot = EPDerates(factors=tuple(factors))
    cool = EPDerates(factors=(1.0,) * 4)
    times = DatabaseEvaluator(plat_p, layers).stage_times(conf)
    # engage -> slowdown (full re-tune), release, re-engage -> throttle
    r1 = tuner.observe(1.0, conf, times, hot, frozenset())
    assert r1 is not None and r1.kind == "slowdown"
    # release: the easing after a full re-tune re-seeds ("recovery")
    tuner.observe(2.0, conf, times, cool, frozenset())
    levels_before = pm.snapshot()
    r2 = tuner.observe(4.0, conf, times, hot, frozenset())
    assert r2 is not None and r2.kind == "throttle"
    # fast path: configuration untouched, frequency stepped down on the hot EP
    assert r2.conf == conf
    assert r2.dvfs_levels is not None
    assert r2.dvfs_levels[hot_ep] == levels_before[hot_ep] + 1
    # one paid measurement, not an Algorithm 2 exploration
    assert r2.tune_result.n_explored == 1
    assert r2.tuning_cost > 0.0
    # the easing that follows a throttle response is benign: no recovery storm
    assert tuner.observe(6.0, conf, times, cool, frozenset()) is None


def test_throttle_at_frequency_floor_escalates_to_retune():
    layers = network_layers("synthnet")
    plat = paper_platform(4)
    pm = uniform_power(plat, n_levels=2)
    plat_p = plat.with_power(pm)
    conf = run_shisha(
        weights(layers), Trace(DatabaseEvaluator(plat_p, layers)), "H3"
    ).result.best_conf
    tuner = _throttle_tuner(plat_p, layers)
    hot_ep = conf.eps[0]
    pm.set_level(hot_ep, 1)  # already at the ladder floor
    factors = [1.0] * 4
    factors[hot_ep] = 1.6
    hot = EPDerates(factors=tuple(factors))
    cool = EPDerates(factors=(1.0,) * 4)
    times = [1.0] * conf.depth
    tuner.observe(1.0, conf, times, hot, frozenset())
    tuner.observe(2.0, conf, times, cool, frozenset())
    r = tuner.observe(4.0, conf, times, hot, frozenset())
    # no headroom left: the throttle event falls through to a full re-tune
    assert r is not None and r.kind == "throttle"
    assert r.tune_result.n_explored > 1


# ---------------------------------------------------------------------------
# serving integration: energy, temperature tracks, bit-for-bit pins
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tuned():
    layers = network_layers("synthnet")
    plat = paper_platform(4)
    ev = DatabaseEvaluator(plat, layers)
    sh = run_shisha(weights(layers), Trace(ev), "H3")
    return {
        "layers": layers,
        "plat": plat,
        "ev": ev,
        "conf": sh.result.best_conf,
        "cap": sh.result.best_throughput,
        "slo": 3.0 * sum(ev.stage_times(sh.result.best_conf)),
    }


def test_serve_reports_energy_and_peak_watts(tuned):
    plat_p = tuned["plat"].with_power(uniform_power(tuned["plat"]))
    ev = DatabaseEvaluator(plat_p, tuned["layers"])
    traffic = PoissonTraffic(rate=0.6 * tuned["cap"], seed=5)
    sim = ServingSimulator(ev, tuned["conf"], slo=tuned["slo"])
    res = sim.run(traffic.arrivals(30.0), 30.0)
    p = res.power
    assert p is not None
    assert p["energy_j"] > 0.0
    assert p["joules_per_request"] == pytest.approx(p["energy_j"] / res.n_completed)
    assert p["avg_package_w"] <= p["peak_package_w"]
    # static leakage alone lower-bounds the window average
    assert p["avg_package_w"] >= plat_p.power.static_package_w * 0.99
    assert p["cap_w"] is None  # uncapped exports None, not inf
    assert p["dvfs_levels"] == [0, 0, 0, 0]
    assert p["throttle_events"] == 0 and p["max_temp_c"] is None


def test_serve_energy_is_deterministic(tuned):
    traffic = PoissonTraffic(rate=0.6 * tuned["cap"], seed=5)
    runs = []
    for _ in range(2):
        plat_p = tuned["plat"].with_power(
            uniform_power(tuned["plat"], thermal=uniform_thermal(4, seed=3))
        )
        ev = DatabaseEvaluator(plat_p, tuned["layers"])
        sim = ServingSimulator(ev, tuned["conf"], slo=tuned["slo"])
        runs.append(sim.run(traffic.arrivals(30.0), 30.0))
    assert runs[0].power == runs[1].power
    assert runs[0].latencies == runs[1].latencies


def test_degenerate_power_serve_is_bit_for_bit(tuned):
    traffic = PoissonTraffic(rate=0.6 * tuned["cap"], seed=5)
    arr = traffic.arrivals(60.0)
    bare = ServingSimulator(tuned["ev"], tuned["conf"], slo=tuned["slo"]).run(arr, 60.0)
    plat_p = tuned["plat"].with_power(degenerate_power(tuned["plat"]))
    powered = ServingSimulator(
        DatabaseEvaluator(plat_p, tuned["layers"]), tuned["conf"], slo=tuned["slo"]
    ).run(arr, 60.0)
    assert bare.latencies == powered.latencies
    assert bare.occupancy == powered.occupancy
    assert bare.n_completed == powered.n_completed
    # the power block is the only addition
    assert bare.power is None and powered.power is not None


def test_lower_dvfs_level_trades_speed_for_joules(tuned):
    traffic = PoissonTraffic(rate=0.4 * tuned["cap"], seed=7)
    arr = traffic.arrivals(40.0)
    results = {}
    for lvl in (0, 2):
        pm = uniform_power(tuned["plat"])
        for ep in range(4):
            pm.set_level(ep, lvl)
        ev = DatabaseEvaluator(tuned["plat"].with_power(pm), tuned["layers"])
        results[lvl] = ServingSimulator(ev, tuned["conf"], slo=tuned["slo"]).run(
            arr, 40.0
        )
    # downclocked: slower service, lower peak draw
    assert results[2].p50 > results[0].p50
    assert results[2].power["peak_package_w"] < results[0].power["peak_package_w"]


def test_serve_thermal_throttle_triggers_dvfs_response(tuned):
    # aggressive thermal constants: tau ~ 4 s with a narrow hysteresis band
    # placed so a busy FEP's draw crosses t_hot while its *throttled* draw
    # (electrical derate) settles below t_cool -- the latch oscillates
    thermal = ThermalModel(
        r_k_per_w=(4.0,) * 4,
        c_j_per_k=(1.0,) * 4,
        t_hot_c=80.0,
        t_cool_c=76.0,
    )
    pm = uniform_power(tuned["plat"], thermal=thermal)
    plat_p = tuned["plat"].with_power(pm)
    ev = DatabaseEvaluator(plat_p, tuned["layers"])
    tuner = ContinuousShisha(
        platform=plat_p,
        layers=tuple(tuned["layers"]),
        make_evaluator=lambda p: DatabaseEvaluator(p, tuned["layers"]),
        cooldown=1.0,
        alpha=2,
        measure_batches=2,
    )
    traffic = PoissonTraffic(rate=0.7 * tuned["cap"], seed=5)
    sim = ServingSimulator(
        ev,
        tuned["conf"],
        slo=tuned["slo"],
        autotuner=tuner,
        monitor_interval=0.5,
    )
    res = sim.run(traffic.arrivals(120.0), 120.0)
    assert res.power["throttle_events"] > 0
    assert res.power["max_temp_c"] >= thermal.t_hot_c
    kinds = [r.kind for r in tuner.history]
    assert "throttle" in kinds, kinds
    # the throttle response stepped frequencies down, not the schedule
    first = next(r for r in tuner.history if r.kind == "throttle")
    assert first.dvfs_levels is not None and any(l > 0 for l in first.dvfs_levels)


def test_temperature_counter_tracks_exported(tuned):
    tl = Telemetry()
    thermal = uniform_thermal(4, seed=1)
    pm = uniform_power(tuned["plat"], thermal=thermal)
    ev = DatabaseEvaluator(tuned["plat"].with_power(pm), tuned["layers"])
    traffic = PoissonTraffic(rate=0.6 * tuned["cap"], seed=5)
    sim = ServingSimulator(ev, tuned["conf"], slo=tuned["slo"], telemetry=tl)
    sim.run(traffic.arrivals(10.0), 10.0)
    rows = [json.loads(l) for l in tl.export_jsonl().splitlines()]
    temp_rows = [r for r in rows if r["name"].startswith("thermal.temp_c:")]
    assert temp_rows and all(r["ph"] == "C" for r in temp_rows)
    assert all(r["args"]["value"] >= thermal.t_ambient_c for r in temp_rows)
    watt_rows = [r for r in rows if r["name"] == "power.package_w" and r.get("ph") == "C"]
    assert watt_rows
    chrome = tl.export_chrome_trace()
    assert any(e.get("ph") == "C" for e in chrome["traceEvents"])
    snap = tl.metrics_snapshot()
    assert "power.energy_j" in snap and "power.package_w" in snap


# ---------------------------------------------------------------------------
# co-serve: per-tenant energy and the degenerate pin
# ---------------------------------------------------------------------------


def _two_tenants(plat, horizon):
    layers_a = network_layers("synthnet")
    layers_b = network_layers("resnet50")
    return [
        Tenant(
            name="synthnet",
            layers=tuple(layers_a),
            traffic=ReplayTraffic.record(PoissonTraffic(rate=2.0, seed=11), horizon),
            slo=2.7,
        ),
        Tenant(
            name="resnet50",
            layers=tuple(layers_b),
            traffic=ReplayTraffic.record(PoissonTraffic(rate=1.0, seed=12), horizon),
            slo=2.0,
        ),
    ]


def test_co_serve_degenerate_power_is_bit_for_bit():
    plat = paper_platform(4)
    horizon = 20.0
    tenants = _two_tenants(plat, horizon)
    bare = co_serve(plat, tenants, horizon=horizon, elastic=False)
    powered = co_serve(
        plat.with_power(degenerate_power(plat)),
        tenants,
        horizon=horizon,
        elastic=False,
    )
    for rb, rp in zip(bare.results, powered.results):
        assert rb.sim.latencies == rp.sim.latencies
        assert rb.sim.n_completed == rp.sim.n_completed
        assert rb.sim.power is None and rp.sim.power is not None
    assert bare.aggregate_energy_j is None
    assert powered.aggregate_energy_j is not None and powered.aggregate_energy_j > 0
    done = sum(r.sim.n_completed for r in powered.results)
    assert powered.joules_per_request == pytest.approx(
        powered.aggregate_energy_j / done
    )
