"""Distribution tests.

Multi-device behaviour needs XLA host-device-count set before jax init, so
those cases run in subprocesses; in-process tests cover the pure helpers
(collective parsing, input specs, sharding rules).
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.dryrun import input_specs, parse_collectives

REPO = Path(__file__).resolve().parents[1]


def _run(code: str, devices: int = 8, timeout: int = 500):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=REPO,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# Pure helpers
# ---------------------------------------------------------------------------


def test_parse_collectives_synthetic():
    hlo = """
  %ag = f32[64,128]{1,0} all-gather(%p0), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %ar = bf16[32,32]{1,0} all-reduce(%p1), replica_groups={{0,1,2,3}}, to_apply=%add
  %done = f32[8]{0} all-reduce-done(%x)
  %cp = f32[4,4]{1,0} collective-permute(%p2), source_target_pairs={{0,1}}
"""
    colls = parse_collectives(hlo)
    ops = sorted(c["op"] for c in colls)
    assert ops == ["all-gather", "all-reduce", "collective-permute"]
    ag = next(c for c in colls if c["op"] == "all-gather")
    assert ag["bytes"] == 64 * 128 * 4
    assert ag["group"] == 16
    ar = next(c for c in colls if c["op"] == "all-reduce")
    assert ar["group"] == 4


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_shapes(arch, shape):
    cfg = get_config(arch)
    cell = SHAPES[shape]
    specs = input_specs(cfg, shape)
    assert "tokens" in specs
    assert specs["tokens"].shape[0] == cell.global_batch
    if cfg.n_patches and cell.phase != "decode":
        total = specs["tokens"].shape[1] + cfg.n_patches
        assert total == cell.seq_len
    if cell.phase == "train":
        assert "labels" in specs


def test_param_shardings_match_tree():
    from repro.models.lm_common import init_params, param_shardings

    for arch in ARCHS:
        cfg = get_config(arch)
        sds = jax.eval_shape(lambda c=cfg: init_params(c, jax.random.PRNGKey(0)))
        spec = param_shardings(cfg)
        assert jax.tree.structure(sds) == jax.tree.structure(
            spec, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )


# ---------------------------------------------------------------------------
# Multi-device subprocess tests
# ---------------------------------------------------------------------------


def test_pipeline_runner_matches_sequential():
    _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import generate_seed, paper_platform
        from repro.models.cnn import make_cnn, network_layers, canonical_pipeline_apply
        from repro.launch.mesh import make_stage_mesh
        from repro.pipeline import PipelineRunner

        model = make_cnn("synthnet", scale=0.1)
        params = model.init(jax.random.PRNGKey(0))
        seed = generate_seed([l.weight for l in network_layers("synthnet")], paper_platform(4), n_stages=4)
        in_shape = (8, 8, 8)
        apply_fn, to_canon, crop_out, _ = canonical_pipeline_apply(model, params, in_shape)
        runner = PipelineRunner(mesh=make_stage_mesh(4), conf=seed.conf, apply_layer=apply_fn, n_micro=5)
        micro_raw = jax.random.normal(jax.random.PRNGKey(1), (5, 2) + in_shape)
        out = crop_out(runner.run(jax.vmap(to_canon)(micro_raw)))
        ref = jnp.stack([model(params, micro_raw[i]) for i in range(5)])
        assert np.allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4), "pipeline != sequential"
        print("OK")
        """,
        devices=4,
    )


def test_tiny_mesh_train_step_with_moe():
    """MoE shard_map path under pjit on a real (4-device) mesh."""
    _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke
        from repro.models.lm_common import init_params, param_shardings
        from repro.models.transformer import make_train_step
        from repro.optim import AdamW, AdamWConfig

        cfg = get_smoke("phi3.5-moe-42b")
        mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = AdamW(AdamWConfig(total_steps=4, warmup=1))
        state = opt.init(params)
        pspec = param_shardings(cfg)
        ospec = {"step": P(), "mu": pspec, "nu": pspec, "master": pspec}
        bspec = {"tokens": P(("data",), None), "labels": P(("data",), None)}
        step = make_train_step(cfg, opt, mesh, ("data",), "model")
        jstep = jax.jit(step,
            in_shardings=jax.tree.map(lambda s: NamedSharding(mesh, s), (pspec, ospec, bspec),
                                      is_leaf=lambda x: isinstance(x, P)),
        )
        batch = {"tokens": jnp.zeros((4, 16), jnp.int32), "labels": jnp.zeros((4, 16), jnp.int32)}
        with mesh:
            p, o, m = jstep(params, state, batch)
        assert jnp.isfinite(m["loss"]), m
        print("OK", float(m["loss"]))
        """,
        devices=4,
    )


def test_moe_local_vs_sharded_equivalence():
    """shard_map MoE == local MoE when TP=1 (same dispatch per data shard)."""
    _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.configs import get_smoke
        from repro.models import blocks
        from repro.models.lm_common import init_params
        import dataclasses

        cfg = dataclasses.replace(get_smoke("phi3.5-moe-42b"), dtype=jnp.float32)
        params = init_params(cfg, jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda a: a[0], params["blocks"])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
        y_local, _ = blocks.moe_ffn(cfg, lp, x, None)
        mesh = Mesh(np.array(jax.devices()[:2]).reshape(2, 1), ("data", "model"))
        with mesh:
            y_shard, _ = jax.jit(lambda xx: blocks.moe_ffn(cfg, lp, xx, mesh, ("data",), "model"))(x)
        assert np.allclose(np.asarray(y_local), np.asarray(y_shard), rtol=1e-4, atol=1e-4)
        print("OK")
        """,
        devices=2,
    )


def test_make_production_mesh_shapes():
    _run(
        """
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert m1.shape == {"data": 16, "model": 16}, m1.shape
        m2 = make_production_mesh(multi_pod=True)
        assert m2.shape == {"pod": 2, "data": 16, "model": 16}, m2.shape
        print("OK")
        """,
        devices=512,
    )
