"""End-to-end system behaviour: training convergence, resume, paper claims."""

import math
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import (
    DatabaseEvaluator,
    Trace,
    exhaustive_search,
    run_shisha,
    weights,
)
from repro.launch.train import train
from repro.models.cnn import network_layers
from repro.core.platform import paper_platform


def test_training_reduces_loss():
    cfg = get_smoke("qwen2-0.5b")
    out = train(cfg, steps=25, batch=4, seq=32, log_every=0)
    losses = out["losses"]
    first = sum(losses[:5]) / 5
    last = sum(losses[-5:]) / 5
    assert last < first - 0.2, (first, last)
    assert all(math.isfinite(l) for l in losses)


def test_training_resumes_exactly(tmp_path):
    """Crash/restart mid-run continues the same trajectory (fault tolerance)."""
    cfg = get_smoke("granite-3-2b")
    full = train(cfg, steps=12, batch=2, seq=16, ckpt_dir=tmp_path / "a", save_every=6, log_every=0)
    # run 1: first 6 steps only (simulated crash at step 6); same LR horizon
    part = train(cfg, steps=6, schedule_steps=12, batch=2, seq=16, ckpt_dir=tmp_path / "b", save_every=6, log_every=0)
    resumed = train(cfg, steps=12, batch=2, seq=16, ckpt_dir=tmp_path / "b", save_every=6, log_every=0)
    np.testing.assert_allclose(
        np.asarray(full["losses"][6:]), np.asarray(resumed["losses"]), rtol=2e-4, atol=2e-4
    )


def test_shisha_matches_exhaustive_search_quality():
    """Paper Fig. 5: Shisha's solution ~= ES while exploring a tiny fraction."""
    layers = network_layers("synthnet")
    plat = paper_platform(4)
    ev = DatabaseEvaluator(plat, layers)
    es = exhaustive_search(Trace(ev), len(layers), max_depth=3)
    sh = run_shisha(weights(layers), Trace(ev), "H3", n_stages=3)
    ratio = sh.result.best_throughput / es.best_throughput
    assert ratio >= 0.9, ratio
    assert sh.trace.n_trials < 0.01 * es.n_explored


def test_shisha_converges_faster_than_baselines():
    """Paper Fig. 4: convergence wall-clock advantage (same cost accounting)."""
    from repro.core import hill_climbing, simulated_annealing

    layers = network_layers("synthnet")
    plat = paper_platform(8)
    ws = weights(layers)

    sh = run_shisha(ws, Trace(DatabaseEvaluator(plat, layers)), "H3")
    t_sh = sh.trace.wall
    target = sh.result.best_throughput

    def time_to_reach(trace):
        for t in trace.trials:
            pass
        best = 0.0
        for t in trace.trials:
            best = max(best, t.throughput)
            if best >= 0.95 * target:
                return t.t_wall
        return float("inf")

    tr_hc = Trace(DatabaseEvaluator(plat, layers))
    hill_climbing(tr_hc, len(ws), budget_s=60 * t_sh)
    tr_sa = Trace(DatabaseEvaluator(plat, layers))
    simulated_annealing(tr_sa, len(ws), budget_s=60 * t_sh)
    # Shisha reaches its solution faster than HC/SA reach 95% of it
    assert t_sh < min(time_to_reach(tr_hc), time_to_reach(tr_sa)) * 1.01
