"""shisha-lint self-tests: the rule suite, the pragma machinery, and the
tree-clean gate.

Three layers of guarantee:

  * every registered rule demonstrably fires on its minimal bad fixture
    and stays silent on the paired clean fixture;
  * the suppression machinery is live in both directions — a pragma
    suppresses exactly its finding, and a pragma that suppresses nothing
    is itself an error — so the pragma inventory cannot go stale;
  * the shipped tree is clean: ``python -m repro.analysis src/`` exits 0,
    and deleting any single pragma in ``src/`` re-surfaces a real finding
    (proving the gate would catch the regression).
"""

import json
import re
from pathlib import Path

import pytest

from repro.analysis import RULES, lint_source, run
from repro.analysis.cli import main
from repro.analysis.framework import USELESS_SUPPRESSION, BAD_PRAGMA

FIXTURES = Path(__file__).resolve().parent / "fixtures" / "lint"
REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"

PRAGMA_RE = re.compile(r"\s*#\s*shisha:\s*allow\(([^)]*)\)")

RULE_FIXTURES = [
    ("wall-clock", "wall_clock"),
    ("unseeded-random", "unseeded_random"),
    ("set-iteration", "set_iteration"),
    ("unkeyed-sort", "unkeyed_sort"),
    ("telemetry-guard", "telemetry_guard"),
    ("id-ordering", "id_ordering"),
    ("float-accum", "float_accum"),
    ("event-past", "event_past"),
    ("wall-clock", "thermal_accum"),
    ("float-accum", "thermal_accum"),
    ("unseeded-random", "mtbf_sampler"),
]


# -- registry shape ----------------------------------------------------------


def test_registry_covers_the_contracts():
    names = set(RULES)
    assert len(names) >= 8
    expected = {r for r, _ in RULE_FIXTURES} | {"import-layering"}
    assert expected <= names


# -- per-rule fixtures -------------------------------------------------------


@pytest.mark.parametrize("rule,stem", RULE_FIXTURES)
def test_rule_fires_on_bad_fixture(rule, stem):
    report = run([FIXTURES / f"{stem}_bad.py"])
    fired = [f for f in report.findings if f.rule == rule]
    assert fired, f"{rule} did not fire on its bad fixture"
    assert all(f.line > 0 and f.path.endswith(f"{stem}_bad.py") for f in fired)


@pytest.mark.parametrize("rule,stem", RULE_FIXTURES)
def test_clean_fixture_is_fully_clean(rule, stem):
    report = run([FIXTURES / f"{stem}_clean.py"])
    assert report.findings == [], [f.format() for f in report.findings]


# -- layering ----------------------------------------------------------------


def test_layering_contract_violations():
    report = run([FIXTURES / "layering_bad"])
    msgs = [f.message for f in report.findings if f.rule == "import-layering"]
    assert len(msgs) == 2
    assert any("repro.telemetry may not import repro.serve" in m for m in msgs)
    assert any(
        "repro.core may not import repro.interconnect" in m and "lazily" in m
        for m in msgs
    )


def test_layering_clean_tree():
    report = run([FIXTURES / "layering_clean"])
    assert report.findings == []


def test_import_cycle_detected():
    report = run([FIXTURES / "cycle"])
    cyc = [f for f in report.findings if f.rule == "import-layering"]
    assert len(cyc) == 1
    assert "mod_a -> mod_b -> mod_a" in cyc[0].message


def test_lazy_import_is_not_a_cycle():
    a = "def get():\n    import mod_b\n    return mod_b\n"
    # a one-file program can't cycle; check the lazy classifier directly
    from repro.analysis.framework import source_context
    from repro.analysis.layering import collect_edges

    edges = collect_edges(source_context(a, module="mod_a"))
    assert [e.lazy for e in edges] == [True]


# -- suppression pragmas -----------------------------------------------------


def test_pragma_suppresses_and_is_load_bearing():
    src = (FIXTURES / "suppression_ok.py").read_text()
    report = lint_source(src, display="suppression_ok.py")
    assert report.findings == []
    assert [f.rule for f in report.suppressed] == ["wall-clock"]
    stripped = PRAGMA_RE.sub("", src)
    report = lint_source(stripped, display="suppression_ok.py")
    assert [f.rule for f in report.findings] == ["wall-clock"]


def test_useless_pragma_is_an_error():
    report = run([FIXTURES / "suppression_useless.py"])
    assert [f.rule for f in report.findings] == [USELESS_SUPPRESSION]


def test_unknown_rule_in_pragma_is_an_error():
    report = lint_source("x = 1  # shisha: allow(no-such-rule)\n")
    assert [f.rule for f in report.findings] == [BAD_PRAGMA]


def test_pragma_mentions_in_docstrings_are_inert():
    report = lint_source('"""docs say # shisha: allow(wall-clock)."""\nx = 1\n')
    assert report.findings == []
    assert report.suppressed == []


# -- the tree-clean gate -----------------------------------------------------


def test_src_tree_is_clean():
    report = run([SRC])
    assert report.findings == [], [f.format() for f in report.findings]
    assert report.suppressed, "expected load-bearing pragmas in src/"


def test_cli_gate_exits_zero_on_src(capsys):
    assert main([str(SRC)]) == 0
    assert "0 error(s)" in capsys.readouterr().out


def test_cli_json_report_and_exit_codes(tmp_path, capsys):
    out = tmp_path / "lint.json"
    rc = main(
        [str(FIXTURES / "wall_clock_bad.py"), "--format=json", "--output", str(out)]
    )
    capsys.readouterr()
    assert rc == 1
    payload = json.loads(out.read_text())
    assert payload["tool"] == "shisha-lint"
    assert payload["summary"]["errors"] >= 1
    assert all(f["rule"] == "wall-clock" for f in payload["findings"])
    rc = main([str(FIXTURES / "wall_clock_bad.py"), "--report-only"])
    capsys.readouterr()
    assert rc == 0


def _module_for(py: Path) -> str:
    return ".".join(py.relative_to(SRC).with_suffix("").parts).removesuffix(
        ".__init__"
    )


def test_every_pragma_in_src_is_load_bearing():
    """Deleting any single suppression pragma must fail the gate."""
    from repro.analysis.framework import parse_pragmas

    checked = 0
    for py in sorted(SRC.rglob("*.py")):
        src = py.read_text()
        lines = src.splitlines(keepends=True)
        for pragma in parse_pragmas(src):
            i = pragma.line - 1
            mutated = "".join(
                PRAGMA_RE.sub("", l) if j == i else l for j, l in enumerate(lines)
            )
            report = lint_source(mutated, display=str(py), module=_module_for(py))
            resurfaced = [f for f in report.findings if f.rule in pragma.rules]
            assert resurfaced, (
                f"{py}:{pragma.line}: pragma allow({', '.join(pragma.rules)}) "
                "suppresses nothing — the gate would not notice its deletion"
            )
            checked += 1
    assert checked >= 3, "expected at least the known pragmas in src/"


def test_report_is_deterministic():
    a = run([FIXTURES])
    b = run([FIXTURES])
    assert [f.to_json() for f in a.findings] == [f.to_json() for f in b.findings]
    assert [f.to_json() for f in a.suppressed] == [f.to_json() for f in b.suppressed]
