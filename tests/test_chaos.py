"""Chaos layer: seeded fault injection, link-fault routing, resilience.

Contracts pinned here:

  1. **Trace purity** — a chaos trace is a pure function of (model,
     platform shape, horizon): regenerating it, in any process, with
     either event engine, yields the identical event tuple.
  2. **Degenerate equivalence** — attaching :func:`repro.faults.no_faults`
     (or nothing) reproduces the fault-free serve results *and* telemetry
     exports bit-for-bit (the fabric-playbook off-by-default contract).
  3. **Link-fault routing** — dead links leave the candidate routes,
     severed stage boundaries price ``inf``, the ``"link-loss"`` drift is
     detected, and the autotuner's placement rescue re-tunes around the
     cut (charged to the Trace).
  4. **Resilience accounting** — deadlines, retries, shedding and the
     goodput/availability arithmetic in :class:`SimResult`, all
     strict-JSON serializable even when nothing completes.
"""

import dataclasses
import json
import math

import pytest

from repro.core import DatabaseEvaluator, Trace, generate_seed, paper_platform, tune, weights
from repro.core.config import PipelineConfig
from repro.faults import (
    FAULT_KINDS,
    FaultEvent,
    FaultInjector,
    FaultModel,
    ResiliencePolicy,
    no_faults,
)
from repro.faults.injector import _down_intervals, _merge, stream
from repro.interconnect import mesh2d, uniform_fabric
from repro.models.cnn import network_layers
from repro.serve import (
    ContinuousShisha,
    HeapEventLoop,
    PoissonTraffic,
    ServingSimulator,
    Tenant,
    co_serve,
)
from repro.telemetry import Telemetry


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tuned():
    layers = network_layers("synthnet")
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=1e9, latency=1e-6))
    )
    ev = DatabaseEvaluator(plat, layers)
    res = tune(generate_seed(weights(layers), plat), Trace(ev))
    return {
        "layers": layers,
        "plat": plat,
        "conf": res.best_conf,
        "cap": res.best_throughput,
    }


CHAOS = FaultModel(
    seed=7,
    ep_mtbf={1: 8.0, 2: 8.0},
    ep_mttr={1: 2.0, 2: 2.0},
    link_mtbf=12.0,
    link_mttr=2.0,
    batch_error_p=0.03,
)


def _run(tuned, platform, *, resilience=None, autotuner=None, loop=None, telemetry=None):
    ev = DatabaseEvaluator(platform, tuned["layers"])
    sim = ServingSimulator(
        ev,
        tuned["conf"],
        slo=1.0,
        resilience=resilience,
        autotuner=autotuner,
        loop=loop,
        telemetry=telemetry,
    )
    arrivals = PoissonTraffic(rate=10.0, seed=5).arrivals(30.0)
    return sim.run(arrivals, 30.0)


# ---------------------------------------------------------------------------
# 1. trace purity and injector invariants
# ---------------------------------------------------------------------------


def test_chaos_trace_is_pure_and_sorted(tuned):
    plat = tuned["plat"]
    a = FaultInjector(CHAOS).trace(plat, 30.0)
    b = FaultInjector(CHAOS).trace(plat, 30.0)
    assert a == b and len(a) > 0
    assert all(e.kind in FAULT_KINDS for e in a)
    assert all(x.t <= y.t for x, y in zip(a, a[1:]))
    kinds = {e.kind for e in a}
    assert "dropout" in kinds and "link" in kinds and "revival" in kinds
    # a different seed perturbs the trace; a longer horizon only extends it
    assert FaultInjector(dataclasses.replace(CHAOS, seed=8)).trace(plat, 30.0) != a
    longer = FaultInjector(CHAOS).trace(plat, 60.0)
    assert {e for e in a if e.t < 30.0} <= set(longer)


def test_stream_keying_is_stable_and_independent():
    assert stream(1, "ep", 0).random() == stream(1, "ep", 0).random()
    assert stream(1, "ep", 0).random() != stream(1, "ep", 1).random()
    assert stream(1, "ep", 0).random() != stream(2, "ep", 0).random()
    # adding a class never perturbs another stream's draws
    assert stream(1, "link", (0, 1)).random() != stream(1, "degrade", (0, 1)).random()


def test_domain_failure_union_never_revives_inside_overlap():
    """An EP down for (EP-process OR domain-process) revives only when the
    merged interval ends — overlapping failures emit no early revival."""
    merged = _merge([(1.0, 4.0), (3.0, 6.0), (8.0, 9.0)])
    assert merged == [(1.0, 6.0), (8.0, 9.0)]
    fm = FaultModel(
        seed=3,
        ep_mtbf={1: 4.0},
        ep_mttr={1: 2.0},
        domains=((0, 1),),
        domain_mtbf=4.0,
        domain_mttr=2.0,
    )
    trace = FaultInjector(fm).trace(paper_platform(4), 50.0)
    state = {}
    for ev in trace:
        if ev.kind == "dropout":
            assert state.get(ev.ep) != "down", f"double dropout for EP {ev.ep}"
            state[ev.ep] = "down"
        elif ev.kind == "revival":
            assert state.get(ev.ep) == "down", f"revival of live EP {ev.ep}"
            state[ev.ep] = "up"


def test_hard_link_failure_shadows_degradation(tuned):
    fm = FaultModel(
        seed=5, link_mtbf=6.0, link_mttr=3.0, degrade_mtbf=4.0, degrade_mttr=4.0
    )
    trace = FaultInjector(fm).trace(tuned["plat"], 40.0)
    factors = {}
    for ev in trace:
        assert ev.kind == "link"
        assert ev.factor != factors.get(ev.link), "no-op link event emitted"
        factors[ev.link] = ev.factor
    assert 0.0 in factors.values() or any(
        f == fm.degrade_factor for f in factors.values()
    )


def test_batch_failure_streams_are_label_keyed():
    inj = FaultInjector(dataclasses.replace(CHAOS, batch_error_p=0.5))
    sa, sa2, sb = (inj.batch_failures(l) for l in ("a", "a", "b"))
    a = [sa.fails() for _ in range(64)]
    a2 = [sa2.fails() for _ in range(64)]
    b = [sb.fails() for _ in range(64)]
    assert a == a2 and a != b
    assert FaultInjector(no_faults()).batch_failures("a") is None


def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(t=1.0, kind="dropout")
    with pytest.raises(ValueError):
        FaultEvent(t=1.0, kind="link", link=(0, 1), factor=2.0)
    with pytest.raises(ValueError):
        FaultModel(ep_mtbf={1: 5.0})  # MTBF without MTTR
    with pytest.raises(ValueError):
        paper_platform(4).with_faults(
            FaultModel(domains=((0, 9),), domain_mtbf=1.0, domain_mttr=1.0)
        )


# ---------------------------------------------------------------------------
# 2. degenerate contract — off by default, bit-for-bit
# ---------------------------------------------------------------------------


def test_no_faults_attachment_is_bit_for_bit_degenerate(tuned):
    tl_bare, tl_none = Telemetry(), Telemetry()
    bare = _run(tuned, tuned["plat"], telemetry=tl_bare)
    degen = _run(tuned, tuned["plat"].with_faults(no_faults()), telemetry=tl_none)
    assert bare == degen
    assert tl_bare.export_jsonl() == tl_none.export_jsonl()


def test_resilience_policy_alone_is_inert_when_nothing_fails(tuned):
    """Deadline/retry/shed knobs only act on faults or pressure: with no
    chaos and a queue cap the traffic never reaches, results are identical
    except for the goodput accounting the deadline defines."""
    pol = ResiliencePolicy(deadline_s=1e9, max_retries=2, queue_cap=10_000)
    bare = _run(tuned, tuned["plat"])
    guarded = _run(tuned, tuned["plat"], resilience=pol)
    assert guarded.n_shed == 0 and guarded.n_failed == 0 and guarded.n_retries == 0
    assert guarded.latencies == bare.latencies
    assert guarded.goodput_rps == bare.throughput_rps


# ---------------------------------------------------------------------------
# determinism: same seeds -> identical results, on both engines
# ---------------------------------------------------------------------------


def test_chaos_run_is_deterministic_across_reruns_and_engines(tuned):
    plat = tuned["plat"]
    first = _run(tuned, plat.with_faults(CHAOS))
    rerun = _run(tuned, plat.with_faults(CHAOS))
    legacy = _run(tuned, plat.with_faults(CHAOS), loop=HeapEventLoop())
    assert first == rerun == legacy
    assert first.n_retries > 0  # the chaos actually bit


def test_chaos_telemetry_is_deterministic(tuned):
    tl_a, tl_b = Telemetry(), Telemetry()
    ra = _run(tuned, tuned["plat"].with_faults(CHAOS), telemetry=tl_a)
    rb = _run(tuned, tuned["plat"].with_faults(CHAOS), telemetry=tl_b)
    assert ra == rb
    assert tl_a.export_jsonl() == tl_b.export_jsonl()
    names = {e.name for e in tl_a.tracer.events}
    assert "chaos:link" in names or "chaos:dropout" in names


# ---------------------------------------------------------------------------
# 3. link faults: routing, pricing, drift detection, rescue
# ---------------------------------------------------------------------------


def test_dead_link_leaves_candidate_routes():
    topo = mesh2d(2, 4, bw=1e9, latency=1e-6)
    cut = topo.without_link((0, 1))
    assert (0, 1) not in cut.links
    for path in cut.k_shortest_paths(0, 1, 4):
        hops = list(zip(path, path[1:]))
        assert (0, 1) not in hops and (1, 0) not in hops
    with pytest.raises(KeyError):
        topo.without_link((0, 5))


def test_degraded_links_scale_bandwidth_and_zero_removes():
    topo = mesh2d(1, 3, bw=1e9, latency=1e-6)
    worse = topo.with_degraded_links({(0, 1): 0.5})
    assert worse.links[(0, 1)].bw == pytest.approx(0.5e9)
    cut = topo.with_degraded_links({(1, 2): 0.0})
    assert (1, 2) not in cut.links
    assert not cut.connected(0, 2)
    assert tuple(cut.components()) == ((0, 1), (2,))


def test_link_fault_severs_flow_and_heals_back_identically(tuned):
    plat = paper_platform(4).with_fabric(
        uniform_fabric(mesh2d(1, 4, bw=1e9, latency=1e-6))
    )
    fabric = plat.fabric
    before = fabric.latency_ep(0, 3)
    fabric.fail_link(1, 2)  # the only path 0..3 crosses it
    assert math.isinf(fabric.latency_ep(0, 3))
    assert fabric.marooned_eps() == (2, 3)
    fabric.restore_link(1, 2)
    assert fabric.latency_ep(0, 3) == before
    assert fabric.fault_fingerprint() == ()


def test_link_state_is_shared_with_restricted_lane_fabrics():
    plat = paper_platform(4).with_fabric(
        uniform_fabric(mesh2d(1, 4, bw=1e9, latency=1e-6))
    )
    lane = plat.fabric.restrict([2, 3])
    plat.fabric.fail_link(2, 3)
    assert math.isinf(lane.latency_ep(0, 1))  # lane-local indices for EPs 2,3
    plat.fabric.restore_link(2, 3)
    assert math.isfinite(lane.latency_ep(0, 1))


def test_severed_boundary_charges_only_reconfig_cost():
    layers = network_layers("synthnet")
    plat = paper_platform(4).with_fabric(
        uniform_fabric(mesh2d(1, 4, bw=1e9, latency=1e-6))
    )
    ev = DatabaseEvaluator(plat, layers)
    conf = PipelineConfig(stages=(len(layers) - 1, 1), eps=(1, 2))
    trace = Trace(ev)
    plat.fabric.fail_link(1, 2)
    tp = trace.execute(conf)
    assert tp == 0.0
    assert trace.wall == pytest.approx(trace.reconfig_overhead)
    plat.fabric.restore_link(1, 2)


def test_link_loss_drift_detected_and_rescued_by_retune():
    """Cutting the only link under a stage boundary must surface as a
    ``"link-loss"`` drift and be answered by a placement rescue that gets
    the pipeline flowing again on the surviving component."""
    layers = network_layers("synthnet")
    plat = paper_platform(4).with_fabric(
        uniform_fabric(mesh2d(1, 4, bw=1e9, latency=1e-6))
    )
    ev = DatabaseEvaluator(plat, layers)
    conf = PipelineConfig(stages=(len(layers) - 1, 1), eps=(1, 2))
    tuner = ContinuousShisha(
        plat,
        layers,
        make_evaluator=lambda p: DatabaseEvaluator(p, layers),
        measure_batches=2,
        alpha=4,
    )
    sim = ServingSimulator(ev, conf, slo=5.0, autotuner=tuner, monitor_interval=0.5)
    sim.schedule_link_fault(5.0, 1, 2, 0.0)
    res = sim.run(PoissonTraffic(rate=5.0, seed=3).arrivals(40.0), 40.0)
    kinds = [r["kind"] for r in res.reconfigs]
    assert "link-loss" in kinds
    rescue = next(r for r in res.reconfigs if r["kind"] == "link-loss")
    assert rescue["tuning_cost_s"] > 0.0  # the rescue was charged to the Trace
    # the pipeline flows again after the rescue: completions keep accruing
    assert res.n_completed > 0
    late = [l for l in res.latencies if l < math.inf]
    assert len(late) == res.n_completed
    plat.fabric.link_state.clear()


# ---------------------------------------------------------------------------
# 4. request-level resilience and honest accounting
# ---------------------------------------------------------------------------


def test_queue_cap_sheds_and_accounts_availability(tuned):
    pol = ResiliencePolicy(deadline_s=0.5, max_retries=1, queue_cap=4)
    slow = FaultModel(seed=2, ep_mtbf={1: 3.0, 2: 3.0}, ep_mttr={1: 4.0, 2: 4.0})
    res = _run(tuned, tuned["plat"].with_faults(slow), resilience=pol)
    assert res.n_shed > 0
    assert res.availability < 1.0
    assert res.availability == pytest.approx(
        1.0 - (res.n_shed + res.n_failed) / res.n_arrived
    )
    assert res.goodput_rps <= res.throughput_rps
    # bounded admission: the stage-0 queue can never exceed the cap
    assert res.n_arrived == res.n_completed + res.n_shed + res.n_failed + (
        res.n_in_flight + res.n_queued
    )


def test_retry_cap_fails_requests_deterministically(tuned):
    hot = dataclasses.replace(CHAOS, batch_error_p=0.6)
    pol = ResiliencePolicy(deadline_s=None, max_retries=0, backoff_s=0.01)
    res = _run(tuned, tuned["plat"].with_faults(hot), resilience=pol)
    assert res.n_failed > 0 and res.n_retries == 0
    rerun = _run(tuned, tuned["plat"].with_faults(hot), resilience=pol)
    assert res == rerun


def test_backoff_is_keyed_not_streamed():
    pol = ResiliencePolicy(backoff_s=0.1, jitter=0.5, seed=9)
    a = pol.backoff(3, 1)
    assert a == pol.backoff(3, 1)  # order-independent determinism
    assert pol.backoff(3, 2) > a * 1.0  # exponential growth dominates jitter
    assert pol.backoff(4, 1) != a
    assert ResiliencePolicy(jitter=0.0).backoff(1, 2) == pytest.approx(0.1)


def test_all_eps_dead_result_is_strict_json(tuned):
    """Nothing ever completes: every percentile is None, not NaN, and the
    whole result serializes under ``allow_nan=False``."""
    doom = FaultModel(seed=1, ep_mtbf={1: 1e-9, 2: 1e-9}, ep_mttr={1: 1e9, 2: 1e9})
    res = _run(tuned, tuned["plat"].with_faults(doom))
    assert res.n_completed == 0
    assert res.p50 is None and res.p95 is None and res.p99 is None
    assert res.p95_wait is None
    json.dumps(dataclasses.asdict(res), allow_nan=False)
    assert "n/a" in res.summary()


def test_dropout_requeue_resets_wait_clock(tuned):
    """Satellite regression: a request whose batch is aborted by a dropout
    must not keep its pre-fault ``t_start`` — its wait time spans until the
    service that actually completed it began."""
    layers = tuned["layers"]
    plat = paper_platform(2)
    ev = DatabaseEvaluator(plat, layers)
    conf = PipelineConfig(stages=(len(layers),), eps=(0,))
    sim = ServingSimulator(ev, conf, slo=50.0, max_batch=1)
    beat = ev.stage_times(conf)[0]
    sim.schedule_dropout(beat / 2.0, 0)  # mid-service of the first request
    sim.schedule_revival(10.0, 0)
    res = sim.run([0.0], 30.0)
    assert res.n_completed == 1
    assert res.p95_wait == pytest.approx(10.0)  # not 0.0: service restarted


def test_co_serve_chaos_is_deterministic_and_resilient_knob_wires_through():
    layers = tuple(network_layers("synthnet"))
    plat = paper_platform(8).with_fabric(
        uniform_fabric(mesh2d(2, 4, bw=1e9, latency=1e-6))
    )
    tenants = [
        Tenant(name="a", layers=layers, traffic=PoissonTraffic(rate=4.0, seed=1)),
        Tenant(name="b", layers=layers, traffic=PoissonTraffic(rate=4.0, seed=2)),
    ]
    chaos = dataclasses.replace(CHAOS, batch_error_p=0.1)
    pol = ResiliencePolicy(deadline_s=5.0, max_retries=2, queue_cap=256)

    def go():
        return co_serve(
            plat,
            tenants,
            horizon=12.0,
            chaos=chaos,
            resilience=pol,
            measure_batches=2,
            alpha=4,
        )

    ra, rb = go(), go()
    assert [r.sim for r in ra.results] == [r.sim for r in rb.results]
    assert all(r.sim.goodput_rps <= r.sim.throughput_rps for r in ra.results)


def test_subplatform_drops_fault_spec():
    from repro.serve import subplatform

    plat = paper_platform(4).with_faults(CHAOS)
    sub = subplatform(plat, [0, 1], "sub")
    assert sub.faults is None
    assert plat.without([3]).faults is None
