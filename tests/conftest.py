"""Shared test configuration.

Hypothesis runs under a *fixed* profile so the property suites are as
reproducible as everything else in this repo: ``ci`` (the default) is
derandomized with a bounded example budget and no deadline — identical
failures on every machine, no flaky time-based aborts.  Set
``HYPOTHESIS_PROFILE=dev`` locally for a randomized, slightly smaller
budget when hunting for new counterexamples.
"""

import os

try:
    from hypothesis import HealthCheck, settings
except ImportError:  # property tests importorskip; plain suites still run
    pass
else:
    settings.register_profile(
        "ci",
        derandomize=True,
        max_examples=60,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.register_profile("dev", max_examples=30, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
