"""Design-space accounting + PipelineConfig invariants (property tests)."""

import itertools

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import PipelineConfig, compositions, enumerate_configs, random_config, space_size
import random


@given(st.integers(2, 10), st.integers(1, 5))
@settings(max_examples=60, deadline=None)
def test_compositions_count_and_validity(L, d):
    d = min(d, L)
    comps = list(compositions(L, d))
    import math

    assert len(comps) == math.comb(L - 1, d - 1)
    for c in comps:
        assert sum(c) == L and all(x >= 1 for x in c)


@given(st.integers(2, 7), st.integers(2, 5))
@settings(max_examples=30, deadline=None)
def test_space_size_matches_enumeration(L, E):
    assert space_size(L, E) == sum(1 for _ in enumerate_configs(L, E))


@given(st.integers(0, 10_000), st.integers(4, 30), st.integers(2, 6))
@settings(max_examples=100, deadline=None)
def test_random_config_valid(seed, L, E):
    conf = random_config(random.Random(seed), L, E)
    assert conf.n_layers == L
    assert len(set(conf.eps)) == conf.depth <= E


@given(st.integers(0, 5000))
@settings(max_examples=100, deadline=None)
def test_move_layer_preserves_invariants(seed):
    rng = random.Random(seed)
    conf = random_config(rng, 12, 4)
    for cand in conf.neighbours():
        assert cand.n_layers == 12
        assert len(set(cand.eps)) == cand.depth


def test_duplicate_ep_rejected():
    with pytest.raises(ValueError):
        PipelineConfig(stages=(1, 1), eps=(0, 0))


def test_empty_stage_rejected():
    with pytest.raises(ValueError):
        PipelineConfig(stages=(0, 2), eps=(0, 1))


def test_boundaries_and_stage_of_layer():
    conf = PipelineConfig(stages=(2, 3, 1), eps=(0, 1, 2))
    assert conf.boundaries() == [(0, 2), (2, 5), (5, 6)]
    assert conf.stage_of_layer(0) == 0
    assert conf.stage_of_layer(4) == 1
    assert conf.stage_of_layer(5) == 2
