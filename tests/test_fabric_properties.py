"""Property-based fabric/scheduler contract (Hypothesis).

The adaptive router makes routing a *decision*, which is exactly when a
fixed example suite stops being enough: the contract has to hold over every
connected topology, every heterogeneous bandwidth assignment and every flow
multiset, not just the presets the benchmarks use.  Four properties are
pinned, each the load-bearing assumption of a different consumer:

  * **determinism** — the adaptive assignment is a pure function of
    (topology, flow multiset, seed): repeated calls and freshly rebuilt
    identical fabrics agree.  Every evaluator, tuner and the serving
    co-simulator rely on this for replayable results.
  * **path validity** — every assigned route is a loopless walk of adjacent
    links from the flow's source node to its destination node.
  * **contention monotonicity** (static routing) — adding a flow never
    lowers any existing flow's priced cost: the fair-share + hotspot model
    is monotone, which is what makes congestion a conservative signal for
    the tuner.  (Adaptive routing deliberately trades this per-flow
    guarantee for the total-cost one below: a re-route triggered by a new
    flow may relieve a link some third flow sits on.)
  * **adaptive never worse than static** — on the same flow set the
    adaptive assignment's *total* priced cost never exceeds all-static
    (ties keep the static assignment bit-for-bit), so leaving the adaptive
    router on can never regress a schedule's evaluation.

Runs under the fixed, derandomized Hypothesis profile from ``conftest.py``;
marked ``slow`` so CI runs it in its own step.
"""

import pytest

pytestmark = pytest.mark.slow

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.interconnect import Fabric, Flow, Link, Topology
from repro.interconnect.topology import path_links

#: heterogeneous but well-conditioned link speed grades (bytes/s) and
#: latency grades (s) — sampled per link, so one topology mixes fast and
#: slow links, which is the regime the adaptive router exists for
_BW_GRADES = (1e6, 1e7, 5e7, 1e8, 1e9)
_LAT_GRADES = (0.0, 1e-7, 1e-6, 1e-4)
_NBYTES = (1e3, 1e5, 2e6)

_links = st.builds(
    Link,
    bw=st.sampled_from(_BW_GRADES),
    latency=st.sampled_from(_LAT_GRADES),
)


@st.composite
def topologies(draw) -> Topology:
    """Random connected topology with heterogeneous links.

    A random spanning tree guarantees connectivity; extra random edges add
    the alternative paths adaptive routing chooses among.
    """
    n = draw(st.integers(min_value=2, max_value=7))
    links = {}
    for v in range(1, n):
        u = draw(st.integers(min_value=0, max_value=v - 1))
        links[(u, v)] = draw(_links)
    n_extra = draw(st.integers(min_value=0, max_value=2 * n))
    for _ in range(n_extra):
        a = draw(st.integers(min_value=0, max_value=n - 1))
        b = draw(st.integers(min_value=0, max_value=n - 1))
        if a != b:
            links[(min(a, b), max(a, b))] = draw(_links)
    return Topology(name=f"rand{n}", n_nodes=n, links=links)


@st.composite
def fabric_and_flows(draw) -> tuple[Topology, list[Flow]]:
    topo = draw(topologies())
    n_flows = draw(st.integers(min_value=1, max_value=8))
    flows = []
    for _ in range(n_flows):
        s = draw(st.integers(min_value=0, max_value=topo.n_nodes - 1))
        d = draw(st.integers(min_value=0, max_value=topo.n_nodes - 1))
        flows.append(
            Flow(src=s, dst=d, nbytes=draw(st.sampled_from(_NBYTES)), nodes=True)
        )
    return topo, flows


def _rebuild(topo: Topology) -> Topology:
    """A structurally identical but fresh Topology (fresh route caches)."""
    return Topology(
        name=topo.name,
        n_nodes=topo.n_nodes,
        links=dict(topo.links),
        coords=dict(topo.coords) if topo.coords is not None else None,
    )


def _fabric(topo: Topology, routing: str, seed: int = 0) -> Fabric:
    return Fabric(
        topology=topo,
        ep_nodes=tuple(range(topo.n_nodes)),
        routing=routing,
        seed=seed,
    )


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------


@given(fabric_and_flows(), st.sampled_from([0, 7]))
@settings(max_examples=60)
def test_adaptive_assignment_is_deterministic(tf, seed):
    topo, flows = tf
    fab = _fabric(topo, "adaptive", seed)
    first = fab.route_flows(flows)
    assert fab.route_flows(flows) == first, "assignment changed between calls"
    assert fab.flow_times(flows) == fab.flow_times(flows)
    rebuilt = _fabric(_rebuild(topo), "adaptive", seed)
    assert rebuilt.route_flows(flows) == first, "assignment differs across instances"


@given(fabric_and_flows(), st.randoms(use_true_random=False))
@settings(max_examples=60)
def test_adaptive_assignment_is_a_function_of_the_flow_multiset(tf, rnd):
    """Reordering the flow list must not change any flow's route or price:
    the sweep visits flows in canonical identity order and tie-breaks hash
    the flow's identity, not its list position."""
    topo, flows = tf
    fab = _fabric(topo, "adaptive")
    perm = list(range(len(flows)))
    rnd.shuffle(perm)
    shuffled = [flows[i] for i in perm]
    routes, times = fab.route_flows(flows), fab.flow_times(flows)
    p_routes, p_times = fab.route_flows(shuffled), fab.flow_times(shuffled)

    def identity(f):
        return (f.src, f.dst, f.nbytes)

    # exact duplicates are mutually interchangeable, so the invariant is on
    # the multiset of (flow identity, route, price) triples ...
    assert sorted(zip(map(identity, flows), routes, times)) == sorted(
        zip(map(identity, shuffled), p_routes, p_times)
    )
    # ... which collapses to exact per-position equality when identities
    # are unique
    if len(set(map(identity, flows))) == len(flows):
        for j, i in enumerate(perm):
            assert p_routes[j] == routes[i] and p_times[j] == times[i]


# ---------------------------------------------------------------------------
# path validity
# ---------------------------------------------------------------------------


def _assert_valid_walk(route, src, dst, topo):
    if src == dst:
        assert route == ()
        return
    node, visited = src, {src}
    for (u, v) in route:
        assert (u, v) in topo.links, f"route uses non-link {(u, v)}"
        assert node in (u, v), f"route {route} breaks at {node}"
        node = v if node == u else u
        assert node not in visited, f"route {route} revisits {node} (cycle)"
        visited.add(node)
    assert node == dst, f"route {route} ends at {node}, not {dst}"


@given(fabric_and_flows())
@settings(max_examples=60)
def test_adaptive_routes_are_valid_loopless_walks(tf):
    topo, flows = tf
    for routing in ("static", "adaptive"):
        fab = _fabric(topo, routing)
        for f, route in zip(flows, fab.route_flows(flows)):
            _assert_valid_walk(route, f.src, f.dst, topo)


@given(topologies(), st.integers(min_value=1, max_value=5))
@settings(max_examples=60)
def test_k_shortest_paths_are_simple_sorted_and_start_with_the_shortest(topo, k):
    for s in range(topo.n_nodes):
        for d in range(topo.n_nodes):
            if s == d:
                continue
            paths = topo.k_shortest_paths(s, d, k)
            assert 1 <= len(paths) <= k
            assert len(set(paths)) == len(paths), "duplicate path"
            costs = []
            for p in paths:
                assert p[0] == s and p[-1] == d
                assert len(set(p)) == len(p), f"path {p} has a cycle"
                _assert_valid_walk(path_links(p), s, d, topo)
                costs.append(topo._path_cost(p))
            assert costs == sorted(costs), "paths not in deterministic cost order"
            # the enumeration's head agrees with Dijkstra's shortest path
            assert costs[0][0] <= topo.path_latency(s, d) + 1e-15


# ---------------------------------------------------------------------------
# contention monotonicity (static routing)
# ---------------------------------------------------------------------------


@given(fabric_and_flows())
@settings(max_examples=60)
def test_static_contention_monotone_adding_a_flow_never_speeds_anyone_up(tf):
    topo, flows = tf
    fab = _fabric(topo, "static")
    for cut in range(1, len(flows)):
        before = fab.flow_times(flows[:cut])
        after = fab.flow_times(flows[: cut + 1])
        for i, (b, a) in enumerate(zip(before, after)):
            assert a >= b - 1e-12 * max(1.0, b), (
                f"flow {i} sped up from {b} to {a} when flow {cut} was added"
            )


# ---------------------------------------------------------------------------
# adaptive never worse than static
# ---------------------------------------------------------------------------


@given(fabric_and_flows(), st.sampled_from([0, 3, 11]))
@settings(max_examples=60)
def test_adaptive_total_cost_never_exceeds_static(tf, seed):
    topo, flows = tf
    static_total = sum(_fabric(topo, "static").flow_times(flows))
    adaptive_total = sum(_fabric(topo, "adaptive", seed).flow_times(flows))
    assert adaptive_total <= static_total, (
        f"adaptive ({adaptive_total}) priced worse than static ({static_total})"
    )


@given(fabric_and_flows())
@settings(max_examples=30)
def test_adaptive_tie_keeps_the_static_assignment(tf):
    """When adaptive finds nothing strictly better it must return the static
    assignment *itself* (not an equal-cost rearrangement), so turning the
    router on is bit-for-bit free whenever it has nothing to offer."""
    topo, flows = tf
    static = _fabric(topo, "static")
    adaptive = _fabric(topo, "adaptive")
    s_routes, a_routes = static.route_flows(flows), adaptive.route_flows(flows)
    if sum(adaptive.flow_times(flows)) == sum(static.flow_times(flows)):
        assert a_routes == s_routes
