"""The §Perf knobs must not change model semantics (only layout/schedule).

Each knob flips an execution strategy; the math — loss values, decode
logits — must be preserved (bf16 scores excepted: it trades precision and
is tested with a loose bound).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.lm_common import init_params
from repro.models.transformer import train_loss

KEY = jax.random.PRNGKey(11)


def _loss(cfg, params, batch):
    return float(jax.jit(lambda p, b: train_loss(cfg, p, b))(params, batch))


def _setup(arch="qwen3-32b"):
    cfg = dataclasses.replace(get_smoke(arch), dtype=jnp.float32)
    params = init_params(cfg, KEY)
    batch = {
        "tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab),
        "labels": jax.random.randint(KEY, (2, 32), 0, cfg.vocab),
    }
    return cfg, params, batch


def test_repeat_kv_preserves_loss():
    cfg, params, batch = _setup()
    base = _loss(cfg, params, batch)
    opt = _loss(dataclasses.replace(cfg, attn_repeat_kv=True), params, batch)
    assert abs(base - opt) < 1e-5, (base, opt)


def test_sp_residuals_flag_preserves_loss():
    cfg, params, batch = _setup("granite-3-2b")
    base = _loss(cfg, params, batch)
    opt = _loss(dataclasses.replace(cfg, sp_residuals=False), params, batch)
    assert abs(base - opt) < 1e-5


def test_attn_q_block_preserves_loss():
    cfg, params, batch = _setup("granite-3-2b")
    base = _loss(dataclasses.replace(cfg, attn_q_block=8), params, batch)
    opt = _loss(dataclasses.replace(cfg, attn_q_block=16), params, batch)
    assert abs(base - opt) < 1e-5


def test_bf16_scores_close():
    cfg, params, batch = _setup("granite-3-2b")
    base = _loss(cfg, params, batch)
    lo = _loss(dataclasses.replace(cfg, attn_fp32_scores=False), params, batch)
    assert abs(base - lo) < 0.05  # precision trade, not semantics


def test_accum_dtype_bf16_close():
    from repro.models.transformer import make_train_step
    from repro.optim import AdamW, AdamWConfig

    cfg, params, batch = _setup("granite-3-2b")
    opt = AdamW(AdamWConfig(total_steps=10, warmup=2, moment_dtype=jnp.float32))
    st = opt.init(params)
    _, _, m32 = jax.jit(make_train_step(cfg, opt, accum=2))(params, st, batch)
    cfgb = dataclasses.replace(cfg, accum_dtype=jnp.bfloat16)
    _, _, mbf = jax.jit(make_train_step(cfgb, opt, accum=2))(params, st, batch)
    assert abs(float(m32["loss"]) - float(mbf["loss"])) < 0.02


def test_repeat_kv_decode_consistency():
    """Decode path is unaffected (repeat_kv only changes full-seq attention)."""
    from repro.models.transformer import init_cache, serve_step

    cfg, params, _ = _setup("qwen3-32b")
    cfg2 = dataclasses.replace(cfg, attn_repeat_kv=True)
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 6), 0, cfg.vocab)
    for c in (cfg, cfg2):
        cache = init_cache(c, 1, 8)
        for t in range(6):
            lg, cache = serve_step(c, params, cache, toks[:, t : t + 1])
        if c is cfg:
            ref = lg
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref), rtol=1e-5, atol=1e-6)
