"""Unit + property tests for the paper's core algorithms (Alg. 1 + Alg. 2)."""

import random

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    AnalyticEvaluator,
    DatabaseEvaluator,
    PipelineConfig,
    Trace,
    conv_layer,
    generate_seed,
    merge_layers,
    paper_platform,
    pick_target,
    run_shisha,
    table3_platform,
    tune,
    weights,
)
from repro.models.cnn import network_layers

# ---------------------------------------------------------------------------
# Eq. 1 / layer tables
# ---------------------------------------------------------------------------


def test_eq1_conv_weight():
    l = conv_layer("c", 14, 14, 256, 3, 3, 512)
    assert l.flops == 2.0 * 14 * 14 * 256 * 3 * 3 * 512


@pytest.mark.parametrize(
    "net,n", [("resnet50", 50), ("yolov3", 52), ("synthnet", 18), ("alexnet", 5)]
)
def test_network_layer_counts(net, n):
    layers = network_layers(net)
    assert len(layers) == n
    assert all(l.flops > 0 and l.bytes_mem > 0 for l in layers)


def test_synthnet_channel_chaining():
    from repro.models.cnn import synthnet_specs

    specs = synthnet_specs(18)
    # repetition r>0 starts from the previous block's output channels
    assert specs[5].c_in == specs[4].k


# ---------------------------------------------------------------------------
# Algorithm 1 (seed generation)
# ---------------------------------------------------------------------------

w_lists = st.lists(st.floats(1.0, 1e6), min_size=2, max_size=40)


@given(w_lists, st.integers(1, 8))
@settings(max_examples=200, deadline=None)
def test_merge_layers_properties(ws, n):
    n = min(n, len(ws))
    groups = merge_layers(ws, n)
    assert len(groups) == n
    flat = [i for g in groups for i in g]
    assert flat == list(range(len(ws)))  # contiguity + completeness
    assert all(len(g) >= 1 for g in groups)


def test_merge_prefers_lighter_neighbour():
    # lightest is index 1 (1.0); lighter neighbour is index 0 (2.0) not 2 (9.)
    groups = merge_layers([2.0, 1.0, 9.0, 9.0], 3)
    assert groups[0] == [0, 1]


@given(w_lists)
@settings(max_examples=100, deadline=None)
def test_seed_is_valid_config(ws):
    plat = paper_platform(8)
    seed = generate_seed(ws, plat)
    conf = seed.conf
    assert conf.n_layers == len(ws)
    assert len(set(conf.eps)) == conf.depth  # injective EP assignment
    assert conf.depth == min(8, len(ws))


def test_rank_w_assigns_heavy_to_fast():
    plat = paper_platform(4)  # EPs 0,1 fast; 2,3 slow
    ws = [100.0, 1.0, 1.0, 1.0]
    seed = generate_seed(ws, plat, n_stages=4, choice="rank_w")
    heavy_stage = max(range(4), key=lambda s: ws[s])
    ranked = plat.ranked()
    assert seed.conf.eps[heavy_stage] == ranked[0]


def test_rank_l_assigns_many_layers_to_slow():
    plat = paper_platform(4)
    ws = [1.0] * 10
    seed = generate_seed(ws, plat, n_stages=3, choice="rank_l")
    sizes = seed.conf.stages
    ranked = plat.ranked()
    # the stage holding the slowest assigned EP must be a max-size stage
    slowest_stage = max(range(3), key=lambda s: ranked.index(seed.conf.eps[s]))
    assert sizes[slowest_stage] == max(sizes)


# ---------------------------------------------------------------------------
# Algorithm 2 (online tuning)
# ---------------------------------------------------------------------------


def _trace(net="synthnet", n_eps=4, db=True):
    layers = network_layers(net)
    plat = paper_platform(n_eps)
    ev = (DatabaseEvaluator if db else AnalyticEvaluator)(plat, layers)
    return layers, plat, Trace(ev)


def test_tune_never_worse_than_seed():
    layers, plat, trace = _trace()
    seed = generate_seed(weights(layers), plat)
    seed_tp = trace.evaluator.throughput(seed.conf)
    res = tune(seed, trace, alpha=10)
    assert res.best_throughput >= seed_tp - 1e-12


def test_tune_terminates_and_counts_alpha():
    layers, plat, trace = _trace()
    seed = generate_seed(weights(layers), plat)
    res = tune(seed, trace, alpha=3)
    assert trace.n_trials <= 10_000
    assert res.best_conf.n_layers == len(layers)


def test_pick_target_prefers_fast_eps():
    plat = paper_platform(4)
    conf = PipelineConfig(stages=(5, 5, 4, 4), eps=(2, 3, 0, 1))  # slow EPs first
    times = [10.0, 1.0, 1.0, 1.0]
    t = pick_target(conf, times, 0, plat, "nlfep")
    assert conf.eps[t] in plat.feps


def test_shisha_explores_tiny_fraction():
    """Paper: ~0.1% of design space for ResNet50-scale networks."""
    from repro.core import space_size

    layers, plat, trace = _trace("resnet50", 8)
    res = run_shisha(weights(layers), trace, "H3")
    frac = trace.n_trials / space_size(len(layers), 8)
    assert frac < 1e-6  # far below even the paper's 0.1%
    assert 5 <= trace.n_trials <= 200


@pytest.mark.parametrize("heuristic", ["H1", "H2", "H3", "H4", "H5", "H6"])
def test_all_heuristics_run(heuristic):
    layers, plat, trace = _trace()
    res = run_shisha(weights(layers), trace, heuristic, rng=random.Random(0))
    assert res.result.best_throughput > 0


def test_stage_collapse_frees_ep():
    """Moving the last layer out of a stage shrinks the pipeline depth."""
    from repro.core.tuner import _move_toward

    conf = PipelineConfig(stages=(1, 5), eps=(0, 1))
    out = _move_toward(conf, 0, 1)
    assert out.depth == 1 and out.stages == (6,) and out.eps == (1,)


# ---------------------------------------------------------------------------
# Table 3 platforms
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("conf,n", [("C1", 2), ("C2", 4), ("C3", 6), ("C4", 6), ("C5", 8)])
def test_table3_platforms(conf, n):
    p = table3_platform(conf)
    assert p.n_eps == n
    assert len(p.feps) >= 1 and len(p.seps) >= 1
