"""Fault tolerance: straggler rebalance, elastic rescale, NaN quarantine."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.core import DatabaseEvaluator, Trace, generate_seed, paper_platform, tune, weights
from repro.models.cnn import network_layers
from repro.runtime import ElasticScheduler, StragglerMitigator, TrainSupervisor


def _make_trace_factory(layers):
    return lambda platform: Trace(DatabaseEvaluator(platform, layers))


def test_straggler_detection_threshold():
    layers = network_layers("synthnet")
    plat = paper_platform(4)
    seed = generate_seed(weights(layers), plat)
    mit = StragglerMitigator(plat, seed.conf, _make_trace_factory(layers))
    ok, _ = mit.check([1.0, 1.0, 1.05, 1.0])
    assert not ok
    hit, stage = mit.check([1.0, 1.0, 4.0, 1.0])
    assert hit and stage == 2


def test_straggler_rebalance_improves_modeled_throughput():
    layers = network_layers("synthnet")
    plat = paper_platform(4)
    trace0 = Trace(DatabaseEvaluator(plat, layers))
    seed = generate_seed(weights(layers), plat)
    base = tune(seed, trace0)
    mit = StragglerMitigator(plat, base.best_conf, _make_trace_factory(layers))

    # EP of stage 0 becomes 3x slower
    times = trace0.evaluator.stage_times(base.best_conf)
    times[0] *= 3.0
    out = mit.rebalance(times)
    assert out is not None
    new_conf, result = out
    # rebalanced schedule beats keeping the old schedule on the derated platform
    derated_ev = Trace(DatabaseEvaluator(mit.platform, layers)).evaluator
    assert derated_ev.throughput(new_conf) >= derated_ev.throughput(base.best_conf) - 1e-12


def test_elastic_rescale_survives_ep_loss():
    layers = network_layers("synthnet")
    plat = paper_platform(4)
    el = ElasticScheduler(plat, weights(layers), _make_trace_factory(layers))
    conf, res = el.on_topology_change(dead_eps=[1])
    assert el.platform.n_eps == 3
    assert conf.depth <= 3
    assert all(ep < 3 for ep in conf.eps)
    assert res.best_throughput > 0


def test_elastic_all_dead_raises():
    layers = network_layers("synthnet")
    plat = paper_platform(2)
    el = ElasticScheduler(plat, weights(layers), _make_trace_factory(layers))
    with pytest.raises(RuntimeError):
        el.on_topology_change(dead_eps=[0, 1])


def test_supervisor_nan_quarantine(tmp_path):
    store = CheckpointStore(tmp_path)
    calls = {"n": 0}

    def step_fn(state, step):
        calls["n"] += 1
        v = state["x"] + 1.0
        # poison exactly one step the first time it is reached
        if step == 4 and calls["n"] < 8:
            return {"x": v}, float("nan")
        return {"x": v}, float(v)

    sup = TrainSupervisor(store=store, save_every=2, max_restores=3)
    state, losses = sup.run({"x": jnp.asarray(0.0)}, step_fn, n_steps=6)
    assert len(losses) == 6 or math.isfinite(losses[-1])
    assert all(math.isfinite(l) for l in losses)
    assert float(state["x"]) >= 6.0 - 1e-6


def test_supervisor_checkpoints_written(tmp_path):
    store = CheckpointStore(tmp_path)
    sup = TrainSupervisor(store=store, save_every=2)
    state, losses = sup.run({"x": jnp.asarray(0.0)}, lambda s, t: ({"x": s["x"] + 1}, 1.0), n_steps=5)
    assert store.steps()  # saved at 2, 4, 5 (minus GC)
