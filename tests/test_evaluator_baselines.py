"""Evaluator semantics + baseline exploration algorithms."""

import math

import pytest

from repro.core import (
    AnalyticEvaluator,
    DatabaseEvaluator,
    PipelineConfig,
    Trace,
    exhaustive_search,
    generate_seed,
    hill_climbing,
    paper_platform,
    pipe_search,
    random_walk,
    run_shisha,
    simulated_annealing,
    weights,
)
from repro.models.cnn import network_layers


def _setup(net="synthnet", n_eps=4):
    layers = network_layers(net)
    plat = paper_platform(n_eps)
    return layers, plat


def test_throughput_is_inverse_slowest_stage():
    layers, plat = _setup()
    ev = AnalyticEvaluator(plat, layers)
    conf = PipelineConfig(stages=(9, 9), eps=(0, 1))
    ts = ev.stage_times(conf)
    assert ev.throughput(conf) == pytest.approx(1.0 / max(ts))


def test_fep_faster_than_sep():
    layers, plat = _setup()
    ev = AnalyticEvaluator(plat, layers)
    conf_fast = PipelineConfig(stages=(18,), eps=(plat.feps[0],))
    conf_slow = PipelineConfig(stages=(18,), eps=(plat.seps[0],))
    assert ev.throughput(conf_fast) > ev.throughput(conf_slow)


def test_latency_knob_inert_below_1ms():
    """Fig. 9: inter-chiplet latency only matters above ~1 ms."""
    layers, plat = _setup()
    conf = PipelineConfig(stages=(5, 5, 4, 4), eps=(0, 1, 2, 3))
    base = AnalyticEvaluator(plat, layers).throughput(conf)
    tiny = AnalyticEvaluator(plat.with_latency(1e-6), layers).throughput(conf)
    huge = AnalyticEvaluator(plat.with_latency(1.0), layers).throughput(conf)
    assert tiny == pytest.approx(base, rel=0.05)
    assert huge < 0.5 * base


def test_database_deterministic():
    layers, plat = _setup()
    ev1 = DatabaseEvaluator(plat, layers)
    ev2 = DatabaseEvaluator(plat, layers)
    conf = PipelineConfig(stages=(10, 8), eps=(0, 2))
    assert ev1.throughput(conf) == ev2.throughput(conf)


def test_trace_accounts_cost_and_curve():
    layers, plat = _setup()
    tr = Trace(DatabaseEvaluator(plat, layers), setup_cost=5.0)
    conf = PipelineConfig(stages=(9, 9), eps=(0, 1))
    tr.execute(conf)
    assert tr.wall > 5.0  # setup + measurement cost
    curve = tr.convergence_curve()
    assert len(curve) == 1 and curve[0][1] == tr.best().throughput


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------


def test_es_beats_or_matches_everyone():
    layers, plat = _setup()
    ws = weights(layers)
    ev = DatabaseEvaluator(plat, layers)
    es = exhaustive_search(Trace(ev), 18, max_depth=2)
    for algo in (hill_climbing, simulated_annealing, random_walk):
        res = algo(Trace(ev), 18, budget_s=30.0, seed=1)
        assert res.best_throughput <= es.best_throughput * 1.001 or res.best_conf.depth > 2

    shisha = run_shisha(ws, Trace(ev), "H3", n_stages=2)
    assert shisha.result.best_throughput >= 0.85 * es.best_throughput


def test_pipe_search_runs_and_respects_budget():
    layers, plat = _setup()
    ws = weights(layers)
    tr = Trace(DatabaseEvaluator(plat, layers), setup_cost=2.0)
    res = pipe_search(tr, ws, budget_s=20.0, max_depth=3)
    assert res.best_throughput > 0
    assert tr.wall >= 2.0


def test_budgets_respected():
    layers, plat = _setup()
    ev = DatabaseEvaluator(plat, layers)
    tr = Trace(ev)
    random_walk(tr, 18, budget_s=3.0, seed=0)
    # at most ONE trial may start past the budget; bound its worst cost
    # (whole net on the slowest EP: fill + measure_batches beats + reconfig)
    worst_beat = sum(max(ev.layer_time_by_index(i, e) for e in range(plat.n_eps)) for i in range(18))
    assert tr.wall < 3.0 + (tr.measure_batches + 1) * worst_beat + tr.reconfig_overhead
