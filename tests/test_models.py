"""Per-architecture smoke tests + decode/prefill consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, applicable, get_config, get_smoke
from repro.models.lm_common import init_params
from repro.models.transformer import (
    init_cache,
    layer_costs,
    make_train_step,
    prefill_step,
    serve_step,
    train_loss,
)
from repro.optim import AdamW, AdamWConfig

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg, key=KEY, b=B, s=S):
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
    }
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (b, cfg.enc_frames, cfg.d_model))
    if cfg.n_patches:
        batch["patch_embeds"] = jax.random.normal(key, (b, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, KEY)
    opt = AdamW(AdamWConfig(total_steps=10, warmup=2))
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    p2, s2, m = step(params, state, _batch(cfg))
    assert jnp.isfinite(m["loss"])
    assert jnp.isfinite(m["grad_norm"])
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum()) for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_shapes(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, KEY)
    cache = init_cache(cfg, B, 32)
    if cfg.is_encdec:
        from repro.models.transformer import prefill

        cache = prefill(cfg, params, _batch(cfg), cache)
    step = jax.jit(lambda p, c, t: serve_step(cfg, p, c, t))
    logits, cache = step(params, cache, jnp.zeros((B, 1), jnp.int32))
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen3-32b", "mamba2-130m", "zamba2-2.7b"])
def test_decode_matches_full_forward(arch):
    """Token-by-token decode reproduces the teacher-forced forward."""
    cfg = dataclasses.replace(get_smoke(arch), dtype=jnp.float32)
    params = init_params(cfg, KEY)
    s = 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, s), 0, cfg.vocab)
    from repro.models.lm_common import rms_norm
    from repro.models.transformer import backbone, embed_tokens

    x = embed_tokens(cfg, params, toks)
    pos = jnp.arange(s)[None, :] * jnp.ones((1, 1), jnp.int32)
    h, _ = backbone(cfg, params, x, pos)
    full = (h @ params["unembed"]).astype(jnp.float32)

    cache = init_cache(cfg, 1, s)
    outs = []
    for t in range(s):
        lg, cache = serve_step(cfg, params, cache, toks[:, t : t + 1])
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["granite-3-2b", "mamba2-130m", "whisper-small", "zamba2-2.7b"])
def test_prefill_then_decode_consistent(arch):
    """prefill(prompt) + decode(next) == decode-from-scratch all the way."""
    cfg = dataclasses.replace(get_smoke(arch), dtype=jnp.float32)
    params = init_params(cfg, KEY)
    s = 8
    batch = _batch(cfg, b=1, s=s)
    logits_pf, cache_pf = prefill_step(cfg, params, batch, max_len=s + 4)

    cache = init_cache(cfg, 1, s + 4)
    if cfg.is_encdec:
        from repro.models.transformer import prefill as warm

        cache = warm(cfg, params, batch, cache)
    toks = batch["tokens"][:, : min(s, cfg.max_decoder_len or s)]
    for t in range(toks.shape[1]):
        lg, cache = serve_step(cfg, params, cache, toks[:, t : t + 1])
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_pf), rtol=3e-4, atol=3e-4)


def test_sliding_window_ring_cache():
    """Ring cache with window smaller than sequence stays consistent."""
    cfg = dataclasses.replace(get_smoke("granite-3-2b"), dtype=jnp.float32, sliding_window=6)
    params = init_params(cfg, KEY)
    s = 16
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, s), 0, cfg.vocab)
    from repro.models.lm_common import rms_norm
    from repro.models.transformer import backbone, embed_tokens

    x = embed_tokens(cfg, params, toks)
    pos = jnp.arange(s)[None, :] * jnp.ones((1, 1), jnp.int32)
    h, _ = backbone(cfg, params, x, pos)
    full = (h @ params["unembed"]).astype(jnp.float32)

    cache = init_cache(cfg, 1, s)  # W = min(s, window) = 6 slots
    assert cache["k"].shape[2] == 6
    outs = []
    for t in range(s):
        lg, cache = serve_step(cfg, params, cache, toks[:, t : t + 1])
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_layer_costs_cover_chain(arch):
    cfg = get_config(arch)
    costs = layer_costs(cfg, seq=2048, batch=1)
    expected = cfg.n_layers + (cfg.enc_layers if cfg.is_encdec else 0)
    assert len(costs) == expected
    assert all(c.flops > 0 for c in costs)


def test_applicability_matrix():
    cells = [(a, s, *applicable(a, s)) for a in ARCHS for s in SHAPES]
    assert len(cells) == 40
    skipped = [c for c in cells if not c[2]]
    # exactly the 8 pure-attention long_500k cells are skipped
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s, _, _ in skipped)
    runnable = [c for c in cells if c[2]]
    assert len(runnable) == 32


def test_moe_flops_are_active_only():
    cfg = get_config("phi3.5-moe-42b")
    total, active = cfg.param_count(), cfg.active_param_count()
    assert active < 0.5 * total  # top-2 of 16 experts


def test_grad_accumulation_equivalence():
    """accum=2 gives (numerically close) same update as accum=1."""
    cfg = dataclasses.replace(get_smoke("granite-3-2b"), dtype=jnp.float32)
    params = init_params(cfg, KEY)
    opt = AdamW(AdamWConfig(total_steps=10, warmup=2, moment_dtype=jnp.float32))
    st = opt.init(params)
    batch = _batch(cfg, b=4, s=8)
    p1, _, m1 = jax.jit(make_train_step(cfg, opt, accum=1))(params, st, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, opt, accum=2))(params, st, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    l1, l2 = jax.tree.leaves(p1), jax.tree.leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)
