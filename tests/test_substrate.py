"""Substrate tests: optimizer, grad compression, data, checkpointing."""

import dataclasses
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointStore
from repro.data import DataConfig, SyntheticLMData, make_batch_iterator
from repro.optim import AdamW, AdamWConfig, compressed_psum, dequantize, quantize_int8

# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    opt = AdamW(AdamWConfig(peak_lr=0.1, warmup=5, total_steps=200, weight_decay=0.0,
                            moment_dtype=jnp.float32))
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        return opt.update(g, s, p)

    for _ in range(200):
        params, state, _ = step(params, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_clips_gradients():
    opt = AdamW(AdamWConfig(clip_norm=1.0))
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    _, _, m = opt.update({"w": jnp.full(4, 1e6)}, state, params)
    assert float(m["grad_norm"]) > 1.0  # raw norm reported


def test_master_weights_dtype():
    opt = AdamW(AdamWConfig())
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    state = opt.init(params)
    assert state["master"]["w"].dtype == jnp.float32
    assert state["mu"]["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------


@given(st.floats(1e-6, 1e6), st.integers(0, 100))
@settings(max_examples=50, deadline=None)
def test_quantize_bounds(scale_mag, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (32,)) * scale_mag
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = quantize_int8(x, scale)
    err = jnp.abs(dequantize(q, scale) - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-6


def test_compressed_psum_close_to_mean():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("dp",))

    def f(g):
        out, err = compressed_psum({"g": g}, "dp")
        return out["g"], err["g"]

    g = jax.random.normal(jax.random.PRNGKey(0), (64,))
    out, err = jax.jit(
        jax.shard_map(f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(None),
                      out_specs=jax.sharding.PartitionSpec(None), check_vma=False)
    )(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=2e-2)
    # error feedback residual = exactly the quantization error
    np.testing.assert_allclose(np.asarray(out + err), np.asarray(g), atol=2e-2)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_per_step():
    cfg = DataConfig(batch=4, seq=32, vocab=1000, seed=3)
    a = SyntheticLMData(cfg).batch_at(7)
    b = SyntheticLMData(cfg).batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_data_restart_equivalence():
    """Iterator restarted at step k produces the same stream (resume contract)."""
    from repro.configs import get_smoke

    mcfg = get_smoke("granite-3-2b")
    dcfg = DataConfig(batch=2, seq=16, vocab=mcfg.vocab, seed=5)
    it = make_batch_iterator(mcfg, dcfg, start_step=0)
    batches = [next(it) for _ in range(6)]
    it2 = make_batch_iterator(mcfg, dcfg, start_step=3)
    for i in range(3):
        b2 = next(it2)
        np.testing.assert_array_equal(np.asarray(batches[3 + i]["tokens"]), np.asarray(b2["tokens"]))


def test_labels_are_shifted_tokens():
    cfg = DataConfig(batch=2, seq=16, vocab=100, seed=1)
    b = SyntheticLMData(cfg).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def _state(x=1.0):
    return {"params": {"w": jnp.full((4, 4), x)}, "step": jnp.asarray(7)}


def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(tmp_path)
    st = _state(2.5)
    store.save(10, st)
    got = store.restore(10, jax.tree.map(jnp.zeros_like, st))
    np.testing.assert_allclose(np.asarray(got["params"]["w"]), 2.5)


def test_checkpoint_latest_and_gc(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _state(float(s)))
    assert store.steps() == [3, 4]
    step, got = store.restore_latest(_state(0.0))
    assert step == 4
    np.testing.assert_allclose(np.asarray(got["params"]["w"]), 4.0)


def test_checkpoint_async_and_torn_write(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(5, _state(5.0), async_=True)
    store.wait()
    assert store.steps() == [5]
    # a torn write (no _DONE) must be invisible
    torn = tmp_path / "step_00000009"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"garbage")
    assert store.steps() == [5]


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(1, _state())
    bad = {"params": {"w": jnp.zeros((2, 2))}, "step": jnp.asarray(0)}
    with pytest.raises(ValueError):
        store.restore(1, bad)
