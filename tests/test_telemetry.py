"""Telemetry subsystem: exact metrics, deterministic traces, no-op pins.

Three contracts under test:

  1. **Exactness** — histogram quantiles are nearest-rank on the full
     observation multiset, bit-identical to the serving simulator's own
     ``percentile`` arithmetic.
  2. **Determinism** — two seeded co-serve runs export byte-identical JSONL
     and Chrome traces (simulated timestamps only, first-seen pid/tid
     mapping), and the exported Chrome trace is strict JSON carrying spans
     from all three layers (request lifecycle, re-tune window, fabric flow
     window).
  3. **Off-by-default** — passing ``NULL`` (or nothing) leaves every
     existing summary bit-for-bit unchanged and records nothing, and the
     instrumented event loop's no-op path still clears a conservative
     dispatch-rate floor.
"""

from __future__ import annotations

import dataclasses
import json
import math
import time

import pytest

from repro.core import DatabaseEvaluator, Trace, paper_platform, weights
from repro.core.heuristics import run_shisha
from repro.interconnect import Flow, mesh2d, uniform_fabric
from repro.models.cnn import network_layers
from repro.serve import (
    ContinuousShisha,
    PoissonTraffic,
    ReplayTraffic,
    ServingSimulator,
    Tenant,
    co_serve,
)
from repro.serve.simulator import EventLoop, percentile
from repro.telemetry import NULL, Histogram, NullTelemetry, Telemetry, live


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_histogram_quantiles_match_simulator_percentile():
    vals = [0.7, 0.1, 3.2, 0.1, 2.5, 1.9, 0.4, 5.0, 0.9, 2.2, 0.3]
    h = Histogram("t")
    for v in vals:
        h.observe(v)
    ref = sorted(vals)
    for q in (0.0, 0.5, 0.9, 0.95, 0.99, 1.0):
        assert h.quantile(q) == percentile(ref, q)
    snap = h.snapshot()
    assert snap["count"] == len(vals)
    assert snap["min"] == min(vals) and snap["max"] == max(vals)
    assert snap["sum"] == pytest.approx(sum(vals))
    assert snap["p50"] == percentile(ref, 0.5)
    assert snap["p95"] == percentile(ref, 0.95)


def test_registry_get_or_create_and_kind_mismatch():
    tl = Telemetry()
    c = tl.counter("x")
    c.inc()
    c.inc(2.5)
    assert tl.counter("x") is c and c.value == 3.5
    tl.gauge("g").set(7)
    tl.histogram("h").observe(1.0)
    with pytest.raises(TypeError):
        tl.histogram("x")
    assert tl.registry.names() == ["g", "h", "x"]
    snap = tl.metrics_snapshot()
    assert snap["x"] == {"kind": "counter", "value": 3.5}
    assert snap["g"] == {"kind": "gauge", "value": 7}


def test_live_normalizes_null_and_none():
    tl = Telemetry()
    assert live(tl) is tl
    assert live(None) is None
    assert live(NULL) is None
    assert live(NullTelemetry()) is None


# ---------------------------------------------------------------------------
# no-op pins: NULL changes nothing, records nothing
# ---------------------------------------------------------------------------


def _drift_sim(telemetry):
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    ev = DatabaseEvaluator(plat, layers)
    sh = run_shisha(weights(layers), Trace(ev), "H3")
    conf, cap = sh.result.best_conf, sh.result.best_throughput
    tuner = ContinuousShisha(
        plat,
        layers,
        make_evaluator=lambda p: DatabaseEvaluator(p, layers),
        measure_batches=2,
        alpha=4,
    )
    sim = ServingSimulator(ev, conf, slo=3.0, autotuner=tuner, telemetry=telemetry)
    times = ev.stage_times(conf)
    bad_ep = conf.eps[max(range(conf.depth), key=times.__getitem__)]
    sim.schedule_slowdown(10.0, bad_ep, 3.0)
    traffic = PoissonTraffic(rate=0.5 * cap, seed=3)
    return sim.run(traffic.arrivals(40.0), 40.0)


def test_nullsink_serve_summary_bit_identical():
    base = _drift_sim(None)
    null = _drift_sim(NULL)
    assert base.reconfigs, "scenario must actually re-tune to pin anything"
    assert dataclasses.asdict(null) == dataclasses.asdict(base)
    # the shared NULL sink recorded nothing anywhere
    assert len(NULL.registry) == 0 and len(NULL.tracer) == 0


def test_nullsink_fabric_adaptive_pricing_identical():
    topo = mesh2d(3, 3)
    flows = [Flow(0, 8, 4e6, nodes=True), Flow(2, 6, 4e6, nodes=True), Flow(0, 6, 2e6, nodes=True)]
    bare = uniform_fabric(topo, mc_bw=None, routing="adaptive", seed=1)
    live_tl = Telemetry()
    for sink, expect_recording in ((None, False), (NULL, False), (live_tl, True)):
        fab = uniform_fabric(topo, mc_bw=None, routing="adaptive", seed=1)
        fab.telemetry = live(sink)
        assert fab.flow_times(flows) == bare.flow_times(flows)
        recorded = "fabric.routing_passes" in (
            sink.registry if sink is not None else Telemetry().registry
        )
        assert recorded == expect_recording
    snap = live_tl.metrics_snapshot()
    assert snap["fabric.routing_passes"]["value"] == 1.0
    assert "fabric.adaptive_delta_s" in snap
    assert snap["fabric.contention_factor"]["max"] >= 1.0


def test_trace_telemetry_records_trials_without_changing_wall():
    layers = network_layers("alexnet")
    plat = paper_platform(4)
    bare = Trace(DatabaseEvaluator(plat, layers))
    tl = Telemetry()
    instrumented = Trace(DatabaseEvaluator(plat, layers), telemetry=tl)
    r1 = run_shisha(weights(layers), bare, "H3")
    r2 = run_shisha(weights(layers), instrumented, "H3")
    assert r2.result.best_conf == r1.result.best_conf
    assert instrumented.wall == bare.wall
    snap = tl.metrics_snapshot()
    assert snap["tune.trials"]["value"] == bare.n_trials
    assert snap["tune.trial_cost_s"]["count"] == bare.n_trials


def test_event_loop_noop_dispatch_floor():
    class Owner:
        def _dispatch(self, t, kind, payload):
            pass

    owner = Owner()
    loop = EventLoop()
    n = 50_000
    for i in range(n):
        loop.push(i * 1e-6, 0, owner, None)
    t0 = time.perf_counter()
    loop.run(math.inf)
    wall = time.perf_counter() - t0
    assert loop.n_dispatched == n
    assert loop.telemetry is None
    # conservative floor: the un-instrumented loop must stay a hot path
    assert n / wall > 20_000, f"event loop at {n / wall:.0f} ev/s"


def test_serve_telemetry_overhead_floor():
    """Instrumented serving must stay close to the bare run.

    Mirrors ``benchmarks.selfbench.bench_serve``: arms are warmed once and
    then *interleaved* best-of, so machine-load drift cancels out of the
    ratio instead of biasing it.  The bound is deliberately loose — the
    optimized hot path (direct ``TraceEvent`` appends from the simulator)
    measures ~1.4-1.65x on the PR 9 drain engine (the faster bare loop
    shrank the denominator; it was ~1.4x on the legacy heap engine),
    while a regression to the pre-optimization path (two delegation
    layers per span) would now sit well above 2x — so the floor still
    catches the old path without flaking on a loaded machine.
    """
    layers = network_layers("synthnet")
    plat = paper_platform(8)
    ev = DatabaseEvaluator(plat, layers)
    sh = run_shisha(weights(layers), Trace(ev), "H3")
    conf, cap = sh.result.best_conf, sh.result.best_throughput
    horizon = 120.0
    arrivals = PoissonTraffic(rate=0.6 * cap, seed=7).arrivals(horizon)

    def arm(instrumented: bool) -> float:
        tl = Telemetry() if instrumented else None
        sim = ServingSimulator(ev, conf, slo=3.0, telemetry=tl)
        t0 = time.perf_counter()
        sim.run(arrivals, horizon)
        return time.perf_counter() - t0

    arm(False), arm(True)  # warmup, untimed
    bare = tel = math.inf
    for _ in range(5):
        bare = min(bare, arm(False))
        tel = min(tel, arm(True))
    ratio = tel / bare
    assert ratio < 1.9, f"telemetry serve overhead {ratio:.2f}x (bare {bare:.3f}s)"


# ---------------------------------------------------------------------------
# co-serve: determinism + three-layer trace acceptance
# ---------------------------------------------------------------------------


def _co_serve_run(telemetry):
    plat = paper_platform(8).with_fabric(uniform_fabric(mesh2d(2, 4)))
    horizon = 8.0
    tenants = [
        Tenant(
            name="resnet50",
            layers=tuple(network_layers("resnet50")),
            traffic=ReplayTraffic.record(PoissonTraffic(rate=30, seed=1), horizon),
            slo=1.0,
        ),
        Tenant(
            name="alexnet",
            layers=tuple(network_layers("alexnet")),
            traffic=ReplayTraffic.record(PoissonTraffic(rate=40, seed=2), horizon),
            slo=0.5,
        ),
    ]
    return co_serve(
        plat,
        tenants,
        horizon=horizon,
        measure_batches=2,
        alpha=4,
        faults=[("dropout", 2.0, 0)],
        telemetry=telemetry,
    )


def test_seeded_co_serve_exports_are_byte_identical():
    tl_a, tl_b = Telemetry(), Telemetry()
    res_a = _co_serve_run(tl_a)
    res_b = _co_serve_run(tl_b)
    assert res_a.aggregate_slo_rate == res_b.aggregate_slo_rate
    jsonl_a, jsonl_b = tl_a.export_jsonl(), tl_b.export_jsonl()
    assert jsonl_a and jsonl_a == jsonl_b
    chrome_a = json.dumps(tl_a.export_chrome_trace(), sort_keys=True)
    chrome_b = json.dumps(tl_b.export_chrome_trace(), sort_keys=True)
    assert chrome_a == chrome_b
    assert json.dumps(tl_a.metrics_snapshot(), sort_keys=True) == json.dumps(
        tl_b.metrics_snapshot(), sort_keys=True
    )


def test_chrome_trace_has_all_three_layers_and_tenant_processes():
    tl = Telemetry()
    res = _co_serve_run(tl)
    trace = tl.export_chrome_trace()
    # strict JSON (Perfetto rejects NaN/Infinity)
    text = json.dumps(trace, allow_nan=False)
    assert json.loads(text)["traceEvents"]
    events = trace["traceEvents"]
    spans = [e for e in events if e.get("ph") == "X"]
    cats = {e.get("cat") for e in spans}
    assert {"request", "retune", "fabric"} <= cats, f"missing layers in {cats}"
    for e in spans:
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        assert e["dur"] >= 0 and e["ts"] >= 0
    procs = {
        e["args"]["name"]
        for e in events
        if e.get("ph") == "M" and e["name"] == "process_name"
    }
    assert {"resnet50", "alexnet"} <= procs  # tenants render as processes
    tracks = {
        e["args"]["name"]
        for e in events
        if e.get("ph") == "M" and e["name"] == "thread_name"
    }
    assert any(t.startswith("ep") for t in tracks)  # EPs render as tracks
    assert "flows" in tracks  # fabric flow windows render as a track
    # the repartition decision shows up as a coserve instant
    assert res.repartitions
    assert any(
        e.get("ph") == "i" and e["name"] == "repartition" for e in events
    )
    # JSONL round-trips line by line
    lines = tl.export_jsonl().splitlines()
    assert len(lines) == len(tl.tracer.events)
    for line in lines:
        json.loads(line)


def test_nullsink_co_serve_matches_bare_run():
    bare = _co_serve_run(None)
    null = _co_serve_run(NULL)
    assert [dataclasses.asdict(r.sim) for r in null.results] == [
        dataclasses.asdict(r.sim) for r in bare.results
    ]
    assert [dataclasses.asdict(e) for e in null.repartitions] == [
        dataclasses.asdict(e) for e in bare.repartitions
    ]
    assert null.partitions == bare.partitions


# ---------------------------------------------------------------------------
# package-deal steals
# ---------------------------------------------------------------------------


def test_extreme_pressure_victim_steals_a_bundle():
    plat = paper_platform(8)
    horizon = 12.0
    layers_v = tuple(network_layers("synthnet"))
    layers_d = tuple(network_layers("alexnet"))
    # victim demand ~3x what its launch partition can serve; donor idle
    from repro.serve import partition_eps, subplatform

    parts = partition_eps(plat, 2, "interleaved")
    cap = run_shisha(
        weights(list(layers_v)),
        Trace(DatabaseEvaluator(subplatform(plat, parts[0], "v"), list(layers_v))),
        "H3",
    ).result.best_throughput
    tenants = [
        Tenant(
            name="victim",
            layers=layers_v,
            traffic=ReplayTraffic.record(
                PoissonTraffic(rate=3.0 * cap, seed=5), horizon
            ),
            slo=1.0,
        ),
        Tenant(
            name="donor",
            layers=layers_d,
            traffic=ReplayTraffic.record(PoissonTraffic(rate=0.5, seed=6), horizon),
            slo=5.0,
        ),
    ]
    dead = parts[0][0]
    tl = Telemetry()
    res = co_serve(
        plat,
        tenants,
        horizon=horizon,
        measure_batches=2,
        alpha=4,
        faults=[("dropout", 3.0, dead)],
        telemetry=tl,
        max_bundle=3,
    )
    ev = next(e for e in res.repartitions if e.kind == "dropout")
    assert ev.victim == "victim"
    assert len(ev.bundle) >= 2, f"expected a package deal, got {ev.bundle}"
    # first deal mirrors the legacy single-steal fields
    assert ev.bundle[0]["donor"] == ev.donor
    assert ev.bundle[0]["ep"] == ev.stolen_ep
    assert ev.bundle[0]["price"] == ev.price
    for deal in ev.bundle:
        assert deal["donor"] == "donor"
        assert set(deal) == {
            "donor",
            "ep",
            "price",
            "gain",
            "surplus",
            "victim_at_risk_after",
        }
        assert deal["surplus"] is None or deal["surplus"] > 0
    # every stolen EP actually moved victim-ward, partitions stay disjoint
    stolen = [d["ep"] for d in ev.bundle]
    assert set(stolen) <= set(ev.partitions["victim"])
    assert not set(ev.partitions["victim"]) & set(ev.partitions["donor"])
    # strict JSON payload (inf gains serialized as None)
    json.dumps([dict(d) for d in ev.bundle], allow_nan=False)
    # and the event is on the telemetry timeline with its pricing breakdown
    inst = next(
        e
        for e in tl.tracer.events
        if e.name == "repartition" and e.dur is None
    )
    assert len(inst.args["bundle"]) == len(ev.bundle)
    assert tl.metrics_snapshot()["coserve.eps_stolen"]["value"] == len(ev.bundle)


def test_single_bundle_is_legacy_single_steal():
    """max_bundle=1 must reproduce the pre-bundle rebalance exactly."""
    from repro.serve.multitenant import ElasticPartitioner

    plat = paper_platform(8)
    layers = {
        "a": tuple(network_layers("synthnet")),
        "b": tuple(network_layers("alexnet")),
    }
    tenants = {
        name: Tenant(
            name=name, layers=ls, traffic=PoissonTraffic(rate=1, seed=1), slo=1.0
        )
        for name, ls in layers.items()
    }
    pricer = ElasticPartitioner(
        plat, lambda p, L: DatabaseEvaluator(p, L), "H3"
    )
    partitions = {"a": (0, 2, 4), "b": (1, 3, 5, 6, 7)}
    loads = {"a": (50.0, 20.0), "b": (0.1, 0.0)}
    single = pricer.rebalance(partitions, "a", tenants, loads)
    deals, parts = pricer.rebalance_bundle(
        partitions, "a", tenants, loads, max_bundle=1
    )
    assert single is not None and len(deals) == 1
    donor, ep, price = single
    assert (deals[0]["donor"], deals[0]["ep"], deals[0]["price"]) == (donor, ep, price)
    assert parts["a"] == partitions["a"] + (ep,)
    # input mapping was not mutated
    assert partitions == {"a": (0, 2, 4), "b": (1, 3, 5, 6, 7)}
